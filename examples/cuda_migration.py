"""Port of the paper's Fig. 8 host program (vector copy): what the
manual CUDA-host → COX-host migration looks like in this framework.

CUDA (paper Fig. 8a)                 | here
-------------------------------------+---------------------------------
cudaMalloc / cudaMemcpy              | numpy / jnp arrays (host==device)
vecCopy<<<grid_size, 1024>>>(a, b)   | vec_copy.launch(grid=..., block=...)
kernel<<<dim3(4,4), dim3(16,16)>>>   | launch(grid=(4, 4), block=(16, 16))
pthread fork/join per block          | lax.scan over blocks (single dev)
                                     | shard_map over mesh (multi dev)

    PYTHONPATH=src python examples/cuda_migration.py
"""
import numpy as np

from repro.core import cox


@cox.kernel
def vec_copy(c, d_b: cox.Array(cox.f32), d_a: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    d_b[i] = d_a[i]


@cox.kernel
def mat_transpose(c, odata: cox.Array(cox.f32), idata: cox.Array(cox.f32),
                  n: cox.i32):
    # the SDK's 2-D tiled transpose, unmodified dim3 indexing: no
    # hand-flattening of threadIdx/blockIdx into linear arithmetic
    tile = c.shared((16, 17), cox.f32)
    x = c.block_idx('x') * 16 + c.thread_idx('x')
    y = c.block_idx('y') * 16 + c.thread_idx('y')
    tile[c.thread_idx('y'), c.thread_idx('x')] = idata[y * n + x]
    c.syncthreads()
    xo = c.block_idx('y') * 16 + c.thread_idx('x')
    yo = c.block_idx('x') * 16 + c.thread_idx('y')
    odata[yo * n + xo] = tile[c.thread_idx('x'), c.thread_idx('y')]


def main():
    n = 4096
    grid_size = n // 1024

    # cudaMalloc + cudaMemcpy(HostToDevice) —> just arrays
    h_a = np.random.default_rng(0).normal(size=n).astype(np.float32)
    h_b = np.zeros(n, np.float32)

    # vecCopy<<<grid_size, 1024>>>(d_a, d_b)
    out = vec_copy.launch(grid=grid_size, block=1024, args=(h_b, h_a))

    # cudaMemcpy(DeviceToHost)
    h_b = np.asarray(out["d_b"])
    assert np.array_equal(h_b, h_a)
    print(f"copied {n} floats through a {grid_size}x1024 COX grid: OK")

    # normal mode vs JIT mode (paper §4: runtime config as variable vs
    # burned in at compile time)
    out_n = vec_copy.launch(grid=grid_size, block=1024, args=(h_b, h_a),
                            mode="normal")
    assert np.array_equal(np.asarray(out_n["d_b"]), h_a)
    print("normal-mode launch: OK")

    # dim3 launch geometry: transpose<<<dim3(4,4), dim3(16,16)>>>(o, i, n)
    m = 64
    h_m = np.random.default_rng(1).normal(size=(m, m)).astype(np.float32)
    out_t = mat_transpose.launch(grid=(4, 4), block=(16, 16),
                                 args=(np.zeros((m, m), np.float32), h_m, m))
    assert np.array_equal(np.asarray(out_t["odata"]), h_m.T)
    print(f"transposed a {m}x{m} matrix through a dim3(4,4)x(16,16) "
          f"COX grid: OK")


if __name__ == "__main__":
    main()
