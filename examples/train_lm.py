"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing and deterministic resume.

By default uses a width-reduced mamba2 (~100M at full vocab); pass
--arch mamba2-130m for the real 130M config (slower on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

import numpy as np

from repro.launch.train import train
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    out = train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        log_every=20,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20,
                            total_steps=args.steps),
    )
    losses = out["losses"]
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\ntrained {args.arch} for {args.steps} steps: "
          f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first else 'check hyperparams'})")


if __name__ == "__main__":
    main()
