"""CUDA graphs on COX: stream capture, instantiate, replay.

The CUDA idiom this ports:

    cudaStreamBeginCapture(s, cudaStreamCaptureModeGlobal);
    step1<<<grid, block, 0, s>>>(tmp, x, y, n);
    step2<<<grid, block, 0, s>>>(out, tmp, n);      // depends on step1
    cudaStreamEndCapture(s, &graph);
    cudaGraphInstantiate(&exec, graph, 0);
    for (int t = 0; t < T; ++t) {
        cudaGraphExecKernelNodeSetParams(exec, ...); // rebind inputs
        cudaGraphLaunch(exec, s);                    // zero re-dispatch
    }

Here `graph.capture(stream)` records every launch (and event edge)
issued on the stream *without dispatching*; `instantiate()` stages the
captured DAG as ONE jitted XLA program — intermediates thread straight
from producer to consumer inside the trace, so XLA fuses across the
launch boundaries — and `replay(**bindings)` re-executes it with
rebound inputs and no per-launch host work.  Replay is guaranteed
bitwise-equal to issuing the same launches eagerly.

    PYTHONPATH=src python examples/graph_replay.py
"""
import statistics
import time

import numpy as np

from repro.core import cox


@cox.kernel
def saxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
          y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


@cox.kernel
def scale(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = x[i] * 0.5 + 1.0


def main():
    grid, block = 32, 256
    n = grid * block
    x = np.arange(n, dtype=np.float32) / n
    y = np.ones(n, np.float32)
    o = np.zeros(n, np.float32)

    s = cox.Stream("capture")

    # ---- capture: record the 2-launch chain, nothing dispatches ----
    g = cox.Graph(name="saxpy-scale")
    with g.capture(s):
        h1 = s.launch(saxpy, grid=grid, block=block, args=(o, x, y, n))
        s.launch(scale, grid=grid, block=block,
                 args=(o, h1.outputs["out"], n))   # data edge, not a sync
    exe = g.instantiate()
    print(f"captured {len(g.nodes)} launches; "
          f"inputs={list(exe.input_names)}")

    # ---- replay == the same launches issued eagerly, bitwise ----
    r1 = saxpy.launch(grid=grid, block=block, args=(o, x, y, n))
    ref = scale.launch(grid=grid, block=block,
                       args=(o, np.asarray(r1["out"]), n))["out"]
    got = exe.replay()["out"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    print("bitwise: replay == eager launches")

    # ---- rebind and replay: new inputs, zero re-capture ----
    x2 = x[::-1].copy()
    got2 = exe.replay(x=x2)["out"]
    want2 = (2.5 * x2 + y) * 0.5 + 1.0
    np.testing.assert_array_equal(np.asarray(got2), want2.astype(np.float32))
    print("rebound replay: exe.replay(x=reversed) correct")

    # ---- timing: per-launch dispatch vs one replay per "token" ----
    def eager(xv):
        h = s.launch(saxpy, grid=grid, block=block, args=(o, xv, y, n))
        h = s.launch(scale, grid=grid, block=block,
                     args=(o, h.outputs["out"], n))
        return np.asarray(h.result()["out"])

    def replay(xv):
        return np.asarray(exe.replay(x=xv)["out"])

    eager(x), replay(x)                       # warm both paths
    te, tg = [], []
    for _ in range(40):
        t0 = time.perf_counter()
        eager(x)
        te.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        replay(x)
        tg.append(time.perf_counter() - t0)
    eager_ms = statistics.median(te) * 1e3
    replay_ms = statistics.median(tg) * 1e3
    print(f"eager dispatch: {eager_ms:7.2f} ms")
    print(f"graph replay:   {replay_ms:7.2f} ms "
          f"({eager_ms / replay_ms:.2f}x)")


if __name__ == "__main__":
    main()
