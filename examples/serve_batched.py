"""Batched serving example: continuous batching of synthetic requests
through the jitted serve step (the same graph the dry-run lowers at
32k context × 512 chips).

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse

from repro.launch.serve import serve_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()
    out = serve_requests(args.arch, batch=args.batch, ctx=args.ctx,
                         n_requests=args.requests, max_tokens=args.tokens)
    print(f"served {out['completed']} requests / {out['tokens']} tokens "
          f"in {out['wall_s']:.1f}s -> {out['tok_per_s']:.1f} tok/s "
          f"(batch={args.batch}, ctx={args.ctx})")


if __name__ == "__main__":
    main()
