"""CUDA streams & events on COX: cross-stream overlap of independent kernels.

The CUDA idiom this ports:

    cudaStream_t s1, s2;  cudaEvent_t start, stop;
    saxpy<<<grid, block, 0, s1>>>(o1, x, y, n);
    scale<<<grid, block, 0, s2>>>(o2, x, n);       // overlaps s1
    cudaEventRecord(stop, s2); ...
    cudaStreamSynchronize(s1); cudaStreamSynchronize(s2);

Here `cox.Stream.launch` enqueues a request and returns a
`LaunchHandle` future; the dispatcher stages each launch once (all
streams share the executable cache) and dispatches in topological order
through XLA's *async* dispatch — the host issues stream 2's kernel
while stream 1's is still executing, which is where the overlap win
comes from on a single XLA device.  Events order streams against each
other and time the pipeline.

    PYTHONPATH=src python examples/streams_overlap.py
"""
import time
import statistics

import numpy as np

from repro.core import cox


@cox.kernel
def saxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
          y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


@cox.kernel
def scale(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = x[i] * 3.0 + 1.0


def main():
    grid, block = 32, 256
    n = grid * block                 # one element per thread, full coverage
    x = np.arange(n, dtype=np.float32) / n
    y = np.ones(n, np.float32)
    o = np.zeros(n, np.float32)
    a1, a2 = (o, x, y, n), (o, x, n)

    s1, s2 = cox.Stream("s1"), cox.Stream("s2")

    # ---- serial issue: launch + synchronize, one after the other ----
    ref1 = saxpy.launch(grid=grid, block=block, args=a1)
    ref2 = scale.launch(grid=grid, block=block, args=a2)

    # ---- two streams: issue both, then synchronize ----
    h1 = s1.launch(saxpy, grid=grid, block=block, args=a1)
    h2 = s2.launch(scale, grid=grid, block=block, args=a2)
    out1, out2 = h1.result(), h2.result()

    # any legal stream schedule is bitwise-identical to serial issue
    np.testing.assert_array_equal(np.asarray(out1["out"]),
                                  np.asarray(ref1["out"]))
    np.testing.assert_array_equal(np.asarray(out2["out"]),
                                  np.asarray(ref2["out"]))
    print("bitwise: 2-stream issue == serial issue")

    # ---- event edge: s2 waits on s1's tail before its next launch ----
    h1 = s1.launch(saxpy, grid=grid, block=block, args=a1)
    ev = s1.record_event()
    s2.wait_event(ev)
    h2 = s2.launch(scale, grid=grid, block=block,
                   args=(o, h1.outputs["out"], n))   # chained, no host sync
    chained = h2.result()["out"]
    want = np.asarray(ref1["out"]) * 3.0 + 1.0
    np.testing.assert_array_equal(np.asarray(chained), want)
    print("event edge + handle chaining: scale(saxpy(x)) correct")

    # ---- timing: serial issue vs 2-stream issue (events time it) ----
    # both paths materialize every result to host numpy; "serial" does
    # it launch-by-launch, "streams" issues everything first
    ts, to = [], []
    for _ in range(20):
        t0 = time.perf_counter()
        np.asarray(saxpy.launch(grid=grid, block=block, args=a1)["out"])
        np.asarray(scale.launch(grid=grid, block=block, args=a2)["out"])
        ts.append(time.perf_counter() - t0)

        start = cox.Event().record(s1)
        t0 = time.perf_counter()
        h1 = s1.launch(saxpy, grid=grid, block=block, args=a1)
        h2 = s2.launch(scale, grid=grid, block=block, args=a2)
        np.asarray(h1.result()["out"])
        np.asarray(h2.result()["out"])
        to.append(time.perf_counter() - t0)
        stop = cox.Event().record(s2)
        _ = start.elapsed(stop)          # the CUDA-style timing API

    serial_ms = statistics.median(ts) * 1e3
    stream_ms = statistics.median(to) * 1e3
    print(f"serial issue:   {serial_ms:7.2f} ms")
    print(f"2-stream issue: {stream_ms:7.2f} ms "
          f"({serial_ms / stream_ms:.2f}x)")


if __name__ == "__main__":
    main()
