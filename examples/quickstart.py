"""Quickstart: write a CUDA-style kernel, run it through hierarchical
collapsing, and check it against the per-thread oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cox
from repro.core.oracle import run_grid as oracle_run


# The paper's motivating kernel (Code 1): warp-shuffle tree reduction of
# the first warp, guarded by a conditional — the case flat collapsing
# cannot express.
@cox.kernel
def warp_reduce(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = val[tid]
    if tid < 32:
        offset = 16
        while offset > 0:
            s = c.shfl_down(v, offset)
            v = v + s
            offset = offset // 2
    if tid == 0:
        out[c.block_idx()] = v


def main():
    block = 256
    val = np.arange(block, dtype=np.float32)
    out0 = np.zeros(1, np.float32)

    # inspect the transformation
    ck = warp_reduce.compiled(collapse="hier")
    print("pipeline summary:", ck.summary())

    # run on the JAX executor (vectorized lanes = the paper's AVX role)
    got = warp_reduce.launch(grid=1, block=block, args=(out0, val))
    print("COX result   :", np.asarray(got['out']))

    # independent per-thread oracle (mini GPU simulator)
    ref = oracle_run(warp_reduce.ir, grid=1, block=block, args=(out0, val))
    print("oracle result:", ref["out"], " (expect", val[:32].sum(), ")")
    assert np.allclose(np.asarray(got["out"]), ref["out"])

    # flat collapsing (the prior art) must reject this kernel
    try:
        warp_reduce.launch(grid=1, block=block, args=(out0, val),
                           collapse="flat")
    except Exception as e:
        print("flat collapsing correctly rejects it:",
              type(e).__name__, "-", str(e)[:80])

    print("OK")


if __name__ == "__main__":
    main()
