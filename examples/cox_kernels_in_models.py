"""The three-way kernel story (DESIGN.md §3): the same row-softmax
written (1) as a CUDA-style COX kernel compiled by hierarchical
collapsing, (2) as a Pallas TPU kernel run in interpret mode, and
(3) as the pure-jnp reference — all agreeing.

    PYTHONPATH=src python examples/cox_kernels_in_models.py
"""
import numpy as np

from repro.core import cox
from repro.kernels import ref, softmax as sm


# (1) CUDA-style: one warp per row, warp collectives for max and sum —
# the reduction pattern the paper's warp-level features exist for.
@cox.kernel
def softmax_rows(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
                 cols: cox.i32):
    row = c.block_idx() * (c.block_dim() // 32) + c.warp_id()
    lane = c.lane_id()
    # strided load: each lane covers cols/32 elements
    m = -1e30
    j = lane
    while j < cols:
        m = max(m, x[row * cols + j])
        j = j + 32
    m = c.red_max(m)                     # warp collective max
    s = 0.0
    j = lane
    while j < cols:
        s = s + c.exp(x[row * cols + j] - m)
        j = j + 32
    s = c.red_add(s)                     # warp collective sum
    j = lane
    while j < cols:
        out[row * cols + j] = c.exp(x[row * cols + j] - m) / s
        j = j + 32


def main():
    rows, cols = 8, 128
    x = np.random.default_rng(0).normal(size=(rows, cols)).astype(np.float32)
    out0 = np.zeros_like(x)

    # 2 warps per block, 4 blocks -> 8 rows
    got_cox = softmax_rows.launch(grid=4, block=64,
                                  args=(out0, x, cols))["out"]
    got_pallas = sm.softmax(x, interpret=True)     # (2) Pallas interpret
    want = ref.softmax(x)                          # (3) jnp oracle

    np.testing.assert_allclose(np.asarray(got_cox), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    print("COX == Pallas(interpret) == jnp reference: OK")
    print("max |cox - ref| =", float(np.abs(got_cox - np.asarray(want)).max()))


if __name__ == "__main__":
    main()
