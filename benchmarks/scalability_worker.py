"""Fig. 14 worker: blocks sharded over 1/2/4/8 host devices.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent harness before jax initializes).
"""
import statistics
import time

import jax
import numpy as np

from repro.core import cox

RNG = np.random.default_rng(3)


@cox.kernel
def saxpy_heavy(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                b: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        acc = 0.0
        for t in range(64):  # compute-heavy body (Hetero-mark style)
            acc = acc + a[i] * 1.0001 + b[i] * 0.9999
        out[i] = acc


def main():
    ndev = len(jax.devices())
    n = 64 * 256
    a = RNG.normal(size=n).astype(np.float32)
    b = RNG.normal(size=n).astype(np.float32)
    out0 = np.zeros(n, np.float32)
    base_us = None
    for d in (1, 2, 4, 8):
        if d > ndev:
            break
        mesh = jax.make_mesh((d,), ("data",))

        def run():
            return saxpy_heavy.launch(grid=64, block=256,
                                      args=(out0, a, b, n), mesh=mesh)

        run()
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            res = run()
            jax.tree_util.tree_map(lambda x: x.block_until_ready(), res)
            ts.append(time.perf_counter() - t0)
        us = statistics.median(ts) * 1e6
        if base_us is None:
            base_us = us
        print(f"scalability.devices_{d},{us:.1f},"
              f"speedup={base_us / us:.2f}x", flush=True)
    print("scalability.NOTE,0.0,host has a single physical core - the 8 "
          "XLA host devices time-share it so wall-clock speedup is not "
          "observable here; block distribution + psum merge correctness "
          "is covered by tests/test_multidevice.py (paper Fig.14 ran on "
          "8 real cores)", flush=True)


if __name__ == "__main__":
    main()
