"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` also
writes every row (plus the structured backend-sweep matrix) to a
machine-readable JSON file (default path ``BENCH_PR10.json``) so the
perf trajectory is recorded across PRs.  ``--sections a,b`` runs a
subset; ``--smoke`` is the CI regression guard (1 timing iteration,
flagship kernels only).

  coverage      — Table 1: 31-kernel suite, flat vs hierarchical support
  flat_vs_hier  — Fig. 12: hierarchical overhead on warp-free kernels
  simd_vote     — Table 2: warp vote with vectorized vs scalar collectives
  jit_mode      — Fig. 13: JIT (unrolled) vs normal (fori) mode
  backend_sweep — grid-execution backends × warp execution: scan vs vmap
                  (vs sharded when >1 device) × serial vs batched warps,
                  equal outputs asserted + timing per cell
  streams       — async launch dispatch: two independent memory-bound
                  kernels on two cox streams vs serial issue, bitwise
                  equality asserted + overlap ratio per pipeline depth
  graph_replay  — CUDA graphs: a depth-d chain of dependent launches
                  captured once into a cox.Graph and replayed per token
                  vs eager per-launch dispatch, bitwise asserted
  placement     — multi-device stream placement: 4 streams round-robined
                  over 1/2/4/8-device pools (subprocess, 8 forced host
                  devices), bitwise equality vs the 1-device pool
                  asserted + throughput ratio per pool size
  grid_stride   — resident waves over oversubscribed grids: 64k–256k
                  blocks under a forced-small COX_FOOTPRINT_BUDGET,
                  the cost-model-routed grid-stride schedule vs the
                  unconstrained chunk-table walk and the clamped-chunk
                  fallback it replaces, bitwise asserted per cell
  autotune      — measured knob tuning vs the hand heuristics: each pick
                  kernel launched with the heuristic knobs, then with
                  autotune=True (cold: candidate cells measured into a
                  fresh cache; warm: zero-measurement cache hit
                  asserted), bitwise equality + never-slower recorded
                  per cell with op/mem estimates and achieved GFLOPS
  scalability   — Fig. 14: blocks across host devices (subprocess, 8 dev)
  roofline      — §Roofline terms from results/dryrun_all.json (if present)
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import cox  # noqa: E402
from repro.core.flat import FlatUnsupported, supports_flat  # noqa: E402
from repro.core.types import CoxUnsupported  # noqa: E402

# timing knobs (--smoke turns both down) and the JSON collectors
WARMUP = 2
ITERS = 10
SMOKE = False
RESULTS = []         # every CSV row, as dicts
SWEEP_RESULTS = []   # structured backend_sweep matrix
STREAM_RESULTS = []  # structured streams-overlap cells
GRAPH_RESULTS = []   # structured graph-replay cells
PLACEMENT_RESULTS = []  # structured multi-device placement cells
AUTOTUNE_RESULTS = []   # structured heuristic-vs-tuned cells
GRID_STRIDE_RESULTS = []  # structured oversubscribed-schedule cells

# device-pool sizes every placement run must cover — module-level so the
# CI regression gate (benchmarks/check_smoke.py) can assert coverage
PLACEMENT_DEVICES = (1, 2, 4, 8)

# chain depths every graph_replay run must cover — module-level so the
# CI regression gate (benchmarks/check_smoke.py) can assert coverage
GRAPH_DEPTHS = (1, 4, 16)

# backend_sweep kernel picks — module-level so the CI regression gate
# (benchmarks/check_smoke.py) can assert the smoke run covered them
SWEEP_SMOKE_PICKS = ("MatrixMulCUDA", "matrixMul1D", "transpose",
                     "warpPrefixStats", "blockCounter", "gridReduce")
SWEEP_FULL_PICKS = ("vectorAdd", "MatrixMulCUDA", "matrixMul1D",
                    "transpose", "stencil2d", "reduce0", "reduce4",
                    "histogram64", "blockCounter", "saxpyHeavy",
                    "warpPrefixStats", "gridReduce")

# autotune kernel picks — module-level so the CI regression gate can
# assert the committed baseline covered them (a mix of chunk-sensitive
# vmap kernels and warp-batched candidates)
AUTOTUNE_PICKS = ("MatrixMulCUDA", "transpose", "warpPrefixStats",
                  "saxpyHeavy")

# grid_stride kernels and oversubscribed grid sizes every run must
# cover — module-level so the CI regression gate can assert coverage;
# the smoke run covers the first grid only (the quarter-million-block
# clamped cells need full timing iterations to be worth recording)
STRIDE_KERNELS = ("strideSaxpy", "strideHist")
STRIDE_GRIDS = (1 << 16, 1 << 18)
STRIDE_SMOKE_GRIDS = (1 << 16,)


def _time_call(fn, *args, warmup=None, iters=None):
    for _ in range(WARMUP if warmup is None else warmup):
        fn(*args)
    ts = []
    for _ in range(ITERS if iters is None else iters):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6  # µs


def _block(out):
    import jax
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    RESULTS.append({"name": name, "us": round(us, 1), "derived": derived})


def _total(dim) -> int:
    """Linear size of an ``int | (x, y[, z])`` dim3 geometry."""
    return dim if isinstance(dim, int) else int(np.prod(dim))


# ---------------------------------------------------------------------------


def coverage():
    """Table 1: which kernels each collapsing strategy supports."""
    from benchmarks.kernels_suite import KERNELS
    from repro.core.oracle import run_grid as oracle_run
    n_flat = n_hier = n_total = 0
    for sk in KERNELS:
        n_total += 1
        if sk.kernel is None:
            _row(f"coverage.{sk.name}", 0.0,
                 f"features={sk.features};flat=no;cox=no;"
                 f"reason={sk.unsupported_reason[:40]}")
            continue
        flat_ok = supports_flat(sk.kernel.ir)
        args = sk.make_args()
        t0 = time.perf_counter()
        try:
            out = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                                   collapse="hier")
            hier_ok = True
            # verify against the per-thread oracle
            ref = oracle_run(sk.kernel.ir, grid=sk.grid, block=sk.block,
                             args=args)
            for k in ref:
                got = np.asarray(out[k], np.float32)
                want = np.asarray(ref[k], np.float32)
                assert np.allclose(got, want, rtol=1e-4, atol=1e-4), \
                    f"{sk.name}.{k} mismatch"
            if sk.check is not None:
                assert sk.check(out), f"{sk.name} check failed"
        except CoxUnsupported:
            hier_ok = False
        us = (time.perf_counter() - t0) * 1e6
        n_flat += flat_ok
        n_hier += hier_ok
        _row(f"coverage.{sk.name}", us,
             f"features={sk.features or 'none'};"
             f"flat={'yes' if flat_ok else 'no'};"
             f"cox={'yes' if hier_ok else 'no'}")
    _row("coverage.TOTAL", 0.0,
         f"flat={n_flat}/{n_total}({100*n_flat//n_total}%);"
         f"cox={n_hier}/{n_total}({100*n_hier//n_total}%);"
         f"paper: POCL 39%, DPCT 68%, COX 90%")


# ---------------------------------------------------------------------------


def flat_vs_hier():
    """Fig. 12: hierarchical-collapsing overhead on warp-free kernels."""
    from benchmarks.kernels_suite import KERNELS
    picks = ["vectorAdd", "MatrixMulCUDA", "reduce0"]
    ratios = []
    for sk in KERNELS:
        if sk.name not in picks:
            continue
        args = sk.make_args()

        def run(mode):
            return sk.kernel.launch(grid=sk.grid, block=sk.block,
                                    args=args, collapse=mode)

        us_flat = _time_call(lambda: run("flat"))
        us_hier = _time_call(lambda: run("hier"))
        ratios.append(us_hier / us_flat)
        _row(f"flat_vs_hier.{sk.name}", us_hier,
             f"flat_us={us_flat:.1f};overhead={us_hier / us_flat:.2f}x")
    _row("flat_vs_hier.MEAN", 0.0,
         f"overhead={statistics.mean(ratios):.2f}x;paper=1.13x")


# ---------------------------------------------------------------------------


def simd_vote():
    """Table 2: vote_all / vote_any with SIMD (lane-vector) vs scalar
    (per-lane loop) collective implementations.

    Two granularities: the whole kernel launch (includes grid machinery,
    like the paper's timing) and the collective function itself in
    isolation (the paper's instruction-count story)."""
    import jax
    import jax.numpy as jnp
    from repro.core import collectives as C
    from benchmarks.kernels_suite import KERNELS

    for nm in ("VoteAllKernel2", "VoteAnyKernel1"):
        sk = next(k for k in KERNELS if k.name == nm)
        args = sk.make_args()

        def run(simd):
            return sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                                    simd=simd, collapse="hier")

        us_simd = _time_call(lambda: run(True))
        us_scalar = _time_call(lambda: run(False))
        _row(f"simd_vote.{nm}", us_simd,
             f"scalar_us={us_scalar:.1f};"
             f"speedup={us_scalar / us_simd:.2f}x;paper=10x")

    # micro: the collective alone, 8192 warps at once
    buf = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, (8192, 32)).astype(bool))
    for fname in ("vote_all", "vote_any"):
        fv = jax.jit(jax.vmap(lambda b: C.VECTORIZED[fname](b, W=32)))
        fs = jax.jit(jax.vmap(lambda b: C.SCALAR[fname](b, W=32)))
        us_v = _time_call(lambda: fv(buf))
        us_s = _time_call(lambda: fs(buf))
        _row(f"simd_vote.micro_{fname}", us_v,
             f"scalar_us={us_s:.1f};speedup={us_s / us_v:.2f}x;paper=10x")


# ---------------------------------------------------------------------------


def jit_mode():
    """Fig. 13: JIT mode (block size burned in, loops unrolled) vs
    normal mode (fori inter-warp loop)."""
    from benchmarks.kernels_suite import KERNELS
    for nm in ("vectorAdd", "MatrixMulCUDA", "reduce4"):
        sk = next(k for k in KERNELS if k.name == nm)
        args = sk.make_args()

        def run(mode):
            return sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                                    mode=mode, collapse="hier")

        us_jit = _time_call(lambda: run("jit"))
        us_normal = _time_call(lambda: run("normal"))
        _row(f"jit_mode.{nm}", us_jit,
             f"normal_us={us_normal:.1f};"
             f"jit_speedup={us_normal / us_jit:.2f}x")


# ---------------------------------------------------------------------------


def backend_sweep():
    """Grid-execution backend × warp-execution axis: the same kernels
    through every (backend, warp_exec) cell, equal outputs asserted,
    median call time per cell.  The vmap column is the block-parallel
    payoff (paper §4's pthread-per-block, recast as a chunked jax.vmap);
    the batched-warp column is the same trick one level down — the
    inter-warp loop vectorized into one (n_warps, W) lane plane."""
    import jax
    from benchmarks.kernels_suite import all_kernels

    backends = ["scan", "vmap"]
    mesh = None
    if not SMOKE and len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        backends.append("sharded")

    picks = SWEEP_SMOKE_PICKS if SMOKE else SWEEP_FULL_PICKS
    for sk in all_kernels():
        if sk.name not in picks:
            continue
        args = sk.make_args()
        n_warps = -(-_total(sk.block) // 32)

        def run(backend, warp_exec="serial", simd=True):
            kw = {"mesh": mesh} if backend == "sharded" else {}
            return sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                                    backend=backend, warp_exec=warp_exec,
                                    simd=simd, **kw)

        # what the all-auto heuristics resolve to — recorded so the CI
        # gate can flag an autotune pick that lands on the slowest
        # measured cell (make_request resolves eagerly, no dispatch)
        rl_auto = sk.kernel.make_request(grid=sk.grid, block=sk.block,
                                         args=args).rl
        auto_cell = f"{rl_auto.backend}_{rl_auto.warp_exec}"
        auto_chunk = rl_auto.chunk

        base = run("scan")
        times = {}
        cells = [(b, we, True) for b in backends
                 for we in ("serial", "batched")]
        if sk.kernel.uses_warp_features():
            # Table-2's w/o-AVX baseline × warp execution: the scalar
            # collectives' per-lane loops are non-fusable op chains, so
            # the batched plane divides their instance count by n_warps
            cells += [("scan", we, False) for we in ("serial", "batched")]
        for b, we, simd in cells:
            out = run(b, we, simd)
            for k in base:
                np.testing.assert_array_equal(
                    np.asarray(out[k]), np.asarray(base[k]),
                    err_msg=f"{sk.name}.{k}: {b}/{we}/simd={simd} "
                            f"!= scan/serial")
            cell = f"{b}_{we}" + ("" if simd else "_noavx")
            times[cell] = _time_call(
                lambda b=b, we=we, simd=simd: run(b, we, simd))
        derived = ";".join(f"{c}_us={t:.1f}" for c, t in times.items())
        wb = times["scan_serial"] / times["scan_batched"]
        derived += f";vmap_speedup={times['scan_serial'] / times['vmap_serial']:.2f}x"
        derived += f";warp_batch_speedup={wb:.2f}x"
        derived += f";auto_cell={auto_cell}"
        entry = {
            "kernel": sk.name, "grid": sk.grid, "block": sk.block,
            "n_warps": n_warps, "features": sk.features or "none",
            "auto_cell": auto_cell,
            "auto_chunk": auto_chunk,
            "chunk_source": rl_auto.chunk_source,
            "auto_schedule": rl_auto.schedule,
            "schedule_source": rl_auto.schedule_source,
            "auto_n_resident": rl_auto.n_resident,
            "times_us": {c: round(t, 1) for c, t in times.items()},
            "warp_batch_speedup_scan": round(wb, 2),
            "warp_batch_speedup_vmap": round(
                times["vmap_serial"] / times["vmap_batched"], 2),
        }
        if "scan_serial_noavx" in times:
            entry["warp_batch_speedup_scan_noavx"] = round(
                times["scan_serial_noavx"] / times["scan_batched_noavx"], 2)
            derived += (f";warp_batch_noavx_speedup="
                        f"{entry['warp_batch_speedup_scan_noavx']:.2f}x")
        _row(f"backend_sweep.{sk.name}", times["vmap_batched"], derived)
        SWEEP_RESULTS.append(entry)

    # dim3 overhead check: the natural 2-D matrixMul vs the hand-
    # flattened 1-D port of the same kernel (acceptance: within 10%)
    by_name = {e["kernel"]: e for e in SWEEP_RESULTS}
    mm2, mm1 = by_name.get("MatrixMulCUDA"), by_name.get("matrixMul1D")
    if mm2 and mm1:
        ratios = {c: mm2["times_us"][c] / mm1["times_us"][c]
                  for c in mm2["times_us"] if c in mm1["times_us"]}
        _row("backend_sweep.matmul_2d_vs_1d", 0.0,
             ";".join(f"{c}_ratio={r:.2f}x" for c, r in ratios.items()))


# ---------------------------------------------------------------------------


def streams():
    """Async streams: two independent memory-bound kernels (saxpy and a
    scale — streaming stores, ~zero arithmetic intensity) issued on two
    ``cox.Stream``\\ s (enqueue both, synchronize after) vs serial issue
    (launch + synchronize each, the pre-stream ``KernelFn.launch``
    discipline).  Outputs are asserted bitwise-equal first — any legal
    stream schedule must match serial issue.  On a single XLA device the
    win is host/device pipelining: while kernel A executes, the host
    binds and dispatches B (and materializes A's result), exactly CUDA's
    copy/compute-overlap story.  ``depth`` is the per-stream in-order
    pipeline length (pairs in flight before the sync) — deeper queues
    amortize more host work, so the ratio grows with depth."""
    import gc
    from repro.core import cox

    @cox.kernel
    def streamSaxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
                    y: cox.Array(cox.f32), n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = 2.5 * x[i] + y[i]

    @cox.kernel
    def streamScale(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
                    n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = x[i] * 3.0 + 1.0

    grid, block = 32, 256
    n = grid * block
    x = np.arange(n, dtype=np.float32) / n
    y = np.ones(n, np.float32)
    o = np.zeros(n, np.float32)
    a1, a2 = (o, x, y, n), (o, x, n)
    s1, s2 = cox.Stream("bench-s1"), cox.Stream("bench-s2")

    def serial(depth):
        outs = []
        for _ in range(depth):
            r1 = streamSaxpy.launch(grid=grid, block=block, args=a1)
            outs.append(np.asarray(r1["out"]))
            r2 = streamScale.launch(grid=grid, block=block, args=a2)
            outs.append(np.asarray(r2["out"]))
        return outs

    def streamed(depth):
        hs = []
        for _ in range(depth):
            hs.append(s1.launch(streamSaxpy, grid=grid, block=block,
                                args=a1))
            hs.append(s2.launch(streamScale, grid=grid, block=block,
                                args=a2))
        return [np.asarray(h.result()["out"]) for h in hs]

    # bitwise: any legal stream schedule == serial issue
    for got, want in zip(streamed(2), serial(2)):
        np.testing.assert_array_equal(got, want)

    # medians need many alternated samples: the pair runs in ~2.5 ms, so
    # scheduler jitter on a shared host is a large fraction of one trial
    iters = 1 if SMOKE else max(ITERS * 12, 120)
    gc.disable()
    try:
        for depth in (1, 2, 4):
            ts, to = [], []
            for _ in range(iters):
                t0 = time.perf_counter()
                serial(depth)
                ts.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                streamed(depth)
                to.append(time.perf_counter() - t0)
            serial_us = statistics.median(ts) * 1e6
            stream_us = statistics.median(to) * 1e6
            ratio = serial_us / stream_us
            _row(f"streams.pair_depth{depth}", stream_us,
                 f"serial_us={serial_us:.1f};overlap={ratio:.2f}x;"
                 f"kernels=streamSaxpy+streamScale;n={n}")
            STREAM_RESULTS.append({
                "pair": "streamSaxpy+streamScale", "depth": depth,
                "grid": grid, "block": block, "n": n,
                "serial_us": round(serial_us, 1),
                "stream_us": round(stream_us, 1),
                "overlap_x": round(ratio, 2),
            })
    finally:
        gc.enable()


# ---------------------------------------------------------------------------


def graph_replay():
    """CUDA graphs: a depth-d chain of *dependent* saxpy launches (one
    token's worth of pipeline work) dispatched eagerly — d per-launch
    bind/stage/dispatch round-trips through the stream — vs captured
    once into a ``cox.Graph`` and **replayed** per token with the
    carried input rebound (``replay(x=...)``).  Replay is one staged
    XLA call regardless of depth (XLA fused across the launch
    boundaries at instantiate), so the win grows with chain depth —
    the ``cudaGraphLaunch`` story.  Bitwise equality of replay vs
    eager is asserted on carried state before any timing."""
    import gc
    from repro.core import cox

    @cox.kernel
    def graphStep(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
                  y: cox.Array(cox.f32), n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = 0.5 * x[i] + y[i]

    grid, block = 32, 256
    n = grid * block
    x0 = np.arange(n, dtype=np.float32) / n
    y = np.ones(n, np.float32)
    o = np.zeros(n, np.float32)
    s = cox.Stream("bench-graph")

    def chain(depth, x):
        h = s.launch(graphStep, grid=grid, block=block, args=(o, x, y, n))
        for _ in range(depth - 1):
            h = s.launch(graphStep, grid=grid, block=block,
                         args=(o, h.outputs["out"], y, n))
        return h

    # medians need many alternated samples (same rationale as streams)
    iters = 1 if SMOKE else max(ITERS * 12, 120)
    for depth in GRAPH_DEPTHS:
        g = cox.Graph(name=f"bench-chain{depth}")
        with g.capture(s):
            chain(depth, x0)
        exe = g.instantiate()

        def eager(x, depth=depth):
            return np.asarray(chain(depth, x).result()["out"])

        def replay(x, exe=exe):
            return np.asarray(exe.replay(x=x)["out"])

        # bitwise: replayed graph == eager launches, carried three deep
        xe, xg = x0, x0
        for _ in range(3):
            xe, xg = eager(xe), replay(xg)
            np.testing.assert_array_equal(xg, xe)

        gc.disable()
        try:
            te, tg = [], []
            for _ in range(iters):
                t0 = time.perf_counter()
                eager(x0)
                te.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                replay(x0)
                tg.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        eager_us = statistics.median(te) * 1e6
        replay_us = statistics.median(tg) * 1e6
        ratio = eager_us / replay_us
        _row(f"graph_replay.chain_depth{depth}", replay_us,
             f"eager_us={eager_us:.1f};speedup={ratio:.2f}x;"
             f"kernel=graphStep;n={n}")
        GRAPH_RESULTS.append({
            "kernel": "graphStep", "depth": depth, "grid": grid,
            "block": block, "n": n,
            "eager_us": round(eager_us, 1),
            "replay_us": round(replay_us, 1),
            "speedup_x": round(ratio, 2),
        })


# ---------------------------------------------------------------------------


def placement():
    """Multi-device stream placement: the same 4-stream program over
    1/2/4/8-device pools (8-dev subprocess — the device count must be
    set before jax initializes).  The worker asserts bitwise equality
    against the 1-device pool and reports the throughput ratio; each
    entry records the host's core count because XLA host devices
    time-share physical cores (the scaling gate is cpus-conditional)."""
    worker = os.path.join(os.path.dirname(__file__), "placement_worker.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    iters = 3 if SMOKE else max(ITERS, 5)
    r = subprocess.run([sys.executable, worker, "--iters", str(iters)],
                       capture_output=True, text=True, env=env, timeout=1200)
    for line in r.stdout.splitlines():
        if line.startswith("PLACEMENT_JSON "):
            PLACEMENT_RESULTS.extend(
                json.loads(line[len("PLACEMENT_JSON "):]))
            continue
        # re-emit through _row so the worker's rows reach --json too
        parts = line.split(",", 2)
        if len(parts) == 3:
            try:
                _row(parts[0], float(parts[1]), parts[2])
                continue
            except ValueError:
                pass
        print(line, flush=True)
    if r.returncode != 0:
        _row("placement.FAILED", 0.0, r.stderr[-200:].replace("\n", ";"))


# ---------------------------------------------------------------------------


def autotune():
    """Measured knob tuning (repro.core.autotune) vs the hand
    heuristics.  Per pick kernel: launch with the heuristic knobs, then
    with ``autotune=True`` against a fresh cache (cold pass — the
    candidate grid is measured and the winner persisted), assert the
    tuned outputs bitwise-equal the heuristic ones, then time both
    picks.  A warm re-resolve in the same process must hit the cache
    with zero new measurement launches — counter-asserted here, and
    again across processes by the CI autotune job.  Each cell records
    the cost model's op/mem estimates and the achieved GFLOPS so
    check_smoke.py can gate estimate accuracy and never-slower."""
    import tempfile
    from benchmarks.kernels_suite import all_kernels
    from repro.core import autotune as at
    from repro.core import costmodel

    tmp = tempfile.mkdtemp(prefix="cox-autotune-bench-")
    cache_file = os.path.join(tmp, "autotune.json")
    prev = os.environ.get(at.ENV_CACHE)
    os.environ[at.ENV_CACHE] = cache_file
    at.reset()
    try:
        for sk in all_kernels():
            if sk.name not in AUTOTUNE_PICKS:
                continue
            args = sk.make_args()

            def run(tune):
                return sk.kernel.launch(grid=sk.grid, block=sk.block,
                                        args=args, autotune=tune)

            req = sk.kernel.make_request(grid=sk.grid, block=sk.block,
                                         args=args)
            heur_rl = req.rl
            heur_cell = (f"{heur_rl.backend}_{heur_rl.warp_exec}"
                         f"_c{heur_rl.chunk}")
            base = run(False)
            tuned_out = run(True)       # cold: measures candidate cells
            for k in base:
                np.testing.assert_array_equal(
                    np.asarray(tuned_out[k]), np.asarray(base[k]),
                    err_msg=f"{sk.name}.{k}: tuned != heuristic")
            m_cold = at.stats()["measurements"]
            req_t = sk.kernel.make_request(grid=sk.grid, block=sk.block,
                                           args=args, autotune=True)
            assert at.stats()["measurements"] == m_cold, \
                f"{sk.name}: warm re-resolve issued measurement launches"
            tuned_rl = req_t.rl
            tuned_cell = (f"{tuned_rl.backend}_{tuned_rl.warp_exec}"
                          f"_c{tuned_rl.chunk}")
            heur_us = _time_call(lambda: run(False))
            tuned_us = _time_call(lambda: run(True))
            rec = next((r for k, r in at.entries().items()
                        if k.startswith(sk.name + "|")), {})
            est = costmodel.estimate(req.ck, tuned_rl, req.shapes,
                                     mode="xla")
            gflops = est.op_estimate / tuned_us / 1e3  # us -> GFLOPS
            ratio = heur_us / tuned_us
            _row(f"autotune.{sk.name}", tuned_us,
                 f"heur_us={heur_us:.1f};heur={heur_cell};"
                 f"tuned={tuned_cell};speedup={ratio:.2f}x;"
                 f"gflops={gflops:.3f}")
            AUTOTUNE_RESULTS.append({
                "kernel": sk.name, "grid": sk.grid, "block": sk.block,
                "heur_cell": heur_cell, "tuned_cell": tuned_cell,
                "heur_us": round(heur_us, 1),
                "tuned_us": round(tuned_us, 1),
                "speedup_x": round(ratio, 2),
                "op_estimate": est.op_estimate,
                "mem_estimate": est.mem_estimate,
                "estimate_source": est.source,
                "gflops": round(gflops, 4),
                "chunk_source": tuned_rl.chunk_source,
                # the tuner's own per-candidate measurements (µs) — the
                # chunk-mispick gate reads these cells
                "candidate_times_us": rec.get("times_us", {}),
            })
        st = at.stats()
        _row("autotune.STATS", 0.0,
             f"misses={st['misses']};hits={st['hits']};"
             f"measurements={st['measurements']};"
             f"disk_writes={st['disk_writes']}")
        assert os.path.exists(cache_file), "autotune cache never written"
    finally:
        if prev is None:
            os.environ.pop(at.ENV_CACHE, None)
        else:
            os.environ[at.ENV_CACHE] = prev


# ---------------------------------------------------------------------------


def grid_stride():
    """Grid-stride lowering on oversubscribed grids: a fixed wave of
    resident block slots loops over strided block ids instead of the
    host materializing an O(grid) chunk table.  Per (kernel, grid)
    three cells, all launched over the same small bound working set so
    the *schedule machinery* dominates the wall time:

    * ``chunked8`` — the unconstrained chunk-table walk at the default
      wave width (``chunk=8``), the pre-budget baseline;
    * ``clamp1``   — the clamped-chunk fallback the autotuner used to
      take when no chunk fit the footprint budget (``chunk=1``: one
      merge pass per *block*, grid of them — the failure mode the
      stride schedule replaces);
    * ``stride``   — all knobs on auto under a forced-small
      ``COX_FOOTPRINT_BUDGET`` (the satellite env override): the cost
      model must route to grid-stride on its own, and the resolved
      provenance is recorded for the CI gate.

    Bitwise equality across all three cells is asserted before any
    timing; ``benchmarks/check_smoke.py`` gates the committed baseline
    on stride never losing to clamp and beating it >= 1.3x on at least
    one kernel."""
    from repro.core import costmodel

    @cox.kernel
    def strideSaxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
                    y: cox.Array(cox.f32), n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = 2.5 * x[i] + y[i]

    @cox.kernel
    def strideHist(c, hist: cox.Array(cox.f32), data: cox.Array(cox.i32),
                   n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            c.atomic_add(hist, data[i], 1.0)

    # deliberately tiny blocks + working set: per-block execution cost
    # on XLA-CPU is scatter-bound and schedule-invariant, so the wave
    # loop's fixed overhead — the term grid-stride amortizes over
    # n_resident slots — only shows when blocks are cheap
    block, n = 8, 256
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    data = rng.integers(0, 64, size=n).astype(np.int32)
    cases = {
        "strideSaxpy": (strideSaxpy, "out",
                        (np.zeros(n, np.float32), x, y, np.int32(n))),
        "strideHist": (strideHist, "hist",
                       (np.zeros(64, np.float32), data, np.int32(n))),
    }
    grids = STRIDE_SMOKE_GRIDS if SMOKE else STRIDE_GRIDS
    # any chunk's bid table is >= 256 KiB at 64k blocks, so a 128 KiB
    # budget forces the stride verdict on the bench-sized working set
    budget = 128 << 10
    prev = os.environ.get(costmodel.ENV_BUDGET)
    os.environ[costmodel.ENV_BUDGET] = str(budget)
    try:
        for name in STRIDE_KERNELS:
            kf, key, args = cases[name]
            for grid in grids:

                def run(grid=grid, kf=kf, args=args, **kw):
                    return kf.launch(grid=grid, block=block, args=args,
                                     backend="vmap", **kw)

                rl = kf.make_request(grid=grid, block=block, args=args,
                                     backend="vmap").rl
                assert rl.schedule == "grid_stride", \
                    f"{name} g{grid}: verdict stayed {rl.schedule!r} " \
                    f"under a {budget}-byte budget"
                out_c8 = run(chunk=8)
                out_c1 = run(chunk=1)
                out_gs = run()
                for tag, out in (("clamp1", out_c1), ("stride", out_gs)):
                    np.testing.assert_array_equal(
                        np.asarray(out[key]), np.asarray(out_c8[key]),
                        err_msg=f"{name} g{grid}: {tag} != chunked8")
                times = {
                    "chunked8_us": _time_call(lambda run=run: run(chunk=8)),
                    "clamp1_us": _time_call(lambda run=run: run(chunk=1)),
                    "stride_us": _time_call(lambda run=run: run()),
                }
                vs_clamp = times["clamp1_us"] / times["stride_us"]
                vs_c8 = times["chunked8_us"] / times["stride_us"]
                _row(f"grid_stride.{name}_g{grid}", times["stride_us"],
                     f"chunked8_us={times['chunked8_us']:.1f};"
                     f"clamp1_us={times['clamp1_us']:.1f};"
                     f"stride_vs_clamp={vs_clamp:.2f}x;"
                     f"stride_vs_chunked={vs_c8:.2f}x;"
                     f"n_resident={rl.n_resident};"
                     f"source={rl.schedule_source};budget={budget}")
                GRID_STRIDE_RESULTS.append({
                    "kernel": name, "grid": grid, "block": block, "n": n,
                    "budget": budget,
                    "schedule": rl.schedule,
                    "schedule_source": rl.schedule_source,
                    "n_resident": rl.n_resident,
                    "chunked8_us": round(times["chunked8_us"], 1),
                    "clamp1_us": round(times["clamp1_us"], 1),
                    "stride_us": round(times["stride_us"], 1),
                    "stride_vs_clamp_x": round(vs_clamp, 2),
                    "stride_vs_chunked_x": round(vs_c8, 2),
                })
    finally:
        if prev is None:
            os.environ.pop(costmodel.ENV_BUDGET, None)
        else:
            os.environ[costmodel.ENV_BUDGET] = prev


# ---------------------------------------------------------------------------


def scalability():
    """Fig. 14: multi-block kernels across host devices (8-dev subprocess
    — device count must be set before jax initializes)."""
    worker = os.path.join(os.path.dirname(__file__), "scalability_worker.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, worker], capture_output=True,
                       text=True, env=env, timeout=1200)
    for line in r.stdout.splitlines():
        # re-emit through _row so the worker's rows reach --json too
        parts = line.split(",", 2)
        if len(parts) == 3:
            try:
                _row(parts[0], float(parts[1]), parts[2])
                continue
            except ValueError:
                pass
        print(line, flush=True)
    if r.returncode != 0:
        _row("scalability.FAILED", 0.0, r.stderr[-200:].replace("\n", ";"))


# ---------------------------------------------------------------------------


def roofline():
    """§Roofline: three terms per dry-run cell (prefers the corrected
    single-pod baseline, falls back to the multi-pod record)."""
    base = os.path.join(os.path.dirname(__file__), "..", "results")
    for name in ("roofline_base.json", "dryrun_all.json"):
        path = os.path.join(base, name)
        if os.path.exists(path):
            from benchmarks.roofline import emit_rows
            emit_rows(path)
            return
    _row("roofline.SKIPPED", 0.0, "run repro.launch.dryrun --all first")


SECTIONS = {
    "coverage": coverage,
    "flat_vs_hier": flat_vs_hier,
    "simd_vote": simd_vote,
    "jit_mode": jit_mode,
    "backend_sweep": backend_sweep,
    "streams": streams,
    "graph_replay": graph_replay,
    "placement": placement,
    "autotune": autotune,
    "grid_stride": grid_stride,
    "scalability": scalability,
    "roofline": roofline,
}


def main(argv=None) -> None:
    global WARMUP, ITERS, SMOKE
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--json", nargs="?", const="BENCH_PR10.json", default=None,
                   metavar="PATH",
                   help="write machine-readable results (default path "
                        "BENCH_PR10.json when the flag is given bare)")
    p.add_argument("--sections", default=None,
                   help=f"comma-separated subset of {sorted(SECTIONS)}")
    p.add_argument("--smoke", action="store_true",
                   help="CI smoke: 1 timing iteration, flagship kernels "
                        "only (pair with --sections backend_sweep)")
    args = p.parse_args(argv)
    if args.smoke:
        WARMUP, ITERS, SMOKE = 1, 1, True
    names = (list(SECTIONS) if args.sections is None
             else [s.strip() for s in args.sections.split(",") if s.strip()])
    for name in names:
        if name not in SECTIONS:
            p.error(f"unknown section {name!r}; available: {sorted(SECTIONS)}")
    for name in names:
        SECTIONS[name]()
    if args.json:
        from benchmarks import roofline as _roofline
        from repro.core import autotune as _at
        payload = {
            "schema": "cox-bench-v5",
            "smoke": SMOKE,
            "iters": ITERS,
            "sections": names,
            "rows": RESULTS,
            "backend_sweep": SWEEP_RESULTS,
            "streams": STREAM_RESULTS,
            "graph_replay": GRAPH_RESULTS,
            "placement": PLACEMENT_RESULTS,
            "autotune": AUTOTUNE_RESULTS,
            "grid_stride": GRID_STRIDE_RESULTS,
            "autotune_stats": _at.stats(),
            # live per-stage-key counters from the dispatcher, placed on
            # the host roofline (estimates vs CPU peaks); rows carrying
            # measured wall time also report the attained roof fraction
            "telemetry": _roofline.from_telemetry(
                cox.get_dispatcher().telemetry()),
            # fault-tolerance counters for the whole run: a clean bench
            # must never have taken a degradation-ladder rung (a rung
            # means the timed configuration is not the resolved one)
            "dispatch_health": cox.get_dispatcher().health(),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json} ({len(RESULTS)} rows, "
              f"{len(SWEEP_RESULTS)} sweep entries)", flush=True)


if __name__ == "__main__":
    main()
