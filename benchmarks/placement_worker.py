"""Multi-device stream placement worker: N streams over 1/2/4/8 devices.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent harness before jax initializes).  Four streams issue independent
compute-heavy launches; a private :class:`~repro.core.streams.Dispatcher`
with a ``devices=`` pool of size k round-robins the streams over k XLA
devices, so the same four-stream program measures 1-device pipelining vs
true k-way device concurrency.  Outputs are asserted bitwise-equal to
the 1-device pool before any timing — placement must never change
results.

Emits ``name,us,derived`` CSV rows plus one ``PLACEMENT_JSON [...]``
line the parent parses into the benchmark JSON payload.  Each entry
records ``cpus`` (os.cpu_count()) because k XLA host devices time-share
the physical cores: wall-clock scaling is only observable when the host
actually has >= k cores, and the CI gate (benchmarks/check_smoke.py)
conditions its scaling floor on that field.
"""
import argparse
import json
import os
import statistics
import time

import jax
import numpy as np

from repro.core import cox
from repro.core.streams import Dispatcher
from repro.launch.mesh import device_pool

POOL_SIZES = (1, 2, 4, 8)
N_STREAMS = 4
DEPTH = 2  # launches in flight per stream before the sync


@cox.kernel
def placeFma(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
             b: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        acc = a[i]
        for t in range(128):  # compute-bound: device work dominates host
            acc = acc * 0.9995 + b[i]
        out[i] = acc


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--iters", type=int, default=5)
    args_ns = p.parse_args()
    iters = max(args_ns.iters, 3)  # a 1-iter ratio is pure noise

    ndev = len(jax.devices())
    grid, block = 32, 256
    n = grid * block
    rng = np.random.default_rng(7)
    # independent per-stream inputs so the streams share no data edges
    per_stream = [(np.zeros(n, np.float32),
                   rng.normal(size=n).astype(np.float32),
                   rng.normal(size=n).astype(np.float32), n)
                  for _ in range(N_STREAMS)]

    cpus = os.cpu_count() or 1
    results = []
    ref_outs = None
    base_us = None
    for k in POOL_SIZES:
        if k > ndev:
            break
        disp = Dispatcher(devices=device_pool(k))
        streams = [cox.Stream(f"place-s{i}", dispatcher=disp)
                   for i in range(N_STREAMS)]

        def run_once():
            hs = []
            for _ in range(DEPTH):
                for st, a in zip(streams, per_stream):
                    hs.append(st.launch(placeFma, grid=grid, block=block,
                                        args=a))
            return [np.asarray(h.result()["out"]) for h in hs]

        outs = run_once()  # warmup (stage per device) + correctness run
        if ref_outs is None:
            ref_outs = outs
        for got, want in zip(outs, ref_outs):
            np.testing.assert_array_equal(
                got, want, err_msg=f"pool={k}: placed != 1-device")

        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            run_once()
            ts.append(time.perf_counter() - t0)
        us = statistics.median(ts) * 1e6
        if base_us is None:
            base_us = us
        used = sorted(d for d, c in disp.device_health().items()
                      if c.get("dispatches", 0) > 0)
        throughput_x = base_us / us
        print(f"placement.devices_{k},{us:.1f},"
              f"streams={N_STREAMS};depth={DEPTH};"
              f"throughput_x={throughput_x:.2f};"
              f"devices_used={len(used)};cpus={cpus};bitwise=yes",
              flush=True)
        results.append({
            "devices": k, "streams": N_STREAMS, "depth": DEPTH,
            "grid": grid, "block": block, "n": n,
            "us": round(us, 1),
            "throughput_x": round(throughput_x, 2),
            "devices_used": len(used),
            "cpus": cpus,
            "bitwise_equal": True,
        })
    if cpus < max(r["devices"] for r in results):
        print("placement.NOTE,0.0,host has fewer physical cores than the "
              "device pool - the XLA host devices time-share them so "
              "wall-clock scaling is bounded by cpus; placement/equality "
              "correctness still asserted (CI runners have >= 4 cores)",
              flush=True)
    print("PLACEMENT_JSON " + json.dumps(results), flush=True)


if __name__ == "__main__":
    main()
