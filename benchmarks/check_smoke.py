"""CI regression gate for the bench-smoke job.

The backend_sweep section of ``benchmarks/run.py`` asserts every
(backend, warp_exec[, simd]) cell's output equals scan/serial in-process,
so a broken executor path crashes the run.  This gate closes the
remaining hole — a sweep that silently *covered less than it used to* —
by diffing the smoke output against the committed baseline
(``BENCH_PR3.json``) structurally:

* both files carry the same schema tag;
* every smoke-pick kernel produced a sweep entry (none skipped or lost
  to an import/registration regression), and those kernels also exist
  in the committed baseline (the perf trajectory stays comparable);
* every entry has the full single-device cell set (scan/vmap ×
  serial/batched, plus the w/o-AVX cells for warp-feature kernels) with
  sane timings;
* the ``streams`` section produced its overlap cells (every pipeline
  depth, sane timings, bitwise equality asserted in-process) in the
  smoke run, and the committed baseline carries the full-run cells —
  including the two-kernel pair's recorded overlap ratio;
* the ``graph_replay`` section produced its capture/replay cells at
  every chain depth (replay-vs-eager bitwise equality asserted
  in-process) in both smoke and baseline, and the committed baseline's
  deepest chain shows replay actually beating per-launch dispatch
  (``speedup_x >= 1.5`` at depth 16) — the tentpole perf claim;
* the smoke run's recorded ``dispatch_health`` is clean: zero
  degradations/retries/timeouts/failures and no sticky error — timed
  cells must be the *resolved* configuration, never a fallback rung.

Usage: ``python benchmarks/check_smoke.py BENCH_SMOKE.json BENCH_PR6.json``
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.run import GRAPH_DEPTHS, SWEEP_SMOKE_PICKS  # noqa: E402

REQUIRED_CELLS = ("scan_serial", "scan_batched", "vmap_serial", "vmap_batched")
NOAVX_CELLS = ("scan_serial_noavx", "scan_batched_noavx")
STREAM_DEPTHS = (1, 2, 4)  # pipeline depths every run must cover
STREAM_FIELDS = ("serial_us", "stream_us", "overlap_x")
GRAPH_FIELDS = ("eager_us", "replay_us", "speedup_x")
GRAPH_MIN_SPEEDUP = 1.5  # baseline deepest-chain replay-vs-eager floor


def fail(msg: str) -> None:
    print(f"check_smoke: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {path}: {e}")
        raise AssertionError  # unreachable


def main(argv: list[str]) -> None:
    if len(argv) != 3:
        fail("usage: check_smoke.py <smoke.json> <baseline.json>")
    smoke, baseline = load(argv[1]), load(argv[2])

    if smoke.get("schema") != baseline.get("schema"):
        fail(
            f"schema mismatch: smoke={smoke.get('schema')!r} "
            f"baseline={baseline.get('schema')!r}"
        )
    if "backend_sweep" not in smoke.get("sections", []):
        fail(f"smoke run missed the backend_sweep section: {smoke.get('sections')}")

    smoke_entries = {e["kernel"]: e for e in smoke.get("backend_sweep", [])}
    base_kernels = {e["kernel"] for e in baseline.get("backend_sweep", [])}

    missing = [k for k in SWEEP_SMOKE_PICKS if k not in smoke_entries]
    if missing:
        fail(f"smoke sweep lost kernels {missing} (present: {sorted(smoke_entries)})")
    gone_from_base = [k for k in SWEEP_SMOKE_PICKS if k not in base_kernels]
    if gone_from_base:
        fail(
            f"kernels {gone_from_base} absent from the committed baseline — "
            f"regenerate BENCH_PR3.json (python benchmarks/run.py "
            f"--sections backend_sweep --json BENCH_PR3.json)"
        )

    row_names = {r["name"] for r in smoke.get("rows", [])}
    for kernel in SWEEP_SMOKE_PICKS:
        entry = smoke_entries[kernel]
        cells = entry.get("times_us", {})
        need = list(REQUIRED_CELLS)
        if any(c in cells for c in NOAVX_CELLS):
            need += list(NOAVX_CELLS)
        for cell in need:
            t = cells.get(cell)
            if not isinstance(t, (int, float)) or t <= 0:
                fail(f"{kernel}: cell {cell!r} missing or non-positive ({t!r})")
        if f"backend_sweep.{kernel}" not in row_names:
            fail(f"{kernel}: CSV row missing from the smoke output")

    check_streams(smoke, baseline, row_names)
    check_graph(smoke, baseline, row_names)
    check_health(smoke)

    print(
        f"check_smoke: OK — {len(SWEEP_SMOKE_PICKS)} kernels × "
        f"{len(REQUIRED_CELLS)}+ cells present; streams cells × "
        f"{len(STREAM_DEPTHS)} depths present; graph_replay cells × "
        f"{len(GRAPH_DEPTHS)} depths present (baseline depth-"
        f"{max(GRAPH_DEPTHS)} speedup ≥ {GRAPH_MIN_SPEEDUP}x); "
        f"equality asserts ran in-process"
    )


def check_streams(smoke: dict, baseline: dict, row_names: set) -> None:
    if "streams" not in smoke.get("sections", []):
        fail(f"smoke run missed the streams section: {smoke.get('sections')}")
    for tag, payload in (("smoke", smoke), ("baseline", baseline)):
        by_depth = {e.get("depth"): e for e in payload.get("streams", [])}
        missing = [d for d in STREAM_DEPTHS if d not in by_depth]
        if missing:
            fail(
                f"{tag}: streams cells missing depths {missing} "
                f"(present: {sorted(by_depth)})"
            )
        for depth in STREAM_DEPTHS:
            entry = by_depth[depth]
            for field in STREAM_FIELDS:
                value = entry.get(field)
                if not isinstance(value, (int, float)) or value <= 0:
                    fail(
                        f"{tag}: streams depth {depth}: field {field!r} "
                        f"missing or non-positive ({value!r})"
                    )
    for depth in STREAM_DEPTHS:
        if f"streams.pair_depth{depth}" not in row_names:
            fail(f"streams.pair_depth{depth}: CSV row missing from smoke output")


def check_graph(smoke: dict, baseline: dict, row_names: set) -> None:
    if "graph_replay" not in smoke.get("sections", []):
        fail(f"smoke run missed the graph_replay section: {smoke.get('sections')}")
    for tag, payload in (("smoke", smoke), ("baseline", baseline)):
        by_depth = {e.get("depth"): e for e in payload.get("graph_replay", [])}
        missing = [d for d in GRAPH_DEPTHS if d not in by_depth]
        if missing:
            fail(
                f"{tag}: graph_replay cells missing depths {missing} "
                f"(present: {sorted(by_depth)})"
            )
        for depth in GRAPH_DEPTHS:
            entry = by_depth[depth]
            for field in GRAPH_FIELDS:
                value = entry.get(field)
                if not isinstance(value, (int, float)) or value <= 0:
                    fail(
                        f"{tag}: graph_replay depth {depth}: field {field!r} "
                        f"missing or non-positive ({value!r})"
                    )
    # the tentpole perf claim, checked on the committed full run (smoke
    # runs 1 iteration — too noisy to gate a ratio on)
    deepest = max(GRAPH_DEPTHS)
    base_deep = {e["depth"]: e for e in baseline["graph_replay"]}[deepest]
    if base_deep["speedup_x"] < GRAPH_MIN_SPEEDUP:
        fail(
            f"baseline graph_replay depth {deepest}: replay speedup "
            f"{base_deep['speedup_x']}x < {GRAPH_MIN_SPEEDUP}x — "
            f"capture/replay no longer beats per-launch dispatch"
        )
    for depth in GRAPH_DEPTHS:
        if f"graph_replay.chain_depth{depth}" not in row_names:
            fail(f"graph_replay.chain_depth{depth}: CSV row missing from smoke")


def check_health(smoke: dict) -> None:
    """A clean bench run must never have leaned on the fault-tolerance
    machinery: a degradation-ladder rung (or a retry/timeout) means the
    timed cell was not the resolved configuration, so the numbers lie.
    Tolerates a baseline written before dispatch_health existed — only
    the fresh smoke run is gated."""
    health = smoke.get("dispatch_health")
    if health is None:
        fail(
            "smoke run carries no dispatch_health (benchmarks/run.py "
            "should record cox.get_dispatcher().health())"
        )
    for key in ("degradations", "retries", "timeouts", "failures"):
        n = health.get(key)
        if n != 0:
            fail(
                f"smoke run is not clean: dispatch_health[{key!r}] == {n!r} "
                f"(expected 0) — the degradation ladder or retry path "
                f"fired during a benchmark"
            )
    if health.get("sticky") is not None:
        fail(f"smoke run ended with a sticky device error: {health['sticky']}")


if __name__ == "__main__":
    main(sys.argv)
