"""CI regression gate for the bench-smoke job.

The backend_sweep section of ``benchmarks/run.py`` asserts every
(backend, warp_exec[, simd]) cell's output equals scan/serial in-process,
so a broken executor path crashes the run.  This gate closes the
remaining hole — a sweep that silently *covered less than it used to* —
by diffing the smoke output against the committed baseline
(``BENCH_PR3.json``) structurally:

* both files carry the same schema tag;
* every smoke-pick kernel produced a sweep entry (none skipped or lost
  to an import/registration regression), and those kernels also exist
  in the committed baseline (the perf trajectory stays comparable);
* every entry has the full single-device cell set (scan/vmap ×
  serial/batched, plus the w/o-AVX cells for warp-feature kernels) with
  sane timings;
* the ``streams`` section produced its overlap cells (every pipeline
  depth, sane timings, bitwise equality asserted in-process) in the
  smoke run, and the committed baseline carries the full-run cells —
  including the two-kernel pair's recorded overlap ratio;
* the ``graph_replay`` section produced its capture/replay cells at
  every chain depth (replay-vs-eager bitwise equality asserted
  in-process) in both smoke and baseline, and the committed baseline's
  deepest chain shows replay actually beating per-launch dispatch
  (``speedup_x >= 1.5`` at depth 16) — the tentpole perf claim;
* the smoke run's recorded ``dispatch_health`` is clean: zero
  degradations/retries/timeouts/failures and no sticky error — timed
  cells must be the *resolved* configuration, never a fallback rung;
* the ``placement`` section produced its multi-device cells (every pool
  size, bitwise equality vs the 1-device pool asserted in-process) in
  both smoke and baseline, and — on hosts with >= 4 physical cores,
  recorded per-entry as ``cpus`` because XLA host devices time-share
  cores — 4 streams over a 4-device pool sustain >= 1.6x the 1-device
  throughput (``PLACEMENT_MIN_SCALING``), the tentpole perf claim;
* every committed-baseline sweep entry records the all-auto heuristics'
  resolved cell (``auto_cell``) *and chunk* (``auto_chunk`` +
  ``chunk_source``), and the cell pick never lands on the slowest
  measured cell when the cells are separated by more than measurement
  noise (``AUTOTUNE_NOISE_X``);
* the ``grid_stride`` section produced its oversubscribed cells (smoke
  grids in the smoke run, every gate grid in the committed baseline)
  with the cost model routing to ``grid_stride`` on its own
  (``schedule_source == 'heuristic'``), stride-vs-chunked bitwise
  equality asserted in-process, and on the committed baseline the
  stride schedule never loses to the clamped-chunk fallback beyond
  noise *and* beats it by ``>= GRID_STRIDE_MIN_SPEEDUP`` on at least
  one kernel — the tentpole perf claim of the grid-stride lowering;
* the ``autotune`` section produced a cell per pick kernel in both runs
  (tuned-vs-heuristic bitwise equality and the zero-measurement warm
  cache hit asserted in-process), and on the committed baseline the
  measured winner is never slower than the heuristic pick beyond noise,
  the heuristic *chunk* is never the slowest measured chunk beyond
  noise (the chunk extension of the mispick gate), and the recorded
  cost-model estimates are sane — positive op/mem estimates whose
  implied GFLOPS/GB/s stay inside generous physical bounds
  (``ESTIMATE_MAX_GFLOPS``/``ESTIMATE_MAX_GBPS``) so cost-model rot
  shows up here instead of silently mis-pruning candidates.

Usage: ``python benchmarks/check_smoke.py BENCH_SMOKE.json BENCH_PR10.json``
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.run import (  # noqa: E402
    AUTOTUNE_PICKS,
    GRAPH_DEPTHS,
    PLACEMENT_DEVICES,
    STRIDE_GRIDS,
    STRIDE_KERNELS,
    STRIDE_SMOKE_GRIDS,
    SWEEP_SMOKE_PICKS,
)

REQUIRED_CELLS = ("scan_serial", "scan_batched", "vmap_serial", "vmap_batched")
NOAVX_CELLS = ("scan_serial_noavx", "scan_batched_noavx")
STREAM_DEPTHS = (1, 2, 4)  # pipeline depths every run must cover
STREAM_FIELDS = ("serial_us", "stream_us", "overlap_x")
GRAPH_FIELDS = ("eager_us", "replay_us", "speedup_x")
GRAPH_MIN_SPEEDUP = 1.5  # baseline deepest-chain replay-vs-eager floor
PLACEMENT_FIELDS = ("us", "throughput_x", "devices_used", "cpus")
PLACEMENT_MIN_SCALING = 1.6  # 4-dev/4-stream throughput floor (cpus >= 4)
PLACEMENT_GATE_DEVICES = 4
STRIDE_FIELDS = ("chunked8_us", "clamp1_us", "stride_us", "stride_vs_clamp_x")
GRID_STRIDE_MIN_SPEEDUP = 1.3  # stride-vs-clamp floor, >= 1 baseline kernel
# never-slower margin: stride must stay within this factor of the
# clamped-chunk fallback on *every* committed-baseline cell (the two
# schedules execute the same grid of blocks, so a real loss means the
# stride loop itself regressed, not the workload)
STRIDE_SLOWDOWN_TOL = 1.15
# slowest/best spread below this is timing noise: on a time-shared host
# equal-cost cells reorder by up to ~1.7x run to run (measured on the
# 1-core dev container), so the autotune gate only binds where a
# mispick is unambiguous — e.g. vmap on a cooperative grid-sync kernel
# (6.5x) or batched warps on a captured-atomics reduction (6.1x)
AUTOTUNE_NOISE_X = 2.0
AUTOTUNE_FIELDS = (
    "heur_us",
    "tuned_us",
    "speedup_x",
    "op_estimate",
    "mem_estimate",
    "gflops",
)
# estimate-accuracy bounds: recorded op/mem estimates against measured
# wall time must imply a throughput a CPU host could conceivably reach —
# generous by orders of magnitude, they catch a cost model that starts
# counting garbage (units slip, double-counted loops), not slow kernels
ESTIMATE_MAX_GFLOPS = 5000.0  # ~50x any host CPU
ESTIMATE_MAX_GBPS = 2000.0  # ~5x any host memory system


def fail(msg: str) -> None:
    print(f"check_smoke: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {path}: {e}")
        raise AssertionError  # unreachable


def main(argv: list[str]) -> None:
    if len(argv) != 3:
        fail("usage: check_smoke.py <smoke.json> <baseline.json>")
    smoke, baseline = load(argv[1]), load(argv[2])

    if smoke.get("schema") != baseline.get("schema"):
        fail(
            f"schema mismatch: smoke={smoke.get('schema')!r} "
            f"baseline={baseline.get('schema')!r}"
        )
    if "backend_sweep" not in smoke.get("sections", []):
        fail(f"smoke run missed the backend_sweep section: {smoke.get('sections')}")

    smoke_entries = {e["kernel"]: e for e in smoke.get("backend_sweep", [])}
    base_kernels = {e["kernel"] for e in baseline.get("backend_sweep", [])}

    missing = [k for k in SWEEP_SMOKE_PICKS if k not in smoke_entries]
    if missing:
        fail(f"smoke sweep lost kernels {missing} (present: {sorted(smoke_entries)})")
    gone_from_base = [k for k in SWEEP_SMOKE_PICKS if k not in base_kernels]
    if gone_from_base:
        fail(
            f"kernels {gone_from_base} absent from the committed baseline — "
            f"regenerate BENCH_PR3.json (python benchmarks/run.py "
            f"--sections backend_sweep --json BENCH_PR3.json)"
        )

    row_names = {r["name"] for r in smoke.get("rows", [])}
    for kernel in SWEEP_SMOKE_PICKS:
        entry = smoke_entries[kernel]
        cells = entry.get("times_us", {})
        need = list(REQUIRED_CELLS)
        if any(c in cells for c in NOAVX_CELLS):
            need += list(NOAVX_CELLS)
        for cell in need:
            t = cells.get(cell)
            if not isinstance(t, (int, float)) or t <= 0:
                fail(f"{kernel}: cell {cell!r} missing or non-positive ({t!r})")
        if f"backend_sweep.{kernel}" not in row_names:
            fail(f"{kernel}: CSV row missing from the smoke output")

    check_streams(smoke, baseline, row_names)
    check_graph(smoke, baseline, row_names)
    check_placement(smoke, baseline, row_names)
    check_grid_stride(smoke, baseline, row_names)
    check_autotune(baseline)
    check_autotune_section(smoke, baseline, row_names)
    check_health(smoke)

    print(
        f"check_smoke: OK — {len(SWEEP_SMOKE_PICKS)} kernels × "
        f"{len(REQUIRED_CELLS)}+ cells present; streams cells × "
        f"{len(STREAM_DEPTHS)} depths present; graph_replay cells × "
        f"{len(GRAPH_DEPTHS)} depths present (baseline depth-"
        f"{max(GRAPH_DEPTHS)} speedup ≥ {GRAPH_MIN_SPEEDUP}x); "
        f"placement cells × {len(PLACEMENT_DEVICES)} pool sizes present "
        f"(≥ {PLACEMENT_MIN_SCALING}x at {PLACEMENT_GATE_DEVICES} devices "
        f"when cpus ≥ {PLACEMENT_GATE_DEVICES}); grid_stride cells × "
        f"{len(STRIDE_KERNELS)} kernels present (baseline stride never "
        f"> {STRIDE_SLOWDOWN_TOL}x clamp, best ≥ "
        f"{GRID_STRIDE_MIN_SPEEDUP}x); autotune picks checked "
        f"({len(AUTOTUNE_PICKS)} tuned kernels: never-slower ≤ "
        f"{AUTOTUNE_NOISE_X}x, chunk picks + estimate bounds); "
        f"equality asserts ran in-process"
    )


def check_streams(smoke: dict, baseline: dict, row_names: set) -> None:
    if "streams" not in smoke.get("sections", []):
        fail(f"smoke run missed the streams section: {smoke.get('sections')}")
    for tag, payload in (("smoke", smoke), ("baseline", baseline)):
        by_depth = {e.get("depth"): e for e in payload.get("streams", [])}
        missing = [d for d in STREAM_DEPTHS if d not in by_depth]
        if missing:
            fail(
                f"{tag}: streams cells missing depths {missing} "
                f"(present: {sorted(by_depth)})"
            )
        for depth in STREAM_DEPTHS:
            entry = by_depth[depth]
            for field in STREAM_FIELDS:
                value = entry.get(field)
                if not isinstance(value, (int, float)) or value <= 0:
                    fail(
                        f"{tag}: streams depth {depth}: field {field!r} "
                        f"missing or non-positive ({value!r})"
                    )
    for depth in STREAM_DEPTHS:
        if f"streams.pair_depth{depth}" not in row_names:
            fail(f"streams.pair_depth{depth}: CSV row missing from smoke output")


def check_graph(smoke: dict, baseline: dict, row_names: set) -> None:
    if "graph_replay" not in smoke.get("sections", []):
        fail(f"smoke run missed the graph_replay section: {smoke.get('sections')}")
    for tag, payload in (("smoke", smoke), ("baseline", baseline)):
        by_depth = {e.get("depth"): e for e in payload.get("graph_replay", [])}
        missing = [d for d in GRAPH_DEPTHS if d not in by_depth]
        if missing:
            fail(
                f"{tag}: graph_replay cells missing depths {missing} "
                f"(present: {sorted(by_depth)})"
            )
        for depth in GRAPH_DEPTHS:
            entry = by_depth[depth]
            for field in GRAPH_FIELDS:
                value = entry.get(field)
                if not isinstance(value, (int, float)) or value <= 0:
                    fail(
                        f"{tag}: graph_replay depth {depth}: field {field!r} "
                        f"missing or non-positive ({value!r})"
                    )
    # the tentpole perf claim, checked on the committed full run (smoke
    # runs 1 iteration — too noisy to gate a ratio on)
    deepest = max(GRAPH_DEPTHS)
    base_deep = {e["depth"]: e for e in baseline["graph_replay"]}[deepest]
    if base_deep["speedup_x"] < GRAPH_MIN_SPEEDUP:
        fail(
            f"baseline graph_replay depth {deepest}: replay speedup "
            f"{base_deep['speedup_x']}x < {GRAPH_MIN_SPEEDUP}x — "
            f"capture/replay no longer beats per-launch dispatch"
        )
    for depth in GRAPH_DEPTHS:
        if f"graph_replay.chain_depth{depth}" not in row_names:
            fail(f"graph_replay.chain_depth{depth}: CSV row missing from smoke")


def check_placement(smoke: dict, baseline: dict, row_names: set) -> None:
    if "placement" not in smoke.get("sections", []):
        fail(f"smoke run missed the placement section: {smoke.get('sections')}")
    for tag, payload in (("smoke", smoke), ("baseline", baseline)):
        by_dev = {e.get("devices"): e for e in payload.get("placement", [])}
        missing = [d for d in PLACEMENT_DEVICES if d not in by_dev]
        if missing:
            fail(
                f"{tag}: placement cells missing pool sizes {missing} "
                f"(present: {sorted(by_dev)})"
            )
        for dev in PLACEMENT_DEVICES:
            entry = by_dev[dev]
            for field in PLACEMENT_FIELDS:
                value = entry.get(field)
                if not isinstance(value, (int, float)) or value <= 0:
                    fail(
                        f"{tag}: placement pool {dev}: field {field!r} "
                        f"missing or non-positive ({value!r})"
                    )
            if entry.get("bitwise_equal") is not True:
                fail(
                    f"{tag}: placement pool {dev}: outputs not bitwise-"
                    f"equal to the 1-device pool ({entry.get('bitwise_equal')!r})"
                )
        # the tentpole perf claim: 4 streams over 4 devices sustain >=
        # 1.6x the 1-device-pool throughput.  XLA host devices time-share
        # physical cores, so the floor only binds where >= 4 cores exist
        # (CI runners); a 1-core dev box records the cells, skips the gate.
        gate = by_dev[PLACEMENT_GATE_DEVICES]
        if gate["cpus"] >= PLACEMENT_GATE_DEVICES:
            if gate["throughput_x"] < PLACEMENT_MIN_SCALING:
                fail(
                    f"{tag}: placement pool {PLACEMENT_GATE_DEVICES} "
                    f"({gate['cpus']} cpus): throughput {gate['throughput_x']}x "
                    f"< {PLACEMENT_MIN_SCALING}x vs the 1-device pool — "
                    f"stream placement no longer yields device concurrency"
                )
        else:
            print(
                f"check_smoke: note — {tag} placement ran on "
                f"{gate['cpus']} core(s); {PLACEMENT_MIN_SCALING}x scaling "
                f"gate needs >= {PLACEMENT_GATE_DEVICES}, skipped"
            )
    for dev in PLACEMENT_DEVICES:
        if f"placement.devices_{dev}" not in row_names:
            fail(f"placement.devices_{dev}: CSV row missing from smoke output")


def check_grid_stride(smoke: dict, baseline: dict, row_names: set) -> None:
    """Gate the grid-stride lowering.  Coverage + provenance on both
    runs (the cost model must route to ``grid_stride`` on its own under
    the section's forced-small footprint budget — ``schedule_source ==
    'heuristic'``, never a fallback or an explicit pin); the perf gates
    bind on the committed full-run baseline only (smoke runs 1 timing
    iteration):

    * never-slower — every baseline cell's ``stride_us`` stays within
      ``STRIDE_SLOWDOWN_TOL`` of ``clamp1_us``, the clamped-chunk
      fallback the stride schedule replaced (both schedules execute the
      same grid of blocks, so a real loss is a stride-loop regression);
    * amortization — at least one baseline kernel cell shows
      ``stride_vs_clamp_x >= GRID_STRIDE_MIN_SPEEDUP``: looping
      ``n_resident`` slots over the oversubscribed grid actually
      amortizes the per-wave dispatch overhead the one-block-per-wave
      clamp pays ``grid`` times."""
    if "grid_stride" not in smoke.get("sections", []):
        fail(f"smoke run missed the grid_stride section: {smoke.get('sections')}")
    for tag, payload, grids in (
        ("smoke", smoke, STRIDE_SMOKE_GRIDS),
        ("baseline", baseline, STRIDE_GRIDS),
    ):
        cells = {
            (e.get("kernel"), e.get("grid")): e
            for e in payload.get("grid_stride", [])
        }
        for kernel in STRIDE_KERNELS:
            for grid in grids:
                entry = cells.get((kernel, grid))
                if entry is None:
                    fail(
                        f"{tag}: grid_stride cell ({kernel}, g{grid}) missing "
                        f"(present: {sorted(cells)})"
                    )
                for field in STRIDE_FIELDS:
                    value = entry.get(field)
                    if not isinstance(value, (int, float)) or value <= 0:
                        fail(
                            f"{tag}: grid_stride {kernel} g{grid}: field "
                            f"{field!r} missing or non-positive ({value!r})"
                        )
                if entry.get("schedule") != "grid_stride":
                    fail(
                        f"{tag}: grid_stride {kernel} g{grid}: resolved "
                        f"schedule is {entry.get('schedule')!r} — the cost "
                        f"model no longer routes oversubscribed grids to "
                        f"the stride schedule"
                    )
                if entry.get("schedule_source") != "heuristic":
                    fail(
                        f"{tag}: grid_stride {kernel} g{grid}: "
                        f"schedule_source is {entry.get('schedule_source')!r} "
                        f"(expected 'heuristic' — the verdict must fire on "
                        f"its own, not via a pin or fallback)"
                    )
                n_res = entry.get("n_resident")
                if not isinstance(n_res, int) or n_res < 1:
                    fail(
                        f"{tag}: grid_stride {kernel} g{grid}: n_resident "
                        f"{n_res!r} is not a positive int"
                    )
    for kernel in STRIDE_KERNELS:
        for grid in STRIDE_SMOKE_GRIDS:
            if f"grid_stride.{kernel}_g{grid}" not in row_names:
                fail(f"grid_stride.{kernel}_g{grid}: CSV row missing from smoke")

    # perf gates: committed full-run baseline only
    base_cells = {
        (e["kernel"], e["grid"]): e for e in baseline.get("grid_stride", [])
    }
    best = 0.0
    for (kernel, grid), entry in sorted(base_cells.items()):
        if entry["stride_us"] > STRIDE_SLOWDOWN_TOL * entry["clamp1_us"]:
            fail(
                f"baseline grid_stride {kernel} g{grid}: stride "
                f"{entry['stride_us']}us is "
                f"{entry['stride_us'] / entry['clamp1_us']:.2f}x slower than "
                f"the clamped-chunk fallback at {entry['clamp1_us']}us "
                f"(> {STRIDE_SLOWDOWN_TOL}x tolerance) — the resident-wave "
                f"loop regressed; regenerate BENCH_PR10.json or fix the "
                f"stride executor"
            )
        best = max(best, entry["stride_vs_clamp_x"])
    if best < GRID_STRIDE_MIN_SPEEDUP:
        fail(
            f"baseline grid_stride: best stride-vs-clamp speedup {best}x < "
            f"{GRID_STRIDE_MIN_SPEEDUP}x on every kernel — grid-stride no "
            f"longer amortizes per-wave dispatch over the oversubscribed "
            f"grid; regenerate BENCH_PR10.json on an idle host or fix the "
            f"stride executor"
        )


def check_autotune(baseline: dict) -> None:
    """The all-auto heuristics must not pick the slowest measured cell.
    Checked on the committed full run only (smoke runs 1 iteration —
    too noisy to rank cells), and only when the slowest/best spread
    exceeds the noise margin: on a time-shared host, equal-cost cells
    reorder freely run to run."""
    for entry in baseline.get("backend_sweep", []):
        kernel = entry.get("kernel")
        auto = entry.get("auto_cell")
        if not auto:
            fail(
                f"{kernel}: baseline sweep entry carries no auto_cell — "
                f"regenerate the baseline (python benchmarks/run.py "
                f"--sections backend_sweep ... --json BENCH_PR10.json)"
            )
        chunk = entry.get("auto_chunk")
        if not isinstance(chunk, int) or chunk < 1:
            fail(
                f"{kernel}: baseline sweep entry carries no auto_chunk "
                f"({chunk!r}) — regenerate the baseline with the "
                f"chunk-resolving sweep (BENCH_PR10.json)"
            )
        if entry.get("chunk_source") not in (
            "heuristic",
            "explicit",
            "cooperative",
            "autotuned",
        ):
            fail(
                f"{kernel}: baseline sweep entry has invalid chunk_source "
                f"{entry.get('chunk_source')!r}"
            )
        cells = {
            c: t for c, t in entry.get("times_us", {}).items() if c in REQUIRED_CELLS
        }
        if auto not in cells:
            fail(
                f"{kernel}: auto_cell {auto!r} has no measured time "
                f"(cells: {sorted(cells)})"
            )
        best, worst = min(cells.values()), max(cells.values())
        if cells[auto] >= worst and worst > AUTOTUNE_NOISE_X * best:
            fail(
                f"{kernel}: auto heuristics picked {auto!r} "
                f"({cells[auto]}us) — the slowest measured cell, "
                f"{worst / best:.2f}x over the best "
                f"({min(cells, key=cells.get)!r} at {best}us); retune "
                f"repro.core.flat or regenerate the baseline"
            )


def check_autotune_section(smoke: dict, baseline: dict, row_names: set) -> None:
    """Gate the measured-tuning section itself.  Coverage + field sanity
    on both runs; the perf and accuracy gates bind on the committed
    full-run baseline only (smoke runs 1 timing iteration):

    * never-slower — the tuned pick's wall time stays within
      ``AUTOTUNE_NOISE_X`` of the heuristic pick's (the heuristic cell
      is always a candidate, so a bigger loss means the tuner picked on
      garbage measurements);
    * chunk mispick — among the tuner's own candidate measurements that
      share the heuristic backend/warp_exec, the heuristic *chunk* is
      never the slowest cell beyond noise (the chunk analogue of the
      ``auto_cell`` gate: it would mean ``DEFAULT_CHUNK`` needs
      retuning).  The candidate cells are min-of-2 single launches —
      jittery on a time-shared host — so the gate additionally requires
      corroboration from the median-of-iters wall timings (the
      heuristic pick actually losing to the tuned pick beyond noise)
      before it fires;
    * estimate accuracy — op/mem estimates are positive and, against the
      measured wall time, imply throughputs inside generous physical
      bounds; a violation means cost-model rot, and the tuner's
      footprint pruning is built on those numbers."""
    if "autotune" not in smoke.get("sections", []):
        fail(f"smoke run missed the autotune section: {smoke.get('sections')}")
    for tag, payload in (("smoke", smoke), ("baseline", baseline)):
        by_kernel = {e.get("kernel"): e for e in payload.get("autotune", [])}
        missing = [k for k in AUTOTUNE_PICKS if k not in by_kernel]
        if missing:
            fail(
                f"{tag}: autotune cells missing kernels {missing} "
                f"(present: {sorted(by_kernel)})"
            )
        for kernel in AUTOTUNE_PICKS:
            entry = by_kernel[kernel]
            for field in AUTOTUNE_FIELDS:
                value = entry.get(field)
                if not isinstance(value, (int, float)) or value <= 0:
                    fail(
                        f"{tag}: autotune {kernel}: field {field!r} "
                        f"missing or non-positive ({value!r})"
                    )
            if not entry.get("heur_cell") or not entry.get("tuned_cell"):
                fail(f"{tag}: autotune {kernel}: pick cells missing")
    stats = smoke.get("autotune_stats", {})
    if stats.get("measurements", 0) <= 0:
        fail(
            "smoke autotune section issued no measurement launches "
            f"(autotune_stats: {stats!r}) — the cold pass never tuned"
        )
    for kernel in AUTOTUNE_PICKS:
        if f"autotune.{kernel}" not in row_names:
            fail(f"autotune.{kernel}: CSV row missing from smoke output")

    base_cells = {e["kernel"]: e for e in baseline.get("autotune", [])}
    for kernel in AUTOTUNE_PICKS:
        entry = base_cells[kernel]
        # never-slower (baseline timings only: medians over full iters)
        if entry["tuned_us"] > AUTOTUNE_NOISE_X * entry["heur_us"]:
            fail(
                f"baseline autotune {kernel}: tuned pick "
                f"{entry['tuned_cell']!r} at {entry['tuned_us']}us is "
                f"{entry['tuned_us'] / entry['heur_us']:.2f}x slower than "
                f"the heuristic pick {entry['heur_cell']!r} at "
                f"{entry['heur_us']}us (> {AUTOTUNE_NOISE_X}x noise) — "
                f"the tuner picked on garbage measurements"
            )
        # chunk mispick: the heuristic chunk vs the tuner's own chunk
        # column (cells sharing the heuristic backend/warp_exec)
        cand = entry.get("candidate_times_us", {})
        heur = entry.get("heur_cell", "")  # e.g. vmap_serial_c8
        prefix = "/".join(heur.split("_")[:2])  # -> vmap/serial
        col = {c: t for c, t in cand.items() if c.startswith(prefix + "/")}
        heur_label = prefix + "/" + heur.split("_")[-1]  # vmap/serial/c8
        # the tuner's cells are min-of-2 launches (jittery); only fail
        # when the stable median timings corroborate the mispick
        corroborated = entry["heur_us"] > AUTOTUNE_NOISE_X * entry["tuned_us"]
        if len(col) > 1 and heur_label in col and corroborated:
            best, worst = min(col.values()), max(col.values())
            if col[heur_label] >= worst and worst > AUTOTUNE_NOISE_X * best:
                fail(
                    f"baseline autotune {kernel}: heuristic chunk cell "
                    f"{heur_label!r} ({col[heur_label]:.0f}us) is the "
                    f"slowest measured chunk, {worst / best:.2f}x over "
                    f"the best, and the median timings confirm "
                    f"({entry['heur_us']}us vs {entry['tuned_us']}us) — "
                    f"retune DEFAULT_CHUNK in repro.core.backends.plan "
                    f"or regenerate the baseline"
                )
        # estimate accuracy: implied throughput at the measured time
        gflops = entry["op_estimate"] / entry["tuned_us"] / 1e3
        gbps = entry["mem_estimate"] / entry["tuned_us"] / 1e3
        if gflops > ESTIMATE_MAX_GFLOPS:
            fail(
                f"baseline autotune {kernel}: op_estimate "
                f"{entry['op_estimate']:.3g} implies {gflops:.0f} GFLOPS "
                f"at {entry['tuned_us']}us (> {ESTIMATE_MAX_GFLOPS}) — "
                f"cost-model op counting is off"
            )
        if gbps > ESTIMATE_MAX_GBPS:
            fail(
                f"baseline autotune {kernel}: mem_estimate "
                f"{entry['mem_estimate']:.3g} implies {gbps:.0f} GB/s "
                f"at {entry['tuned_us']}us (> {ESTIMATE_MAX_GBPS}) — "
                f"cost-model byte counting is off"
            )


def check_health(smoke: dict) -> None:
    """A clean bench run must never have leaned on the fault-tolerance
    machinery: a degradation-ladder rung (or a retry/timeout) means the
    timed cell was not the resolved configuration, so the numbers lie.
    Tolerates a baseline written before dispatch_health existed — only
    the fresh smoke run is gated."""
    health = smoke.get("dispatch_health")
    if health is None:
        fail(
            "smoke run carries no dispatch_health (benchmarks/run.py "
            "should record cox.get_dispatcher().health())"
        )
    for key in ("degradations", "retries", "timeouts", "failures"):
        n = health.get(key)
        if n != 0:
            fail(
                f"smoke run is not clean: dispatch_health[{key!r}] == {n!r} "
                f"(expected 0) — the degradation ladder or retry path "
                f"fired during a benchmark"
            )
    if health.get("sticky") is not None:
        fail(f"smoke run ended with a sticky device error: {health['sticky']}")


if __name__ == "__main__":
    main(sys.argv)
