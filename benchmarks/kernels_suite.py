"""The coverage kernel suite — Table 1 of the paper, rebuilt.

31 kernels mirroring the CUDA SDK 10.1 rows the paper evaluates (same
feature classes: plain SPMD, block cooperative groups / __syncthreads
reductions, warp cooperative groups, warp shuffle, warp vote, grid sync,
dynamic cooperative groups).  Each entry carries the feature tag used in
the paper's table so the coverage comparison (flat vs hierarchical)
reproduces Table 1's structure.

Unsupported-on-purpose rows (grid sync, multi-grid sync, dynamic groups)
are represented by builders that raise CoxUnsupported at parse/compile
time — the same 3 rows COX itself cannot run (90% coverage).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core import cox


@dataclasses.dataclass
class SuiteKernel:
    name: str
    features: str                  # '' | block-cg | warp-cg | shuffle | vote | grid-sync | dynamic-cg
    kernel: Optional[object]       # KernelFn, or None for unsupported rows
    grid: object                   # int | (x, y[, z]) dim3
    block: object                  # int | (x, y[, z]) dim3
    make_args: Callable[[], tuple]
    check: Optional[Callable] = None
    unsupported_reason: str = ""


RNG = np.random.default_rng(7)
KERNELS: List[SuiteKernel] = []


def _reg(name, features, kernel, grid, block, make_args, check=None,
         unsupported_reason=""):
    KERNELS.append(SuiteKernel(name, features, kernel, grid, block,
                               make_args, check, unsupported_reason))


# ---------------------------------------------------------------------------
# plain SPMD kernels (the ✓✓✓ rows)
# ---------------------------------------------------------------------------

@cox.kernel
def initVectors(c, rhs: cox.Array(cox.f32), x: cox.Array(cox.f32),
                n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        rhs[i] = 1.0
        x[i] = 0.0


_reg("initVectors", "", initVectors, 2, 256,
     lambda: (np.zeros(512, np.float32), np.ones(512, np.float32), 500),
     lambda out: np.allclose(out["rhs"][:500], 1.0) and
     np.allclose(out["x"][:500], 0.0))


@cox.kernel
def vectorAdd(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
              b: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = a[i] + b[i]


def _va_args():
    a = RNG.normal(size=512).astype(np.float32)
    b = RNG.normal(size=512).astype(np.float32)
    return (np.zeros(512, np.float32), a, b, 512)


_reg("vectorAdd", "", vectorAdd, 2, 256, _va_args)


@cox.kernel
def gpuSpMV(c, y: cox.Array(cox.f32), vals: cox.Array(cox.f32),
            cols: cox.Array(cox.i32), rowptr: cox.Array(cox.i32),
            x: cox.Array(cox.f32), n_rows: cox.i32):
    row = c.block_idx() * c.block_dim() + c.thread_idx()
    if row < n_rows:
        acc = 0.0
        start = rowptr[row]
        end = rowptr[row + 1]
        j = start
        while j < end:
            acc = acc + vals[j] * x[cols[j]]
            j = j + 1
        y[row] = acc


def _spmv_args():
    n = 64
    rowptr = np.arange(n + 1, dtype=np.int32) * 4
    cols = RNG.integers(0, n, size=4 * n).astype(np.int32)
    vals = RNG.normal(size=4 * n).astype(np.float32)
    x = RNG.normal(size=n).astype(np.float32)
    return (np.zeros(n, np.float32), vals, cols, rowptr, x, n)


_reg("gpuSpMV", "", gpuSpMV, 1, 64, _spmv_args)


@cox.kernel
def r1_div_x(c, r1: cox.Array(cox.f32), r0: cox.Array(cox.f32),
             dot: cox.Array(cox.f32)):
    i = c.thread_idx()
    if i == 0:
        r1[0] = r0[0] / dot[0]


_reg("r1_div_x", "", r1_div_x, 1, 32,
     lambda: (np.zeros(1, np.float32), np.array([6.0], np.float32),
              np.array([2.0], np.float32)),
     lambda out: np.allclose(out["r1"], 3.0))


@cox.kernel
def a_minus(c, a: cox.Array(cox.f32), na: cox.Array(cox.f32)):
    i = c.thread_idx()
    if i == 0:
        na[0] = 0.0 - a[0]


_reg("a_minus", "", a_minus, 1, 32,
     lambda: (np.array([5.0], np.float32), np.zeros(1, np.float32)),
     lambda out: np.allclose(out["na"], -5.0))


@cox.kernel
def MatrixMulCUDA(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                  b: cox.Array(cox.f32), n: cox.i32):
    # the CUDA SDK's natural 2-D form: tiled 16x16 matmul with shared
    # memory + block barriers, launched <<<dim3(n/16, n/16), dim3(16, 16)>>>
    tile_a = c.shared((16, 16), cox.f32)
    tile_b = c.shared((16, 16), cox.f32)
    ty = c.thread_idx('y')
    tx = c.thread_idx('x')
    row = c.block_idx('y') * 16 + ty
    col = c.block_idx('x') * 16 + tx
    acc = 0.0
    for t in range(0, 64, 16):
        tile_a[ty, tx] = a[row * n + t + tx]
        tile_b[ty, tx] = b[(t + ty) * n + col]
        c.syncthreads()
        for kk in range(16):
            acc = acc + tile_a[ty, kk] * tile_b[kk, tx]
        c.syncthreads()
    out[row * n + col] = acc


def _mm_args():
    n = 64
    a = RNG.normal(size=(n, n)).astype(np.float32)
    b = RNG.normal(size=(n, n)).astype(np.float32)
    return (np.zeros((n, n), np.float32), a, b, n)


def _mm_check(out):
    a, b = _MM_CACHE
    return np.allclose(out["out"], a @ b, atol=1e-3)


_MM_CACHE = None


def _mm_args_cached():
    global _MM_CACHE
    args = _mm_args()
    _MM_CACHE = (args[1], args[2])
    return args


_reg("MatrixMulCUDA", "", MatrixMulCUDA, (4, 4), (16, 16), _mm_args_cached,
     _mm_check)
_reg("matrixMul", "", MatrixMulCUDA, (4, 4), (16, 16), _mm_args_cached,
     _mm_check)
_reg("matrixMultiplyKernel", "", MatrixMulCUDA, (4, 4), (16, 16),
     _mm_args_cached, _mm_check)


@cox.kernel
def copyp2p(c, dst: cox.Array(cox.f32), src: cox.Array(cox.f32),
            n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        dst[i] = src[i]


_reg("copyp2p", "", copyp2p, 2, 128,
     lambda: (np.zeros(256, np.float32),
              RNG.normal(size=256).astype(np.float32), 256))


@cox.kernel
def simpleKernel(c, out: cox.Array(cox.f32), inp: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    out[i] = inp[i] * 2.0 + 1.0


_reg("simpleKernel", "", simpleKernel, 2, 64,
     lambda: (np.zeros(128, np.float32),
              RNG.normal(size=128).astype(np.float32)))


@cox.kernel
def uniform_add(c, out: cox.Array(cox.f32), uni: cox.Array(cox.f32),
                n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] += uni[c.block_idx()]


_reg("uniform_add", "", uniform_add, 2, 128,
     lambda: (np.zeros(256, np.float32),
              np.array([1.0, 2.0], np.float32), 256))


@cox.kernel
def spinWhileLessThanOne(c, flag: cox.Array(cox.i32),
                         out: cox.Array(cox.i32)):
    i = c.thread_idx()
    spins = 0
    while flag[0] < 1 and spins < 4:
        spins = spins + 1
    out[i] = spins


_reg("spinWhileLessThanone", "", spinWhileLessThanOne, 1, 64,
     lambda: (np.zeros(1, np.int32), np.zeros(64, np.int32)))


# ---------------------------------------------------------------------------
# block cooperative groups (reduce0-3: __syncthreads tree reductions)
# ---------------------------------------------------------------------------


def _make_block_reduce(name):
    @cox.kernel(name=name)
    def reduce_block(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
        tile = c.shared((256,), cox.f32)
        tid = c.thread_idx()
        tile[tid] = val[c.block_idx() * c.block_dim() + tid]
        c.syncthreads()
        s = 128
        while s > 0:
            if tid < s:
                tile[tid] = tile[tid] + tile[tid + s]
            c.syncthreads()
            s = s // 2
        if tid == 0:
            out[c.block_idx()] = tile[0]
    return reduce_block


def _br_args():
    v = RNG.normal(size=512).astype(np.float32)
    return (np.zeros(2, np.float32), v)


def _br_check(out):
    return True  # validated against oracle in tests


for nm in ("reduce0", "reduce1", "reduce2", "reduce3"):
    _reg(nm, "block-cg", _make_block_reduce(nm), 2, 256, _br_args)


# ---------------------------------------------------------------------------
# warp cooperative groups / shuffle / vote (the rows flat collapsing fails)
# ---------------------------------------------------------------------------


def _make_warp_reduce(name):
    @cox.kernel(name=name)
    def reduce_warp(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
        tile = c.shared((8,), cox.f32)
        tid = c.thread_idx()
        v = val[c.block_idx() * c.block_dim() + tid]
        offset = 16
        while offset > 0:
            s = c.shfl_down(v, offset)
            v = v + s
            offset = offset // 2
        if c.lane_id() == 0:
            tile[c.warp_id()] = v
        c.syncthreads()
        if tid < 8:
            w = tile[tid]
            off2 = 4
            while off2 > 0:
                s2 = c.shfl_down(w, off2, width=8)
                w = w + s2
                off2 = off2 // 2
            if tid == 0:
                out[c.block_idx()] = w
    return reduce_warp


for nm in ("reduce4", "reduce5", "reduce6", "reduce", "reduceFinal",
           "gpuDotProduct"):
    _reg(nm, "warp-cg", _make_warp_reduce(nm), 2, 256, _br_args)


def _make_shfl_scan(name):
    @cox.kernel(name=name)
    def shfl_scan(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
        tid = c.thread_idx()
        v = val[c.block_idx() * c.block_dim() + tid]
        lane = c.lane_id()
        offset = 1
        while offset < 32:
            s = c.shfl_up(v, offset)
            if lane >= offset:
                v = v + s
            offset = offset * 2
        out[c.block_idx() * c.block_dim() + tid] = v
    return shfl_scan


for nm in ("shfl_intimage_rows", "shfl_vertical_shfl", "shfl_scan_test"):
    _reg(nm, "shuffle", _make_shfl_scan(nm), 2, 64,
         lambda: (np.zeros(128, np.float32),
                  RNG.normal(size=128).astype(np.float32)))


@cox.kernel
def VoteAnyKernel1(c, result: cox.Array(cox.i32), inp: cox.Array(cox.i32)):
    tx = c.thread_idx()
    r = c.vote_any(inp[tx] > 0)
    result[tx] = c.i32(r)


@cox.kernel
def VoteAllKernel2(c, result: cox.Array(cox.i32), inp: cox.Array(cox.i32)):
    tx = c.thread_idx()
    r = c.vote_all(inp[tx] > 0)
    result[tx] = c.i32(r)


@cox.kernel
def VoteAnyKernel3(c, result: cox.Array(cox.i32), inp: cox.Array(cox.i32)):
    tx = c.thread_idx()
    p = tx % 3 == 0
    r = c.vote_any(p)
    b = c.ballot(inp[tx] > 0)
    result[tx] = c.i32(r) + c.i32(b & 1)


def _vote_args():
    return (np.zeros(64, np.int32),
            RNG.integers(-2, 3, size=64).astype(np.int32))


_reg("VoteAnyKernel1", "vote", VoteAnyKernel1, 1, 64, _vote_args)
_reg("VoteAllKernel2", "vote", VoteAllKernel2, 1, 64, _vote_args)
_reg("VoteAnyKernel3", "vote", VoteAnyKernel3, 1, 64, _vote_args)


# ---------------------------------------------------------------------------
# extra kernels outside the paper's 31-row table: atomics + a memory-light
# many-block kernel, used by the backend-equivalence tests and the
# backend sweep in benchmarks/run.py (Table-1 coverage counts stay on
# KERNELS; ALL_KERNELS = KERNELS + EXTRA_KERNELS)
# ---------------------------------------------------------------------------

EXTRA_KERNELS: List[SuiteKernel] = []


def _reg_extra(name, features, kernel, grid, block, make_args, check=None):
    EXTRA_KERNELS.append(SuiteKernel(name, features, kernel, grid, block,
                                     make_args, check))


@cox.kernel
def histogram64(c, hist: cox.Array(cox.f32), data: cox.Array(cox.i32),
                n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, data[i], 1.0)


def _hist_args():
    data = RNG.integers(0, 64, size=2000).astype(np.int32)
    return (np.zeros(64, np.float32), data, 2000)


_reg_extra("histogram64", "atomics", histogram64, 16, 128, _hist_args,
           lambda out: out["hist"].sum() == 2000)


@cox.kernel
def blockCounter(c, total: cox.Array(cox.f32), partial: cox.Array(cox.f32),
                 val: cox.Array(cox.f32), n: cox.i32):
    # atomics + plain stores on different arrays in one kernel: each
    # thread stores its element and block-atomically counts valid ones
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        partial[i] = val[i] * 0.5
        c.atomic_add(total, 0, 1.0)


def _bc_args():
    v = RNG.normal(size=1000).astype(np.float32)
    return (np.zeros(1, np.float32), np.zeros(1000, np.float32), v, 900)


_reg_extra("blockCounter", "atomics", blockCounter, 8, 128, _bc_args,
           lambda out: out["total"][0] == 900)


@cox.kernel
def saxpyHeavy(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
               b: cox.Array(cox.f32), n: cox.i32):
    # memory-light, many-block, compute-heavy (Hetero-mark style): the
    # backend sweep's flagship — block parallelism dominates here
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        acc = 0.0
        for t in range(64):
            acc = acc + a[i] * 1.0001 + b[i] * 0.9999
        out[i] = acc


def _saxpy_args():
    n = 64 * 256
    a = RNG.normal(size=n).astype(np.float32)
    b = RNG.normal(size=n).astype(np.float32)
    return (np.zeros(n, np.float32), a, b, n)


_reg_extra("saxpyHeavy", "", saxpyHeavy, 64, 256, _saxpy_args)


@cox.kernel
def warpPrefixStats(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    # collective-dense warp statistics (butterfly all-reduce pipelines +
    # segmented reductions) staged through shared memory: 8 warps of
    # peel-free chained collectives, a block barrier, cross-warp shared
    # reads — the flagship for warp-batched execution (the serial
    # inter-warp loop pays one collective-op chain per warp; the batched
    # (n_warps, W) plane pays one chain total)
    tile = c.shared((8,), cox.f32)
    tid = c.thread_idx()
    i = c.block_idx() * c.block_dim() + tid
    v = a[i]
    x = v
    s1 = c.shfl_xor(x, 1)
    x = x + s1
    s2 = c.shfl_xor(x, 2)
    x = x + s2
    s4 = c.shfl_xor(x, 4)
    x = x + s4
    s8 = c.shfl_xor(x, 8)
    x = x + s8
    s16 = c.shfl_xor(x, 16)
    x = x + s16
    y = v * v
    t1 = c.shfl_xor(y, 1)
    y = c.max(y, t1)
    t2 = c.shfl_xor(y, 2)
    y = c.max(y, t2)
    t4 = c.shfl_xor(y, 4)
    y = c.max(y, t4)
    t8 = c.shfl_xor(y, 8)
    y = c.max(y, t8)
    t16 = c.shfl_xor(y, 16)
    y = c.max(y, t16)
    z = v + 1.0
    u1 = c.shfl_down(z, 1)
    z = z + u1
    u2 = c.shfl_down(z, 2)
    z = z + u2
    u4 = c.shfl_down(z, 4)
    z = z + u4
    m = c.red_max(v)
    n = c.red_min(v)
    r = c.red_add(z)
    b = c.red_add(y, width=8)
    if c.lane_id() == 0:
        tile[c.warp_id()] = x + m
    c.syncthreads()
    t = tile[tid % 8]
    out[i] = x + y + z + m + n + r + b + t


def _wps_args():
    # small-integer values keep every float reduction exact in any
    # association order, so all executor flavors agree bitwise
    n = 32 * 256
    a = RNG.integers(-6, 7, size=n).astype(np.float32)
    return (np.zeros(n, np.float32), a)


_reg_extra("warpPrefixStats", "warp-cg", warpPrefixStats, 32, 256, _wps_args)


@cox.kernel
def gridReduce(c, total: cox.Array(cox.f32), partial: cox.Array(cox.f32),
               data: cox.Array(cox.f32), n: cox.i32):
    # cooperative two-pass grid-wide reduction (the SDK's
    # reduceSinglePassMultiBlockCG shape): every block tree-reduces its
    # tile into partial[bid], the grid synchronizes, block 0 totals the
    # partials — no host round-trip between the passes.  The paper's
    # Table 1 marks this feature class ✗ for COX; our phase-split
    # grid_sync (repro.core.phases) runs it.
    tile = c.shared((128,), cox.f32)
    tid = c.thread_idx()
    i = c.block_idx() * c.block_dim() + tid
    tile[tid] = data[i] if i < n else 0.0
    c.syncthreads()
    s = 64
    while s > 0:
        if tid < s:
            tile[tid] = tile[tid] + tile[tid + s]
        c.syncthreads()
        s = s // 2
    if tid == 0:
        partial[c.block_idx()] = tile[0]
    c.grid_sync()
    if c.block_idx() == 0:
        acc = 0.0
        j = tid
        while j < c.grid_dim():
            acc = acc + partial[j]
            j = j + c.block_dim()
        tile[tid] = acc
        c.syncthreads()
        s2 = 64
        while s2 > 0:
            if tid < s2:
                tile[tid] = tile[tid] + tile[tid + s2]
            c.syncthreads()
            s2 = s2 // 2
        if tid == 0:
            total[0] = tile[0]


def _gr_args():
    # small integers: every float add is exact in any association order,
    # so scan/vmap/sharded × serial/batched agree bitwise with the oracle
    n = 1000
    data = RNG.integers(-8, 9, size=n).astype(np.float32)
    return (np.zeros(1, np.float32), np.zeros(8, np.float32), data, n)


_reg_extra("gridReduce", "grid-sync", gridReduce, 8, 128, _gr_args,
           lambda out: out["total"][0] == out["partial"].sum())


# ---------------------------------------------------------------------------
# dim3 kernels: the 2-D geometry the SDK actually ships (matrixMul above
# runs <<<dim3(4,4), dim3(16,16)>>>), plus the hand-flattened 1-D matmul
# kept as the perf baseline for the natural-2-D-within-10% comparison
# ---------------------------------------------------------------------------


@cox.kernel
def matrixMul1D(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                b: cox.Array(cox.f32), n: cox.i32):
    # the pre-dim3 port: same tiled matmul with the index arithmetic a
    # human flattened by hand (row/col recovered from linear ids)
    tile_a = c.shared((16, 16), cox.f32)
    tile_b = c.shared((16, 16), cox.f32)
    ty = c.thread_idx() // 16
    tx = c.thread_idx() % 16
    row = c.block_idx() // (n // 16) * 16 + ty
    col = c.block_idx() % (n // 16) * 16 + tx
    acc = 0.0
    for t in range(0, 64, 16):
        tile_a[ty, tx] = a[row * n + t + tx]
        tile_b[ty, tx] = b[(t + ty) * n + col]
        c.syncthreads()
        for kk in range(16):
            acc = acc + tile_a[ty, kk] * tile_b[kk, tx]
        c.syncthreads()
    out[row * n + col] = acc


_reg_extra("matrixMul1D", "", matrixMul1D, 16, 256, _mm_args_cached,
           _mm_check)


@cox.kernel
def transpose(c, odata: cox.Array(cox.f32), idata: cox.Array(cox.f32),
              n: cox.i32):
    # the SDK's shared-memory tiled transpose: coalesced reads into a
    # padded tile (TILE_DIM+1 kills bank conflicts on real hardware;
    # kept for fidelity), barrier, coalesced transposed writes
    tile = c.shared((16, 17), cox.f32)
    x = c.block_idx('x') * 16 + c.thread_idx('x')
    y = c.block_idx('y') * 16 + c.thread_idx('y')
    tile[c.thread_idx('y'), c.thread_idx('x')] = idata[y * n + x]
    c.syncthreads()
    xo = c.block_idx('y') * 16 + c.thread_idx('x')
    yo = c.block_idx('x') * 16 + c.thread_idx('y')
    odata[yo * n + xo] = tile[c.thread_idx('x'), c.thread_idx('y')]


_T_CACHE = None


def _tr_args():
    global _T_CACHE
    n = 64
    _T_CACHE = RNG.normal(size=(n, n)).astype(np.float32)
    return (np.zeros((n, n), np.float32), _T_CACHE, n)


_reg_extra("transpose", "block-cg", transpose, (4, 4), (16, 16), _tr_args,
           lambda out: np.array_equal(out["odata"], _T_CACHE.T))


@cox.kernel
def stencil2d(c, out: cox.Array(cox.f32), inp: cox.Array(cox.f32),
              n: cox.i32):
    # 5-point Jacobi step over the interior, natural 2-D indexing
    x = c.block_idx('x') * c.block_dim('x') + c.thread_idx('x')
    y = c.block_idx('y') * c.block_dim('y') + c.thread_idx('y')
    if x > 0 and x < n - 1 and y > 0 and y < n - 1:
        out[y * n + x] = 0.25 * (inp[(y - 1) * n + x] + inp[(y + 1) * n + x]
                                 + inp[y * n + x - 1] + inp[y * n + x + 1])


_ST_CACHE = None


def _st_args():
    global _ST_CACHE
    n = 64
    _ST_CACHE = RNG.normal(size=(n, n)).astype(np.float32)
    return (np.zeros((n, n), np.float32), _ST_CACHE, n)


def _st_check(out):
    i = _ST_CACHE
    want = np.zeros_like(i)
    want[1:-1, 1:-1] = 0.25 * (i[:-2, 1:-1] + i[2:, 1:-1]
                               + i[1:-1, :-2] + i[1:-1, 2:])
    return np.allclose(out["out"], want, atol=1e-6)


_reg_extra("stencil2d", "", stencil2d, (4, 4), (16, 16), _st_args, _st_check)


def all_kernels() -> List[SuiteKernel]:
    """Table-1 rows plus the extra (atomics / sweep) kernels."""
    return KERNELS + EXTRA_KERNELS


# ---------------------------------------------------------------------------
# unsupported rows (grid sync / dynamic groups — COX's own ✗ rows)
# ---------------------------------------------------------------------------


def _unsupported(name, features, reason):
    _reg(name, features, None, 1, 64, lambda: (),
         unsupported_reason=reason)


_unsupported("gpuConjugateGradient", "grid-sync",
             "grid sync inside the CG iteration loop: dynamic phase "
             "count (phase-split grid_sync covers top-level syncs "
             "only — see gridReduce; paper §5.1: fully unsupported "
             "in COX)")
_unsupported("multiGpuConjugateGradient", "multi-grid-sync",
             "multi-grid sync across devices (paper: unsupported)")
_unsupported("filter_arr", "dynamic-cg",
             "dynamic cooperative group of activated threads "
             "(paper §2.2.3: runtime-level feature)")
