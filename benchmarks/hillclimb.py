"""§Perf hillclimbing driver: hypothesis → change → re-lower → re-analyse.

Runs the three selected (arch × shape) cells through their candidate
variants (sharding strategy, remat policy, SSD chunk size), records all
three roofline terms per variant into results/hillclimb.json, and prints
the before/after log that EXPERIMENTS.md §Perf reproduces.

Each variant is a *config/sharding* change only — the model math is
identical (tested); the dry-run artifacts are re-lowered and re-compiled
per variant.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# device count must be set before jax loads (this module is run directly)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

EXPERIMENTS = [
    # (arch, shape, variant-name, strategy, overrides, hypothesis)
    ("yi-34b", "train_4k", "baseline-tp", "tp", {},
     "paper-era default: DP16×TP16, sequence-parallel residuals"),
    ("yi-34b", "train_4k", "fsdp", "fsdp", {},
     "TP activation all-gathers (65k tok/dev × d) dwarf weight traffic; "
     "FSDP swaps them for per-layer weight gathers: predict ~3x coll ↓"),
    ("yi-34b", "train_4k", "fsdp-noremat", "fsdp", {"remat": "none"},
     "FSDP frees HBM (1 seq/chip): drop full remat, predict ~25% flops ↓"),

    ("zamba2-1.2b", "train_4k", "baseline-tp", "tp", {},
     "worst roofline fraction of the fleet (0.08)"),
    ("zamba2-1.2b", "train_4k", "chunk64", "tp", {"ssd_chunk": 64},
     "SSD L-matrices are S*C elements: halving C halves that traffic; "
     "predict ~25-40% memory-term ↓ on the SSD share"),
    ("zamba2-1.2b", "train_4k", "fsdp", "fsdp", {},
     "d_model=2048/16 TP shards are tiny; batch-everywhere removes TP "
     "collectives entirely for the mamba trunk"),
    ("zamba2-1.2b", "train_4k", "fsdp-chunk64", "fsdp", {"ssd_chunk": 64},
     "compose both wins"),

    ("mamba2-130m", "train_4k", "baseline-tp", "tp", {},
     "the paper-representative cell: SSD scan = COX warp-collective "
     "structure (intra-chunk = intra-warp, carried state = cross-PR var)"),
    ("mamba2-130m", "train_4k", "fsdp", "fsdp", {},
     "130M params: TP=16 on d=768 leaves MXU tiles tiny and pays "
     "all-gathers; FSDP makes every matmul full-width"),
    ("mamba2-130m", "train_4k", "fsdp-chunk256", "fsdp", {"ssd_chunk": 256},
     "bigger chunks raise SSD arithmetic intensity (C x C matmuls), "
     "fewer inter-chunk state round-trips; predict memory-term ↓"),
]


def main():
    from repro.launch.dryrun import run_cell
    from benchmarks.roofline import terms

    out = []
    for arch, shape, variant, strategy, overrides, hyp in EXPERIMENTS:
        rec = run_cell(arch, shape, multi_pod=False, strategy=strategy,
                       overrides=overrides)
        rec["variant"] = variant
        rec["hypothesis"] = hyp
        if rec["status"] == "ok":
            t = terms(rec)
            rec["terms"] = {k: v for k, v in t.items()
                            if isinstance(v, (int, float, str))}
            print(f"{arch} × {shape} [{variant}]: "
                  f"compute={t['t_compute']:.3f}s mem={t['t_memory']:.3f}s "
                  f"coll={t['t_collective']:.3f}s dom={t['dominant']} "
                  f"frac={t['roofline_fraction']:.3f}", flush=True)
        else:
            print(f"{arch} × {shape} [{variant}]: {rec['status']} "
                  f"{rec.get('error', '')[:200]}", flush=True)
        out.append(rec)
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "hillclimb.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
