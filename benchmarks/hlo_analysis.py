"""Shim: the while-aware HLO analyzer lives in repro.launch.hlo_analysis."""
from repro.launch.hlo_analysis import (Computation, accumulate, analyze,  # noqa
                                       parse_hlo, trip_count)
