"""CI driver for the autotune persistent-cache contract.

The promise the on-disk winner cache makes is *cross-process*: a fleet
tunes once, and every later boot resolves the same knobs from disk with
zero measurement launches.  In-process tests can only fake the fresh
boot (``autotune.reset(memory_only=True)``); this driver proves it for
real by running three phases in three separate interpreters, glued
together by the ``autotune`` CI job:

  cold   — resolve every pick kernel with ``autotune=True`` against an
           empty cache: the candidate grid is measured, winners land on
           disk.  Asserts the cache file exists afterwards and records
           picks / stats / wall time to ``cold.json``.
  warm   — a brand-new process repeats the identical resolves.  Asserts
           the measurement-launch counter stayed at ZERO (every pick
           came off disk) and records to ``warm.json``.
  check  — compares the two records: identical picks per kernel, warm
           disk hits == kernel count, and warm resolve wall time below
           the cold tuning wall time.

Usage (the CI job sets COX_AUTOTUNE_CACHE to a workspace-local path):

    python benchmarks/autotune_ci.py --phase cold  --out /tmp/at
    python benchmarks/autotune_ci.py --phase warm  --out /tmp/at
    python benchmarks/autotune_ci.py --phase check --out /tmp/at
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def fail(msg: str) -> None:
    print(f"autotune_ci: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def resolve_picks() -> tuple:
    """Resolve every pick kernel with autotune on; return
    ({kernel: resolved-cell}, stats, wall_seconds).  Cold: measures and
    persists.  Warm: must come entirely off the disk cache."""
    from benchmarks.kernels_suite import all_kernels
    from benchmarks.run import AUTOTUNE_PICKS
    from repro.core import autotune as at

    picks = {}
    t0 = time.perf_counter()
    for sk in all_kernels():
        if sk.name not in AUTOTUNE_PICKS:
            continue
        req = sk.kernel.make_request(grid=sk.grid, block=sk.block,
                                     args=sk.make_args(), autotune=True)
        rl = req.rl
        picks[sk.name] = {"backend": rl.backend, "warp_exec": rl.warp_exec,
                          "chunk": rl.chunk, "chunk_source": rl.chunk_source}
    wall = time.perf_counter() - t0
    if sorted(picks) != sorted(AUTOTUNE_PICKS):
        fail(f"pick kernels missing: resolved {sorted(picks)}, "
             f"expected {sorted(AUTOTUNE_PICKS)}")
    return picks, at.stats(), wall


def phase_cold(out: str) -> None:
    from repro.core import autotune as at
    path = at.cache_path()
    if path is None:
        fail(f"{at.ENV_CACHE} is 'off' — the cold phase needs a cache file")
    if os.path.exists(path):
        fail(f"cache file {path} already exists — cold phase must start "
             f"from an empty cache (the CI job uses a fresh workspace dir)")
    picks, stats, wall = resolve_picks()
    if stats["measurements"] <= 0:
        fail(f"cold phase issued no measurement launches: {stats}")
    if stats["misses"] != len(picks):
        fail(f"cold phase expected {len(picks)} misses, got {stats}")
    if not os.path.exists(path):
        fail(f"cold phase never wrote the cache file {path}")
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != at.AUTOTUNE_VERSION:
        fail(f"cache file carries version {doc.get('version')!r}, "
             f"expected {at.AUTOTUNE_VERSION}")
    record(out, "cold", picks, stats, wall, path)


def phase_warm(out: str) -> None:
    from repro.core import autotune as at
    path = at.cache_path()
    if path is None or not os.path.exists(path):
        fail(f"warm phase needs the cold phase's cache file ({path})")
    picks, stats, wall = resolve_picks()
    # the contract: a fresh process resolves every pick from disk with
    # ZERO measurement launches
    if stats["measurements"] != 0:
        fail(f"warm phase issued {stats['measurements']} measurement "
             f"launches (expected 0) — the disk cache was not honored")
    # the first lookup seeds the whole in-memory cache from disk (one
    # disk hit); later picks are memory hits — all that matters is that
    # every pick resolved from cache and disk was actually involved
    if stats["disk_hits"] < 1:
        fail(f"warm phase never touched the disk cache: {stats}")
    if stats["hits"] + stats["disk_hits"] != len(picks):
        fail(f"warm phase expected {len(picks)} cache hits, got {stats}")
    if stats["misses"] != 0:
        fail(f"warm phase missed the cache {stats['misses']} times")
    record(out, "warm", picks, stats, wall, path)


def record(out: str, phase: str, picks: dict, stats: dict, wall: float,
           cache: str) -> None:
    os.makedirs(out, exist_ok=True)
    doc = {"phase": phase, "picks": picks, "stats": stats,
           "wall_s": round(wall, 3), "cache": cache}
    dest = os.path.join(out, f"{phase}.json")
    with open(dest, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"autotune_ci: {phase} OK — {len(picks)} picks, "
          f"{stats['measurements']} measurement launches, "
          f"wall {wall:.2f}s -> {dest}")


def phase_check(out: str) -> None:
    docs = {}
    for phase in ("cold", "warm"):
        p = os.path.join(out, f"{phase}.json")
        try:
            with open(p) as f:
                docs[phase] = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            fail(f"cannot read {p}: {e}")
    cold, warm = docs["cold"], docs["warm"]
    if warm["picks"] != cold["picks"]:
        diff = {k: (cold["picks"].get(k), warm["picks"].get(k))
                for k in set(cold["picks"]) | set(warm["picks"])
                if cold["picks"].get(k) != warm["picks"].get(k)}
        fail(f"warm picks differ from cold picks: {diff}")
    if warm["wall_s"] >= cold["wall_s"]:
        fail(f"warm resolve ({warm['wall_s']}s) not faster than cold "
             f"tuning ({cold['wall_s']}s) — the cache saves nothing")
    speedup = cold["wall_s"] / max(warm["wall_s"], 1e-9)
    print(f"autotune_ci: check OK — identical picks for "
          f"{len(cold['picks'])} kernels; warm startup {warm['wall_s']}s "
          f"vs cold {cold['wall_s']}s ({speedup:.1f}x faster, "
          f"0 warm measurement launches)")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--phase", required=True,
                   choices=("cold", "warm", "check"))
    p.add_argument("--out", required=True,
                   help="directory for the per-phase record JSONs")
    args = p.parse_args(argv)
    {"cold": phase_cold, "warm": phase_warm,
     "check": phase_check}[args.phase](args.out)


if __name__ == "__main__":
    main()
