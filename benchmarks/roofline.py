"""§Roofline: derive the three roofline terms per (arch × shape) from the
dry-run's compiled artifacts (results/dryrun_all.json).

Terms (seconds, per step, per chip — cost/collective numbers from the
partitioned per-device HLO):

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw                (819 GB/s)
    collective = collective_bytes / link_bw        (~50 GB/s/link ICI)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) over the step's global
tokens; the ratio MODEL_FLOPS / (chips · HLO_FLOPs) shows how much of
the compiled compute is "useful" (catches remat/redundancy waste; >1 is
possible when XLA undercounts fused ops, <1 shows remat or padding).

CPU-backend caveat (recorded in EXPERIMENTS.md): XLA-CPU legalizes bf16
into f32 copies, inflating `bytes accessed` roughly 2× vs a TPU build;
FLOP counts are dtype-independent and transfer as-is.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

# host-CPU roofline for the live-telemetry path (repro.core.streams
# Dispatcher.telemetry()): a conservative per-core AVX2 FMA peak and
# one DDR channel's worth of bandwidth, scaled by visible cores —
# override per machine via from_telemetry(peak_flops=..., mem_bw=...)
CPU_CORE_FLOPS = 32e9    # FLOP/s/core (8-lane f32 FMA @ ~2 GHz)
CPU_CORE_BW = 12e9       # bytes/s/core (shared-bus share)

_COLL_KEYS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")


def cpu_peaks() -> Dict[str, float]:
    cores = os.cpu_count() or 1
    return {"peak_flops": CPU_CORE_FLOPS * cores,
            "mem_bw": CPU_CORE_BW * cores}


def from_telemetry(rows, peak_flops: float = None,
                   mem_bw: float = None) -> list:
    """Roofline rows from the dispatcher's *live* counters
    (``cox.get_dispatcher().telemetry()``) instead of dry-run JSON:
    each stage-key row's op/mem estimates against the host peaks give
    t_compute/t_memory, the dominant term, and — where the row carries
    measured wall time — the achieved fraction of the dominant roof.
    The returned dicts keep the telemetry fields (kernel, backend,
    warp_exec, chunk, launches, gflops) so the bench JSON can embed
    them verbatim."""
    peaks = cpu_peaks()
    pf = peak_flops if peak_flops is not None else peaks["peak_flops"]
    bw = mem_bw if mem_bw is not None else peaks["mem_bw"]
    out = []
    for rec in rows:
        t_comp = rec.get("op_estimate", 0.0) / pf
        t_mem = rec.get("mem_estimate", 0.0) / bw
        dominant = "compute" if t_comp >= t_mem else "memory"
        bound = max(t_comp, t_mem)
        row = dict(rec)
        row.update(t_compute=t_comp, t_memory=t_mem, dominant=dominant,
                   roofline_fraction=(t_comp / bound if bound else 0.0))
        per = rec.get("s_per_launch", 0.0)
        if rec.get("time_basis") == "measured" and per > 0 and bound > 0:
            # achieved share of the dominant roof: 1.0 = running at the
            # machine balance point, ≪1 = far off the roof (overhead,
            # serialization, or a pessimistic estimate — the
            # check_smoke accuracy gate bounds how far)
            row["roof_attained"] = bound / per
        out.append(row)
    return out


def model_flops(rec: Dict[str, Any]) -> float:
    """6·N(_active)·D over the step's global tokens."""
    n = rec.get("active_param_count") or rec.get("param_count") or 0
    shape = rec["shape"]
    tokens = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
              "decode_32k": 128, "long_500k": 1}[shape]
    mult = 6.0 if shape in ("train_4k",) else 2.0  # fwd-only for serving
    if shape == "prefill_32k":
        mult = 6.0  # prefill cell lowers the training graph (fwd+bwd)
    return mult * n * tokens


def terms(rec: Dict[str, Any], chips: int = 256) -> Dict[str, Any]:
    """Prefer the while-aware corrected numbers (scan bodies × trips);
    fall back to raw cost_analysis for old records."""
    coll = rec.get("coll_bytes_corrected")
    if coll is None:
        coll = sum(rec["collectives"].get(k, 0) for k in _COLL_KEYS)
    flops = rec.get("flops_corrected") or rec["flops"]
    mem_bytes = rec.get("out_bytes_corrected")
    if mem_bytes is not None:
        mem_bytes *= 2.0  # outputs ≈ writes; ×2 for the read side
    else:
        mem_bytes = rec["bytes_accessed"]
    t_comp = flops / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = coll / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    useful = mf / (chips * flops) if flops else 0.0
    bound = max(t_comp, t_mem, t_coll)
    frac = t_comp / bound if bound else 0.0  # roofline fraction (compute share)
    return {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "dominant": dominant, "model_flops": mf, "useful": useful,
            "roofline_fraction": frac, "coll_bytes": coll, "flops": flops}


_SUGGEST = {
    "compute": "cast more matmuls to bf16 MXU shapes / cut remat recompute",
    "memory": "raise arithmetic intensity: fuse norms/rope into matmul "
              "epilogues, keep residuals bf16, shrink saved activations",
    "collective": "reshard to cut all-gathers (sequence-parallel residuals),"
                  " overlap DP all-reduce with backward, compress grads",
}


def emit_rows(path: str):
    with open(path) as f:
        recs = json.load(f)
    for rec in recs:
        if rec.get("mesh") != "16x16":
            continue  # roofline table is single-pod per the brief
        name = f"roofline.{rec['arch']}.{rec['shape']}"
        if rec["status"] != "ok":
            print(f"{name},0.0,status={rec['status']}")
            continue
        t = terms(rec)
        us = max(t["t_compute"], t["t_memory"], t["t_collective"]) * 1e6
        print(f"{name},{us:.1f},"
              f"compute_s={t['t_compute']:.3e};mem_s={t['t_memory']:.3e};"
              f"coll_s={t['t_collective']:.3e};dominant={t['dominant']};"
              f"useful={t['useful']:.2f};fix={_SUGGEST[t['dominant']][:40]}",
              flush=True)


def markdown_table(path: str) -> str:
    with open(path) as f:
        recs = json.load(f)
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | MODEL_FLOPS | useful | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        if rec.get("mesh") != "16x16":
            continue
        if rec["status"] == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skipped | — | — | {rec['reason'][:60]} |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"ERROR | — | — | {rec['error'][:60]} |")
            continue
        t = terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t['t_compute']:.3e} | "
            f"{t['t_memory']:.3e} | {t['t_collective']:.3e} | "
            f"{t['dominant']} | {t['model_flops']:.2e} | "
            f"{t['useful']:.2f} | {_SUGGEST[t['dominant']][:48]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    p = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(__file__), "..", "results",
                     "dryrun_all.json")
    print(markdown_table(p))
