"""Shared test configuration.

Registers the ``ci`` hypothesis profile (the default; the CI workflow
also pins ``HYPOTHESIS_PROFILE=ci`` explicitly) so property tests
(test_dim3, test_collectives_property, test_core_property) are
deterministic and bounded on shared runners: fixed example order
(``derandomize``), a capped example count, and no deadline — wall-clock
flakiness on busy runners must not fail the suite.  Set
``HYPOTHESIS_PROFILE=dev`` for a wider randomized local run.
Hypothesis stays optional: without it the property tests importorskip
themselves out.
"""
import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # property tests skip themselves
    settings = None

if settings is not None:
    settings.register_profile(
        "ci",
        derandomize=True,        # fixed seed: same examples every run
        max_examples=25,         # bounded work per property
        deadline=None,           # shared runners stall; no per-example clock
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", max_examples=100)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
