"""Frontend and coverage-gap negative paths: the unsupported feature set
must fail loudly (the paper's ✗ rows), and edge syntax must parse."""
import numpy as np
import pytest

from repro.core import cox
from repro.core.types import CoxUnsupported
from repro.core.oracle import run_grid as oracle_run


def test_break_rejected():
    with pytest.raises(CoxUnsupported, match="break"):
        @cox.kernel
        def k(c, out: cox.Array(cox.f32)):
            for i in range(4):
                break


def test_scalar_param_write_rejected():
    with pytest.raises(CoxUnsupported, match="read-only"):
        @cox.kernel
        def k(c, out: cox.Array(cox.f32), n: cox.i32):
            n = n + 1


def test_chained_compare_rejected():
    with pytest.raises(CoxUnsupported, match="chained"):
        @cox.kernel
        def k(c, out: cox.Array(cox.f32), n: cox.i32):
            i = c.thread_idx()
            if 0 < i < n:
                out[i] = 1.0


def test_dynamic_tile_width_rejected():
    with pytest.raises(CoxUnsupported, match="static"):
        @cox.kernel
        def k(c, out: cox.Array(cox.f32), w: cox.i32):
            v = out[c.thread_idx()]
            _s = c.red_add(v, width=w)


def test_warp_call_nested_in_expression_rejected():
    with pytest.raises(CoxUnsupported, match="sole"):
        @cox.kernel
        def k(c, out: cox.Array(cox.f32)):
            v = out[c.thread_idx()]
            out[c.thread_idx()] = c.shfl_down(v, 1) + 1.0


def test_return_inside_divergence_rejected():
    @cox.kernel
    def k(c, out: cox.Array(cox.f32)):
        if c.thread_idx() < 2:
            return
        out[c.thread_idx()] = 1.0
    with pytest.raises(CoxUnsupported):
        k.compiled(collapse="hier")


# -------- positive edges --------

@cox.kernel
def k_ternary_boolops(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.thread_idx()
    v = a[i]
    r = v * 2.0 if v > 0.0 and i % 2 == 0 else -v
    out[i] = max(r, 0.5) + min(v, 0.0) + abs(v) * 0.1


def test_ternary_and_boolops_match_oracle():
    a = np.random.default_rng(5).normal(size=64).astype(np.float32)
    out0 = np.zeros(64, np.float32)
    ref = oracle_run(k_ternary_boolops.ir, grid=1, block=64, args=(out0, a))
    got = k_ternary_boolops.launch(grid=1, block=64, args=(out0, a))
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                               rtol=1e-5, atol=1e-6)


@cox.kernel
def k_math(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.thread_idx()
    v = abs(a[i]) + 0.5
    out[i] = c.exp(c.log(v)) + c.sqrt(v) * c.rsqrt(v) + c.tanh(v) * 0.0 \
        + c.sigmoid(v) * 0.0 + c.floor(v) * 0.0


def test_math_intrinsics_match_oracle():
    a = np.random.default_rng(6).normal(size=32).astype(np.float32)
    out0 = np.zeros(32, np.float32)
    ref = oracle_run(k_math.ir, grid=1, block=32, args=(out0, a))
    got = k_math.launch(grid=1, block=32, args=(out0, a))
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                               rtol=1e-4, atol=1e-4)


@cox.kernel
def k_ballot(c, out: cox.Array(cox.u32), a: cox.Array(cox.i32)):
    i = c.thread_idx()
    b = c.ballot(a[i] > 0)
    out[i] = b


def test_ballot_bitmask():
    a = np.array([1, -1] * 16, np.int32)
    out0 = np.zeros(32, np.uint32)
    got = k_ballot.launch(grid=1, block=32, args=(out0, a))
    want = sum(1 << i for i in range(0, 32, 2))
    assert (np.asarray(got["out"]) == np.uint32(want)).all()
    ref = oracle_run(k_ballot.ir, grid=1, block=32, args=(out0, a))
    np.testing.assert_array_equal(np.asarray(got["out"]), ref["out"])


@cox.kernel
def k_gridstride(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                 n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    stride = c.grid_dim() * c.block_dim()
    j = i
    while j < n:
        out[j] = a[j] + 1.0
        j = j + stride


def test_grid_stride_loop():
    n = 500
    a = np.arange(512, dtype=np.float32)
    out0 = np.zeros(512, np.float32)
    got = k_gridstride.launch(grid=2, block=64, args=(out0, a, n))
    want = np.where(np.arange(512) < n, a + 1, 0)
    np.testing.assert_allclose(np.asarray(got["out"]), want)
