"""Chaos suite: the CUDA-faithful error model under injected faults.

Semantics under test (README "Error model & fault tolerance"):

* a failed launch surfaces its *typed* ``CoxError`` at its own sync;
* DAG descendants — stream program order, ``Event.wait`` edges,
  ``handle.outputs`` data edges — fail fast with ``CoxDependencyError``
  and are never dispatched on stale inputs, while non-faulting siblings
  stay bitwise-correct;
* streams are poisoned until the error is surfaced (or ``reset()``);
  sticky ``CoxDeviceError`` poisons every enqueue until
  ``device_reset()``; ``get_last_error``/``peek_at_last_error`` follow
  the ``cudaGetLastError`` contract;
* transient failures get a bounded retry-with-backoff; non-transient
  failures on auto knobs walk the degradation ladder (batched→serial,
  vmap→scan) bitwise-correctly; explicit knobs never degrade;
* a per-launch deadline turns a hung launch into ``CoxTimeoutError``;
* captured graphs: a failing node fails the whole replay with the
  node's typed error; a failing fused executable falls back to eager
  replay bitwise-correctly;
* the serving pool isolates a faulting slot;
* errored-request retention stays bounded when handles are dropped.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.core import cox  # noqa: E402
from repro.core import faults  # noqa: E402
from repro.core.errors import (CoxCompileError,  # noqa: E402
                               CoxDependencyError, CoxDeviceError,
                               CoxError, CoxLaunchError, CoxTimeoutError)
from repro.core.streams import Dispatcher, Stream  # noqa: E402


@cox.kernel
def _ft_saxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
              y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


@cox.kernel
def _ft_scale(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = x[i] * 3.0 + 1.0


@cox.kernel
def _ft_warpstage(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    """Shared memory + warp collective + block barrier: auto-resolves
    to backend='vmap', warp_exec='batched' at block=128, so the full
    batched→serial→scan degradation ladder is walkable."""
    tile = c.shared((4,), cox.f32)
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    s = c.red_add(v)
    if c.lane_id() == 0:
        tile[c.warp_id()] = s
    c.syncthreads()
    t = tile[tid % 4]
    out[c.block_idx() * c.block_dim() + tid] = v + t


def _args(n=1024, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    return (np.zeros(n, np.float32), x, y, np.int32(n))


def _fresh(**kw):
    d = Dispatcher(**kw)
    return d, Stream("a", d), Stream("b", d)


def _saxpy_want(args):
    return 2.5 * args[1] + args[2]


def _scale_want(stream, x, n=1024):
    """Bitwise reference for ``_ft_scale``: a clean launch of the same
    kernel (XLA may fuse multiply-add, so a numpy expression is only
    close, not bitwise-equal)."""
    h = stream.launch(_ft_scale, grid=4, block=256,
                      args=(np.zeros(n, np.float32), x, np.int32(n)))
    return np.asarray(h.result()["out"])


# ---------------------------------------------------------------------------
# typed surfacing at the failing request's own sync
# ---------------------------------------------------------------------------


def test_injected_dispatch_fault_is_typed_and_surfaces_at_own_sync():
    d, s1, s2 = _fresh()
    args = _args()
    want = _scale_want(s2, args[1])
    with faults.inject("_ft_saxpy", site="dispatch") as spec:
        bad = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
        good = s2.launch(_ft_scale, grid=4, block=256,
                         args=(np.zeros(1024, np.float32), args[1], 1024))
    assert spec.fired == 1
    # the sibling on the other stream is untouched, bitwise
    np.testing.assert_array_equal(np.asarray(good.result()["out"]), want)
    with pytest.raises(CoxLaunchError, match="injected dispatch fault"):
        bad.result()
    # surfacing reclaimed the bookkeeping and un-poisoned the stream
    assert bad.request.seq not in d._inflight
    assert bad.request.seq not in d._errored
    assert s1.error is None
    # outputs were never produced — nothing dispatched on stale inputs
    assert bad.request.outputs is None


def test_stage_fault_is_cox_compile_error():
    d, s1, _ = _fresh()
    with faults.inject("_ft_saxpy", site="stage"):
        bad = s1.launch(_ft_saxpy, grid=4, block=256, args=_args())
    with pytest.raises(CoxCompileError, match="injected stage fault"):
        bad.result()
    # raising at the sync surfaced it, but last-error persists until
    # get_last_error consumes it (the cudaGetLastError contract)
    assert isinstance(d.get_last_error(), CoxCompileError)
    assert d.peek_at_last_error() is None


# ---------------------------------------------------------------------------
# DAG failure propagation: one test per edge kind
# ---------------------------------------------------------------------------


def test_program_order_descendant_fails_fast():
    d, s1, s2 = _fresh()
    args = _args()
    want = _scale_want(s2, args[1])
    with faults.inject("_ft_saxpy", site="dispatch"):
        bad = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
        dep = s1.launch(_ft_scale, grid=4, block=256,
                        args=(np.zeros(1024, np.float32), args[1], 1024))
        sib = s2.launch(_ft_scale, grid=4, block=256,
                        args=(np.zeros(1024, np.float32), args[1], 1024))
    with pytest.raises(CoxDependencyError) as ei:
        dep.result()
    assert isinstance(ei.value.root, CoxLaunchError)
    # the descendant was failed fast, never dispatched on stale inputs
    assert dep.request.outputs is None
    with pytest.raises(CoxLaunchError):
        bad.result()
    np.testing.assert_array_equal(np.asarray(sib.result()["out"]), want)


def test_event_edge_descendant_fails_fast():
    d, s1, s2 = _fresh()
    args = _args()
    want = _scale_want(s2, args[1])
    sib = s2.launch(_ft_scale, grid=4, block=256,
                    args=(np.zeros(1024, np.float32), args[1], 1024))
    with faults.inject("_ft_saxpy", site="dispatch"):
        bad = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    ev = s1.record_event()
    s2.wait_event(ev)
    dep = s2.launch(_ft_scale, grid=4, block=256,
                    args=(np.zeros(1024, np.float32), args[1], 1024))
    with pytest.raises(CoxDependencyError):
        dep.result()
    assert dep.request.outputs is None
    # the sibling launched before the event edge is bitwise-correct
    np.testing.assert_array_equal(np.asarray(sib.result()["out"]), want)
    with pytest.raises(CoxLaunchError):
        bad.result()


def test_data_edge_descendant_fails_fast_after_timeout():
    """handle.outputs edges: a launch consuming a (later-)timed-out
    producer's outputs fails at its sync with CoxDependencyError."""
    d, s1, s2 = _fresh()
    args = _args()
    want = _scale_want(s2, args[1])
    # the sibling precedes the consumer in s2's program order, so the
    # consumer's dependency failure cannot poison it
    sib = s2.launch(_ft_scale, grid=4, block=256,
                    args=(np.zeros(1024, np.float32), args[1], 1024))
    with faults.inject("_ft_saxpy", site="timeout"):
        prod = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    # dispatch succeeded; the hang is only detected at prod's sync
    cons = s2.launch(_ft_scale, grid=4, block=256,
                     args=(np.zeros(1024, np.float32),
                           prod.outputs["out"], 1024))
    assert prod.request.seq in cons.request.data_deps
    with pytest.raises(CoxTimeoutError):
        s1.synchronize()
    with pytest.raises(CoxDependencyError) as ei:
        cons.result()
    assert isinstance(ei.value.root, CoxTimeoutError)
    np.testing.assert_array_equal(np.asarray(sib.result()["out"]), want)


# ---------------------------------------------------------------------------
# stream poisoning, reset, get_last_error
# ---------------------------------------------------------------------------


def test_unsurfaced_error_poisons_stream_until_reset():
    d, s1, _ = _fresh()
    args = _args()
    with faults.inject("_ft_saxpy", site="dispatch"):
        bad = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    del bad                               # handle dropped, never surfaced
    assert isinstance(s1.error, CoxLaunchError)
    poisoned = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    with pytest.raises(CoxDependencyError):
        poisoned.result()
    s1.reset()
    assert s1.error is None
    ok = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    np.testing.assert_allclose(np.asarray(ok.result()["out"]),
                               _saxpy_want(args), rtol=1e-5, atol=1e-6)


def test_get_last_error_returns_and_clears():
    d, s1, _ = _fresh()
    with faults.inject("_ft_saxpy", site="dispatch"):
        s1.launch(_ft_saxpy, grid=4, block=256, args=_args())
    err = d.peek_at_last_error()
    assert isinstance(err, CoxLaunchError)
    assert d.peek_at_last_error() is err       # peek never clears
    assert d.get_last_error() is err           # get returns...
    assert d.get_last_error() is None          # ...and clears
    assert s1.error is None                    # consuming = surfacing
    ok = s1.launch(_ft_saxpy, grid=4, block=256, args=_args())
    np.testing.assert_allclose(np.asarray(ok.result()["out"]),
                               _saxpy_want(_args()), rtol=1e-5, atol=1e-6)


def test_sticky_device_error_poisons_until_device_reset():
    d, s1, s2 = _fresh()
    args = _args()
    with faults.inject("_ft_saxpy", site="sticky-device"):
        bad = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    with pytest.raises(CoxDeviceError):
        bad.result()
    # sticky: every subsequent enqueue fails synchronously, any stream
    with pytest.raises(CoxDeviceError):
        s2.launch(_ft_scale, grid=4, block=256,
                  args=(np.zeros(1024, np.float32), args[1], 1024))
    # sticky errors are returned but never cleared by get_last_error
    assert isinstance(d.get_last_error(), CoxDeviceError)
    assert isinstance(d.get_last_error(), CoxDeviceError)
    with pytest.raises(CoxDeviceError):
        s1.synchronize()
    d.device_reset()
    assert d.peek_at_last_error() is None
    ok = s2.launch(_ft_saxpy, grid=4, block=256, args=args)
    np.testing.assert_allclose(np.asarray(ok.result()["out"]),
                               _saxpy_want(args), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# per-launch deadline (watchdog wiring)
# ---------------------------------------------------------------------------


def test_deadline_turns_hang_into_timeout_and_recovers():
    d, s1, _ = _fresh(launch_deadline_s=0.05)
    args = _args()
    with faults.inject("_ft_saxpy", site="timeout"):
        hung = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    with pytest.raises(CoxTimeoutError, match="deadline"):
        hung.result()
    assert d.timeouts == 1
    assert d.watchdog is not None and d.watchdog.strikes == 1
    # a healthy launch under the same deadline completes and clears the
    # strike count (consecutive-straggler semantics)
    ok = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    np.testing.assert_allclose(np.asarray(ok.result()["out"]),
                               _saxpy_want(args), rtol=1e-5, atol=1e-6)
    assert d.watchdog.strikes == 0


# ---------------------------------------------------------------------------
# retry (transient) + degradation ladder
# ---------------------------------------------------------------------------


def test_transient_fault_cleared_by_bounded_retry():
    d, s1, _ = _fresh()
    args = _args()
    with faults.inject("_ft_saxpy", site="dispatch", transient=True,
                       times=2) as spec:
        h = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
        np.testing.assert_allclose(np.asarray(h.result()["out"]),
                                   _saxpy_want(args), rtol=1e-5, atol=1e-6)
    assert spec.fired == 2
    assert d.retries == 2
    assert d.degradations == 0            # retry is not a ladder rung
    assert d.failures == 0


def test_transient_retry_exhaustion_surfaces_the_error():
    d, s1, _ = _fresh()
    with faults.inject("_ft_saxpy", site="dispatch", transient=True,
                       times=None):      # fires on every attempt
        h = s1.launch(_ft_saxpy, grid=4, block=256, args=_args())
        with pytest.raises(CoxLaunchError):
            h.result()
    assert d.retries == d.retry_limit


def _ws_args(seed=3):
    a = np.random.default_rng(seed).integers(-8, 9, 256).astype(np.float32)
    return (np.zeros(256, np.float32), a)


def test_ladder_batched_to_serial_is_bitwise():
    d, s1, _ = _fresh()
    args = _ws_args()
    want = np.asarray(
        s1.launch(_ft_warpstage, grid=2, block=128,
                  args=args).result()["out"])
    assert d.degradations == 0            # clean run: no fallback
    with faults.inject("_ft_warpstage", site="dispatch", times=1):
        h = s1.launch(_ft_warpstage, grid=2, block=128, args=args)
        got = np.asarray(h.result()["out"])
    np.testing.assert_array_equal(got, want)
    assert d.degradations == 1
    ev = d.degradation_log[-1]
    assert ev["from"] == "as-resolved" and ev["to"] == "warp_exec=serial"
    assert d.failures == 0                # the launch ultimately succeeded


def test_ladder_walks_to_scan_when_serial_also_fails():
    d, s1, _ = _fresh()
    args = _ws_args(seed=4)
    want = np.asarray(
        s1.launch(_ft_warpstage, grid=2, block=128,
                  args=args).result()["out"])
    with faults.inject("_ft_warpstage", site="dispatch", times=2):
        h = s1.launch(_ft_warpstage, grid=2, block=128, args=args)
        got = np.asarray(h.result()["out"])
    np.testing.assert_array_equal(got, want)
    assert d.degradations == 2
    assert [e["to"] for e in list(d.degradation_log)[-2:]] == \
        ["warp_exec=serial", "backend=scan"]


def test_explicit_knobs_never_degrade():
    d, s1, _ = _fresh()
    args = _ws_args(seed=5)
    with faults.inject("_ft_warpstage", site="dispatch", times=1):
        h = s1.launch(_ft_warpstage, grid=2, block=128, args=args,
                      backend="vmap", warp_exec="batched")
        with pytest.raises(CoxLaunchError):
            h.result()
    assert d.degradations == 0


# ---------------------------------------------------------------------------
# graphs: node-typed staging errors + replay → eager fallback
# ---------------------------------------------------------------------------


def test_graph_node_stage_fault_fails_replay_with_node_error():
    d, s1, _ = _fresh()
    g = cox.Graph(name="ft-graph-stage")
    args = _args()
    with g.capture(s1):
        h0 = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
        s1.launch(_ft_scale, grid=4, block=256,
                  args=(np.zeros(1024, np.float32), h0.outputs["out"], 1024))
    with faults.inject("_ft_scale", site="stage"):
        with pytest.raises(CoxCompileError, match="injected stage fault"):
            g.replay()


def test_graph_replay_falls_back_to_eager_bitwise():
    d, s1, _ = _fresh()
    g = cox.Graph(name="ft-graph-replay")
    args = _args(seed=7)
    with g.capture(s1):
        h0 = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
        s1.launch(_ft_scale, grid=4, block=256,
                  args=(np.zeros(1024, np.float32), h0.outputs["out"], 1024))
    exe = g.instantiate()
    want = {k: np.asarray(v) for k, v in exe.replay().items()}
    with faults.inject("ft-graph-replay", site="dispatch", times=1) as spec:
        got = {k: np.asarray(v) for k, v in exe.replay().items()}
    assert spec.fired == 1
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    assert d.degradations == 1
    ev = d.degradation_log[-1]
    assert ev["from"] == "graph-replay" and ev["to"] == "eager"
    # a user error (unknown binding) is never swallowed by the fallback
    with pytest.raises(KeyError):
        exe.replay(nope=np.zeros(4, np.float32))


# ---------------------------------------------------------------------------
# serving pool: slot isolation
# ---------------------------------------------------------------------------


def test_request_pool_isolates_faulting_slot():
    from repro.launch.serve import RequestKernelPool
    pool = RequestKernelPool(3, nbins=8)
    with faults.inject("_token_hist", site="dispatch", index=0, times=1):
        pool.submit(0, [1, 2, 3])         # this one is forced to fail
        pool.submit(1, [4, 4, 4, 4])
        pool.submit(2, [5, 6])
        hists = pool.collect()
    assert pool.health["submitted"] == 3
    assert pool.health["failed"] == 1 and pool.health["failed_slots"] == [0]
    assert pool.health["completed"] == 2 and len(hists) == 2
    np.testing.assert_array_equal(
        hists[0], np.bincount(np.array([4, 4, 4, 4]) % 8, minlength=8))
    np.testing.assert_array_equal(
        hists[1], np.bincount(np.array([5, 6]) % 8, minlength=8))
    assert pool.ok_tokens == 6
    # the faulted slot's stream was reset — it serves the next request
    pool.submit(0, [7])
    assert np.asarray(pool.handles[-1].result()["hist"]).sum() == 1
    cox.get_last_error()     # drain the default dispatcher's last-error


# ---------------------------------------------------------------------------
# bounded retention (the _inflight leak regression)
# ---------------------------------------------------------------------------


def test_errored_retention_stays_bounded_under_repeated_failures():
    d, s1, _ = _fresh(error_log_max=8)
    with faults.inject("_ft_saxpy", site="stage", times=None):
        for _ in range(40):
            s1.launch(_ft_saxpy, grid=4, block=256, args=_args())
            # handle dropped every iteration — never synced
    assert len(d._errored) <= 8
    assert not d._pending
    assert all(r.error is None for r in d._inflight.values())
    assert d.health()["errored_retained"] <= 8
    assert d.failures == 40
    # the retained tail is still surfaced via get_last_error
    assert isinstance(d.get_last_error(),
                      (CoxCompileError, CoxDependencyError))
    assert d.get_last_error() is None


def test_fault_scope_ends_with_the_context():
    d, s1, _ = _fresh()
    args = _args(seed=9)
    with faults.inject("_ft_saxpy", site="dispatch"):
        pass                              # armed and disarmed, never hit
    assert faults.active() == []
    h = s1.launch(_ft_saxpy, grid=4, block=256, args=args)
    np.testing.assert_allclose(np.asarray(h.result()["out"]),
                               _saxpy_want(args), rtol=1e-5, atol=1e-6)
    assert d.failures == 0


def test_typed_hierarchy_is_exported():
    for cls in (CoxError, CoxCompileError, CoxLaunchError, CoxTimeoutError,
                CoxDependencyError, CoxDeviceError):
        assert getattr(cox, cls.__name__) is cls
    assert cox.faults is faults
    assert callable(cox.get_last_error)
    assert callable(cox.peek_at_last_error)
    assert callable(cox.device_reset)
    assert issubclass(CoxDeviceError, CoxError) and CoxDeviceError.sticky
