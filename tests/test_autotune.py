"""Autotune engine + cost model + dispatcher telemetry.

Contracts under test (README "Autotune & telemetry"):

* chunk resolution is a single resolved field: ``ResolvedLaunch.chunk``
  + ``chunk_source`` ('explicit' | 'heuristic' | 'cooperative' |
  'autotuned'), and the autotuner may only move knobs whose source is
  'heuristic'/'auto' — an explicit ``chunk=``/``backend=``/
  ``warp_exec=`` is never overridden (the regression the resolver
  refactor exists to prevent);
* tuned launches are bitwise-equal to heuristic launches, the winner is
  persisted (version-stamped, atomic), and a warm lookup — in-memory or
  from disk in a simulated fresh process — issues ZERO measurement
  launches;
* cache robustness: corrupt/truncated/stale-version files degrade to
  heuristics without crashing, concurrent writers never torch the file
  (atomic rename + read-merge), ``COX_AUTOTUNE_CACHE=off`` keeps disk
  untouched;
* the cost model returns positive op/mem estimates in both 'static'
  and 'xla' modes, and the footprint model scales with chunk;
* the dispatcher records per-stage-key telemetry rows and surfaces the
  autotune counters through ``health()``.
"""
import dataclasses
import json
import os
import threading

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.core import cox  # noqa: E402
from repro.core import autotune as at  # noqa: E402
from repro.core import costmodel  # noqa: E402
from repro.core import runtime as rt  # noqa: E402
from repro.core.backends.plan import DEFAULT_CHUNK  # noqa: E402
from repro.core.types import CoxUnsupported  # noqa: E402


@cox.kernel
def _atSaxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
             y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.0 * x[i] + y[i]


@cox.kernel
def _atGridSum(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32)):
    s = c.shared(32, cox.f32)
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    s[c.thread_idx()] = x[i]
    c.syncthreads()
    if c.thread_idx() == 0:
        acc = 0.0
        for j in range(32):
            acc = acc + s[j]
        out[c.block_idx()] = acc


GRID, BLOCK = 16, 64
N = GRID * BLOCK


def _args():
    x = np.arange(N, dtype=np.float32) / N
    y = np.ones(N, np.float32)
    return (np.zeros(N, np.float32), x, y, N)


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    """Isolated autotune state: fresh counters, a tmp cache file."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(at.ENV_CACHE, str(cache))
    monkeypatch.delenv(at.ENV_ENABLE, raising=False)
    at.reset()
    yield cache
    at.reset()


# ---------------------------------------------------------------------------
# chunk resolution: one resolved field, explicit never overridden
# ---------------------------------------------------------------------------

class TestChunkResolution:
    def test_heuristic_default(self):
        ck = _atSaxpy.compiled(block=BLOCK)
        val, src = rt.resolve_chunk(ck, GRID, None)
        assert (val, src) == (min(GRID, DEFAULT_CHUNK), "heuristic")
        val, src = rt.resolve_chunk(ck, GRID, "auto")
        assert (val, src) == (min(GRID, DEFAULT_CHUNK), "heuristic")

    def test_explicit(self):
        ck = _atSaxpy.compiled(block=BLOCK)
        assert rt.resolve_chunk(ck, GRID, 3) == (3, "explicit")
        # clamped to the grid but still explicit
        assert rt.resolve_chunk(ck, GRID, 999) == (GRID, "explicit")
        with pytest.raises(ValueError):
            rt.resolve_chunk(ck, GRID, 0)

    def test_resolved_launch_carries_source(self):
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    chunk=5)
        assert req.rl.chunk == 5
        assert req.rl.chunk_source == "explicit"
        assert req.chunk == 5  # the request mirrors the resolved value
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args())
        assert req.rl.chunk == min(GRID, DEFAULT_CHUNK)
        assert req.rl.chunk_source == "heuristic"

    def test_explicit_never_autotuned(self, tuner):
        """Regression: an explicit chunk= survives autotune=True."""
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    chunk=5, autotune=True)
        assert req.rl.chunk == 5
        assert req.rl.chunk_source == "explicit"

    def test_explicit_backend_never_autotuned(self, tuner):
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    backend="scan", warp_exec="serial",
                                    chunk=5, autotune=True)
        # nothing tunable: the tuner must not even measure
        assert req.rl.backend == "scan"
        assert req.rl.warp_exec == "serial"
        assert req.rl.chunk == 5
        assert at.stats()["measurements"] == 0

    def test_tuned_source_marked(self, tuner):
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    autotune=True)
        # whatever won, the knobs must be legal and the source recorded
        assert req.rl.backend in ("scan", "vmap")
        assert req.rl.chunk >= 1
        if req.rl.chunk_source == "autotuned":
            assert at.stats()["tuned"] >= 1


# ---------------------------------------------------------------------------
# tuning correctness + persistence
# ---------------------------------------------------------------------------

class TestTune:
    def test_cold_tune_writes_cache(self, tuner):
        out = _atSaxpy.launch(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        want = 2.0 * np.arange(N, dtype=np.float32) / N + 1.0
        np.testing.assert_allclose(np.asarray(out["out"]), want, rtol=1e-6)
        st = at.stats()
        assert st["misses"] == 1
        assert st["measurements"] > 0
        assert st["disk_writes"] == 1
        doc = json.loads(tuner.read_text())
        assert doc["version"] == at.AUTOTUNE_VERSION
        assert len(doc["entries"]) == 1
        rec = next(iter(doc["entries"].values()))
        assert rec["backend"] in ("scan", "vmap")
        assert rec["chunk"] >= 1
        assert rec["op_estimate"] > 0
        assert rec["mem_estimate"] > 0

    def test_warm_memory_hit(self, tuner):
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        n = at.stats()["measurements"]
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    autotune=True)
        st = at.stats()
        assert st["hits"] == 1
        assert st["measurements"] == n  # zero new launches
        assert req.rl.chunk >= 1

    def test_warm_disk_hit_fresh_process(self, tuner):
        req1 = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                     autotune=True)
        cold = at.stats()["measurements"]
        at.reset(memory_only=True)  # simulated fresh process, disk intact
        req2 = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                     autotune=True)
        st = at.stats()
        assert st["disk_hits"] == 1
        assert st["measurements"] == cold  # zero NEW measurement launches
        assert (req2.rl.backend, req2.rl.warp_exec, req2.rl.chunk) == \
            (req1.rl.backend, req1.rl.warp_exec, req1.rl.chunk)

    def test_bitwise_equal_grid_sum(self, tuner):
        x = np.random.default_rng(0).random(8 * 32).astype(np.float32)
        args = (np.zeros(8, np.float32), x)
        base = _atGridSum.launch(grid=8, block=32, args=args)
        tuned = _atGridSum.launch(grid=8, block=32, args=args,
                                  autotune=True)
        np.testing.assert_array_equal(np.asarray(tuned["out"]),
                                      np.asarray(base["out"]))

    def test_heuristic_cell_always_candidate(self, tuner):
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        rec = next(iter(at.entries().values()))
        rl = rt.resolve_launch(_atSaxpy.compiled(block=BLOCK), grid=GRID,
                               block=BLOCK)
        heur = "%s/%s/c%d" % (rl.backend, rl.warp_exec, rl.chunk)
        assert heur in rec["times_us"], \
            f"heuristic cell {heur} missing from {sorted(rec['times_us'])}"

    def test_env_enable_tunes_all_auto(self, tuner, monkeypatch):
        monkeypatch.setenv(at.ENV_ENABLE, "1")
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args())
        assert at.stats()["misses"] == 1


# ---------------------------------------------------------------------------
# cache robustness
# ---------------------------------------------------------------------------

class TestCacheRobustness:
    def test_corrupt_cache_falls_back(self, tuner):
        tuner.write_text("{not json at all")
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    autotune=True)
        assert req.rl.chunk >= 1  # no crash, tuning proceeded
        st = at.stats()
        assert st["load_errors"] >= 1
        # and the bad file was replaced with a valid one
        doc = json.loads(tuner.read_text())
        assert doc["version"] == at.AUTOTUNE_VERSION

    def test_truncated_cache_falls_back(self, tuner):
        # a valid doc chopped mid-way (torn write from a dead process)
        at.reset()
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        whole = tuner.read_text()
        tuner.write_text(whole[: len(whole) // 2])
        at.reset()
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                                    autotune=True)
        st = at.stats()
        assert st["load_errors"] >= 1
        assert st["misses"] == 1  # re-measured, no crash
        assert req.rl.chunk >= 1

    def test_stale_version_invalidates(self, tuner):
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        doc = json.loads(tuner.read_text())
        doc["version"] = at.AUTOTUNE_VERSION - 1
        tuner.write_text(json.dumps(doc))
        at.reset()
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        st = at.stats()
        assert st["disk_hits"] == 0
        assert st["misses"] == 1  # stale stamp -> wholesale re-measure

    def test_wrong_shape_entries_tolerated(self, tuner):
        tuner.write_text(json.dumps(
            {"version": at.AUTOTUNE_VERSION, "entries": ["not", "a", "map"]}))
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        assert at.stats()["load_errors"] >= 1

    def test_concurrent_writers_atomic(self, tuner):
        """N threads save disjoint records; the file must stay valid
        JSON and (read-merge) retain every record."""
        recs = {f"key-{i}": {"backend": "scan", "warp_exec": "serial",
                             "chunk": i + 1} for i in range(16)}
        errs = []

        def save(k):
            try:
                at._save_disk(str(tuner), {k: recs[k]})
            except Exception as e:  # pragma: no cover - the failure mode
                errs.append(e)

        threads = [threading.Thread(target=save, args=(k,)) for k in recs]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        doc = json.loads(tuner.read_text())  # never torn
        assert doc["version"] == at.AUTOTUNE_VERSION
        # atomic rename means a racer can lose an update but never
        # corrupt: whatever survives is a valid subset of what was
        # written, and at least the last replace's view is complete
        assert set(doc["entries"]) <= set(recs)
        assert doc["entries"]
        for k, v in doc["entries"].items():
            assert v == recs[k]

    def test_cache_off_env(self, tuner, monkeypatch):
        monkeypatch.setenv(at.ENV_CACHE, "off")
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        st = at.stats()
        assert st["misses"] == 1
        assert st["disk_writes"] == 0
        assert at.cache_path() is None
        assert not tuner.exists()

    def test_no_leftover_temp_files(self, tuner):
        _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args(),
                              autotune=True)
        stray = [p for p in os.listdir(tuner.parent)
                 if p.startswith(".autotune-")]
        assert stray == []


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_static_estimate_positive(self):
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args())
        est = costmodel.estimate(req.ck, req.rl, req.shapes, mode="static")
        assert est.source == "static"
        assert est.op_estimate > 0
        assert est.mem_estimate > 0
        assert est.gflops(1.0) == pytest.approx(est.op_estimate / 1e9)
        assert est.gflops(0.0) == 0.0

    def test_xla_estimate_positive(self):
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args())
        est = costmodel.estimate(req.ck, req.rl, req.shapes, mode="xla")
        assert est.source == "xla"
        assert est.op_estimate > 0
        assert est.mem_estimate > 0

    def test_estimate_cached(self):
        req = _atSaxpy.make_request(grid=GRID, block=BLOCK, args=_args())
        a = costmodel.estimate(req.ck, req.rl, req.shapes, mode="static")
        b = costmodel.estimate(req.ck, req.rl, req.shapes, mode="static")
        assert a is b

    def test_footprint_scales_with_chunk(self):
        req = _atGridSum.make_request(grid=8, block=32,
                                      args=(np.zeros(8, np.float32),
                                            np.zeros(8 * 32, np.float32)))
        f4 = costmodel.chunk_footprint(req.ck, req.shapes, chunk=4,
                                       n_warps=1)
        f8 = costmodel.chunk_footprint(req.ck, req.shapes, chunk=8,
                                       n_warps=1)
        assert f8 == 2 * f4 > 0
        # the batched plane replicates shared memory per warp
        fb = costmodel.chunk_footprint(req.ck, req.shapes, chunk=4,
                                       n_warps=2, warp_exec="batched")
        assert fb > f4

    def test_kernel_features_shared(self):
        shared, peels, density = costmodel.kernel_features(
            _atGridSum.compiled(block=32))
        assert shared == 32 * 4  # 32 f32 slots
        assert peels >= 0
        assert 0.0 <= density <= 1.0

    def test_telemetry_mode_env(self, monkeypatch):
        monkeypatch.delenv(costmodel.ENV_MODE, raising=False)
        assert costmodel.telemetry_mode() == "static"
        monkeypatch.setenv(costmodel.ENV_MODE, "xla")
        assert costmodel.telemetry_mode() == "xla"
        monkeypatch.setenv(costmodel.ENV_MODE, "garbage")
        assert costmodel.telemetry_mode() == "static"


# ---------------------------------------------------------------------------
# dispatcher telemetry + health
# ---------------------------------------------------------------------------

class TestTelemetry:
    def test_rows_recorded(self):
        from repro.core.streams import Dispatcher
        d = Dispatcher()
        s = cox.Stream("telemetry-test", dispatcher=d)
        h = s.launch(_atSaxpy, grid=GRID, block=BLOCK, args=_args())
        h.result()
        rows = d.telemetry()
        assert len(rows) == 1
        row = rows[0]
        assert row["kernel"] == "_atSaxpy"
        assert row["launches"] == 1
        assert row["chunk"] >= 1
        assert row["chunk_source"] in ("heuristic", "explicit",
                                       "cooperative", "autotuned")
        assert row["op_estimate"] > 0
        assert row["mem_estimate"] > 0
        assert row["estimate_source"] in ("static", "xla")
        # dispatch timing is host-side and always present
        assert row["time_basis"] in ("dispatch", "measured")
        assert row["s_per_launch"] > 0

    def test_health_carries_autotune_and_telemetry(self):
        from repro.core.streams import Dispatcher
        d = Dispatcher()
        s = cox.Stream("health-test", dispatcher=d)
        s.launch(_atSaxpy, grid=GRID, block=BLOCK, args=_args()).result()
        h = d.health()
        assert h["telemetry_keys"] == 1
        assert h["dispatch_s"] > 0
        assert h["bytes"] > 0
        assert isinstance(h["autotune"], dict)
        assert set(h["autotune"]) >= {"hits", "misses", "measurements"}

    def test_roofline_from_telemetry(self):
        from benchmarks.roofline import from_telemetry
        from repro.core.streams import Dispatcher
        d = Dispatcher()
        s = cox.Stream("roofline-test", dispatcher=d)
        s.launch(_atSaxpy, grid=GRID, block=BLOCK, args=_args()).result()
        rows = from_telemetry(d.telemetry(), peak_flops=1e9, mem_bw=1e9)
        assert len(rows) == 1
        r = rows[0]
        assert r["dominant"] in ("compute", "memory")
        assert r["t_compute"] > 0 and r["t_memory"] > 0
        assert 0.0 <= r["roofline_fraction"] <= 1.0


# ---------------------------------------------------------------------------
# cooperative launches pin the chunk
# ---------------------------------------------------------------------------

@cox.kernel
def _atGridSync(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    out[i] = x[i] * 2.0
    c.grid_sync()
    out[i] = out[i] + 1.0


class TestCooperative:
    def test_chunk_pinned_to_grid(self):
        n = 4 * 32
        req = _atGridSync.make_request(
            grid=4, block=32, args=(np.zeros(n, np.float32),
                                    np.ones(n, np.float32)))
        assert req.rl.chunk == 4
        assert req.rl.chunk_source == "cooperative"

    def test_explicit_small_chunk_rejected(self):
        n = 4 * 32
        with pytest.raises(CoxUnsupported):
            _atGridSync.make_request(
                grid=4, block=32, chunk=2,
                args=(np.zeros(n, np.float32), np.ones(n, np.float32)))

    def test_autotune_respects_cooperative(self, tuner):
        n = 4 * 32
        req = _atGridSync.make_request(
            grid=4, block=32, autotune=True,
            args=(np.zeros(n, np.float32), np.ones(n, np.float32)))
        assert req.rl.chunk == 4
        assert req.rl.chunk_source == "cooperative"
