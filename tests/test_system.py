"""End-to-end behaviour tests for the whole system."""


def test_quickstart_example():
    import examples.quickstart as q
    q.main()


def test_cuda_migration_example():
    import examples.cuda_migration as m
    m.main()


def test_three_way_kernel_agreement():
    import examples.cox_kernels_in_models as k
    k.main()


def test_serve_batched_end_to_end():
    from repro.launch.serve import serve_requests
    out = serve_requests("mamba2-130m-smoke", batch=2, ctx=64,
                         n_requests=3, max_tokens=8)
    assert out["completed"] >= 3
    assert out["tokens"] > 0


def test_serve_graph_replay_matches_eager():
    """--graph captures the per-token stats pipeline once and replays
    it every decode step; serve_requests itself asserts the replayed
    statistics are bitwise-equal to a shadow eager pipeline."""
    from repro.launch.serve import serve_requests
    out = serve_requests("mamba2-130m-smoke", batch=2, ctx=64,
                         n_requests=2, max_tokens=6, graph=True)
    assert out["graph"]["replayed"]
    assert out["graph"]["steps"] > 1          # captured once, replayed
    assert out["graph"]["hist_tokens"] == out["tokens"]


def test_batched_prefill_matches_token_by_token():
    """prefill_prompt consumes the whole prompt in one scanned call;
    the resulting decode output must be identical to stepping the
    prompt through the decode path one token at a time."""
    import numpy as np
    from repro.launch.serve import BatchedServer

    prompts = {0: [5, 9, 2, 7], 1: [11, 3, 8, 1]}

    def reference(server):
        """The old prefill: one jitted decode dispatch per token."""
        for slot, prompt in prompts.items():
            server.pos[slot] = 0
            server.outputs[slot] = []
            server.active[slot] = True
            for t in prompt:
                server.tokens[slot] = t
                server._step_all()
            server.tokens[slot] = prompt[-1]
        return server.decode(8)

    def batched(server):
        for slot, prompt in prompts.items():
            server.prefill_prompt(slot, prompt)
        return server.decode(8)

    a = BatchedServer("mamba2-130m-smoke", batch=2, ctx=64, seed=3)
    b = BatchedServer("mamba2-130m-smoke", batch=2, ctx=64, seed=3)
    ref, new = reference(a), batched(b)
    assert ref == new                          # exact token match
    assert all(len(o) > 0 for o in new)
    assert np.array_equal(a.pos, b.pos)


def test_dryrun_single_cell_smoke():
    """The dry-run path works in-process on the 1-device platform when
    pointed at a tiny mesh (full 512-dev runs happen via the module CLI,
    which sets XLA_FLAGS before jax init)."""
    import jax
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.parallel import steps as steps_mod
    from repro.launch.hlo_analysis import analyze

    cfg = registry.get("granite-20b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 64, 2, "train")
    jitted, bundle, abstract = steps_mod.jit_train_step(cfg, mesh, shape)
    compiled = jitted.lower(*abstract).compile()
    assert compiled.memory_analysis().temp_size_in_bytes >= 0
    stats = analyze(compiled.as_text())
    assert stats["flops"] > 0
