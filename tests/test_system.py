"""End-to-end behaviour tests for the whole system."""


def test_quickstart_example():
    import examples.quickstart as q
    q.main()


def test_cuda_migration_example():
    import examples.cuda_migration as m
    m.main()


def test_three_way_kernel_agreement():
    import examples.cox_kernels_in_models as k
    k.main()


def test_serve_batched_end_to_end():
    from repro.launch.serve import serve_requests
    out = serve_requests("mamba2-130m-smoke", batch=2, ctx=64,
                         n_requests=3, max_tokens=8)
    assert out["completed"] >= 3
    assert out["tokens"] > 0


def test_dryrun_single_cell_smoke():
    """The dry-run path works in-process on the 1-device platform when
    pointed at a tiny mesh (full 512-dev runs happen via the module CLI,
    which sets XLA_FLAGS before jax init)."""
    import jax
    from repro.configs import registry
    from repro.configs.base import ShapeConfig
    from repro.parallel import steps as steps_mod
    from repro.launch.hlo_analysis import analyze

    cfg = registry.get("granite-20b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 64, 2, "train")
    jitted, bundle, abstract = steps_mod.jit_train_step(cfg, mesh, shape)
    compiled = jitted.lower(*abstract).compile()
    assert compiled.memory_analysis().temp_size_in_bytes >= 0
    stats = analyze(compiled.as_text())
    assert stats["flops"] > 0
