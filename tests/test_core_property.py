"""Property-based tests (hypothesis): the compiled executor agrees with
the independent per-thread oracle on randomized kernels and inputs, and
system invariants hold across modes."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cox  # noqa: E402
from repro.core.oracle import run_grid as oracle_run  # noqa: E402

# profile selection lives in tests/conftest.py (HYPOTHESIS_PROFILE)


# --- kernels exercised by the properties -----------------------------------

@cox.kernel
def k_arith(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
            b: cox.Array(cox.f32), alpha: cox.f32, n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        x = a[i] * alpha + b[i]
        if x > 0.0:
            x = x * 2.0
        else:
            x = 0.0 - x
        j = 0
        while j < i % 4:
            x = x + 1.0
            j = j + 1
        out[i] = x


@cox.kernel
def k_warp_mix(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    s = c.red_add(v)                       # warp sum
    m = c.red_max(v)                       # warp max
    d = c.shfl_xor(v, 1)                   # butterfly exchange
    anyneg = c.vote_any(v < 0.0)
    r = s + m + d + c.select(anyneg, 1.0, 0.0)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_shared(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tile = c.shared((64,), cox.f32)
    tid = c.thread_idx()
    tile[tid] = a[c.block_idx() * c.block_dim() + tid]
    c.syncthreads()
    out[c.block_idx() * c.block_dim() + tid] = \
        tile[(tid + 1) % c.block_dim()]


@cox.kernel
def k_atomic(c, hist: cox.Array(cox.f32), a: cox.Array(cox.i32),
             n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, a[i], 1.0)


floats = st.lists(st.floats(-4, 4, allow_nan=False, width=32),
                  min_size=128, max_size=128)


@given(floats, floats, st.floats(-2, 2, allow_nan=False, width=32),
       st.integers(1, 128),
       st.sampled_from(["jit", "normal"]))
def test_arith_matches_oracle(av, bv, alpha, n, mode):
    a = np.asarray(av, np.float32)
    b = np.asarray(bv, np.float32)
    out0 = np.zeros(128, np.float32)
    ref = oracle_run(k_arith.ir, grid=2, block=64,
                     args=(out0, a, b, np.float32(alpha), n))
    got = k_arith.launch(grid=2, block=64,
                         args=(out0, a, b, alpha, n), mode=mode)
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                               rtol=1e-5, atol=1e-5)


@given(floats, st.booleans())
def test_warp_collectives_match_oracle(av, simd):
    a = np.asarray(av, np.float32)
    out0 = np.zeros(128, np.float32)
    ref = oracle_run(k_warp_mix.ir, grid=2, block=64, args=(out0, a))
    got = k_warp_mix.launch(grid=2, block=64, args=(out0, a), simd=simd)
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                               rtol=1e-4, atol=1e-4)


@given(floats)
def test_shared_memory_rotation(av):
    a = np.asarray(av, np.float32)
    out0 = np.zeros(128, np.float32)
    got = k_shared.launch(grid=2, block=64, args=(out0, a))
    want = a.reshape(2, 64)[:, list(range(1, 64)) + [0]].reshape(-1)
    np.testing.assert_allclose(np.asarray(got["out"]), want)


@given(st.lists(st.integers(0, 15), min_size=96, max_size=96))
def test_atomic_histogram(idxs):
    a = np.asarray(idxs, np.int32)
    hist0 = np.zeros(16, np.float32)
    got = k_atomic.launch(grid=3, block=32, args=(hist0, a, 96))
    want = np.bincount(a, minlength=16).astype(np.float32)
    np.testing.assert_allclose(np.asarray(got["hist"]), want)


@given(st.integers(1, 4), st.integers(1, 8))
def test_partial_last_warp(grid, rem):
    """block sizes that are not multiples of warpSize still compute
    correctly (masked last warp)."""
    block = 32 + rem
    n = grid * block
    a = np.arange(n, dtype=np.float32)
    b = np.ones(n, np.float32)
    out0 = np.zeros(n, np.float32)
    ref = oracle_run(k_arith.ir, grid=grid, block=block,
                     args=(out0, a, b, np.float32(1.0), n))
    got = k_arith.launch(grid=grid, block=block,
                         args=(out0, a, b, 1.0, n))
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                               rtol=1e-5)


@given(st.sampled_from([2, 4, 8, 16, 32]), floats)
def test_tile_widths(width, av):
    """Static cooperative-group tiles of every power-of-two width."""
    a = np.asarray(av, np.float32)

    # kernels must be defined at module scope for inspect; parametrize
    # via the width-specific kernel map below.
    kern = _TILE_KERNELS[width]
    out0 = np.zeros(128, np.float32)
    ref = oracle_run(kern.ir, grid=2, block=64, args=(out0, a))
    got = kern.launch(grid=2, block=64, args=(out0, a))
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                               rtol=1e-4, atol=1e-4)


def _make_tile_kernel(width):
    @cox.kernel(name=f"tile_{width}")
    def k(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
        tid = c.thread_idx()
        v = a[c.block_idx() * c.block_dim() + tid]
        s = c.red_add(v, width=width)
        out[c.block_idx() * c.block_dim() + tid] = s
    return k


_TILE_KERNELS = {w: _make_tile_kernel(w) for w in (2, 4, 8, 16, 32)}
