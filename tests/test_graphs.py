"""CUDA graphs: stream capture → instantiate → replay (cox.Graph).

The load-bearing property is bitwise equivalence: a captured-then-
replayed schedule must produce exactly the arrays the eager stream
schedule produces — across backends and warp-exec modes — because the
execution model is functional (values flow between launches only
through explicit output refs), so fusing the DAG into one XLA program
may not change a single bit.  On top of that: rebound-input replay,
double-instantiate staging, capture-time legality (no synchronize, no
donation, no placeholder escape), and trace-cache sharing between
graphs and eager launches.
"""
import numpy as np
import pytest

from repro.core import cox
from repro.core.streams import Dispatcher, Stream
from repro.core.types import CoxUnsupported, GraphRef

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


# the streams test kernel set: elementwise chain + shared-memory tile
@cox.kernel
def _saxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
           y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


@cox.kernel
def _scale(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = x[i] * 3.0 + 1.0


@cox.kernel
def _tile_sum(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
              n: cox.i32):
    tile = c.shared((256,), cox.f32)
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    v = 0.0
    if i < n:
        v = x[i]
    tile[c.thread_idx()] = v
    c.syncthreads()
    s = 0.0
    for k in range(256):
        s += tile[k]
    out[c.block_idx()] = s


@cox.kernel
def _hist(c, hist: cox.Array(cox.f32), data: cox.Array(cox.i32),
          n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, data[i], 1.0)


@cox.kernel
def _coop_scan(c, out: cox.Array(cox.f32), scratch: cox.Array(cox.f32),
               a: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    v = a[i] * 2.0
    scratch[i] = v
    c.grid_sync()
    w = scratch[(i + 64) % 256]
    out[i] = v + w


def _args(n=2048, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    return (np.zeros(n, np.float32), x, y, np.int32(n))


def _fresh():
    d = Dispatcher()
    return d, Stream("a", d), Stream("b", d)


def _chain_eager(stream, kw, o, x, y, n):
    """saxpy → scale → tile_sum issued eagerly on ``stream``."""
    h1 = stream.launch(_saxpy, grid=8, block=256, args=(o, x, y, n), **kw)
    h2 = stream.launch(_scale, grid=8, block=256,
                       args=(np.zeros_like(o), h1.outputs["out"], n), **kw)
    h3 = stream.launch(_tile_sum, grid=8, block=256,
                       args=(np.zeros(8, np.float32), h2.outputs["out"], n),
                       **kw)
    return h2.result()["out"], h3.result()["out"]


# ---------------------------------------------------------------------------
# bitwise equivalence: replay == eager, across backends × warp-exec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scan", "vmap"])
@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_replay_bitwise_equals_eager(backend, warp_exec):
    d, s, _ = _fresh()
    o, x, y, n = _args()
    kw = dict(backend=backend, warp_exec=warp_exec)
    want_mid, want_sum = _chain_eager(s, kw, o, x, y, n)

    g = cox.Graph()
    with g.capture(s):
        h1 = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n), **kw)
        h2 = s.launch(_scale, grid=8, block=256,
                      args=(np.zeros_like(o), h1.outputs["out"], n), **kw)
        s.launch(_tile_sum, grid=8, block=256,
                 args=(np.zeros(8, np.float32), h2.outputs["out"], n), **kw)
    res = g.replay()
    # both upstream 'out's were consumed and elided, so the terminal
    # tile_sum output keeps the bare name; what remains besides it are
    # unconsumed pass-throughs (each node returns all its globals)
    assert "out" in res and not any(k.startswith("out_") for k in res)
    np.testing.assert_array_equal(np.asarray(res["out"]),
                                  np.asarray(want_sum))
    # and replay again — replay is pure, results stay identical
    res2 = g.replay()
    np.testing.assert_array_equal(np.asarray(res2["out"]),
                                  np.asarray(res["out"]))
    del want_mid


def test_replay_bitwise_equals_eager_sharded():
    mesh = jax.make_mesh((1,), ("data",))
    d, s, _ = _fresh()
    o, x, y, n = _args()
    kw = dict(mesh=mesh, backend="sharded")
    h = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n), **kw)
    want = h.result()["out"]
    g = cox.Graph()
    with g.capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n), **kw)
    res = g.replay()
    np.testing.assert_array_equal(np.asarray(res["out"]), np.asarray(want))


def test_replay_bitwise_equals_eager_atomics_and_coop():
    """A grid-sync (multi-phase) kernel and an atomics kernel inside one
    capture — the fused program must thread the phase machinery and the
    delta merges exactly as the eager path does."""
    d, s, _ = _fresh()
    rng = np.random.default_rng(3)
    a = rng.normal(size=256).astype(np.float32)
    data = rng.integers(0, 64, size=600).astype(np.int32)
    coop_args = (np.zeros(256, np.float32), np.zeros(256, np.float32), a)
    hist_args = (np.zeros(64, np.float32), data, np.int32(600))
    want_coop = s.launch(_coop_scan, grid=4, block=64,
                         args=coop_args).result()["out"]
    want_hist = s.launch(_hist, grid=6, block=128,
                         args=hist_args).result()["hist"]
    g = cox.Graph()
    with g.capture(s):
        s.launch(_coop_scan, grid=4, block=64, args=coop_args)
        s.launch(_hist, grid=6, block=128, args=hist_args)
    res = g.replay()
    np.testing.assert_array_equal(np.asarray(res["out"]),
                                  np.asarray(want_coop))
    np.testing.assert_array_equal(np.asarray(res["hist"]),
                                  np.asarray(want_hist))


def test_capture_with_event_edges_across_streams():
    """A two-stream capture joined by an event edge — the captured DAG
    records the edge, and replay equals the eager two-stream run."""
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    ha = s1.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    ev0 = s1.record_event()
    s2.wait_event(ev0)
    hb = s2.launch(_scale, grid=8, block=256,
                   args=(np.zeros_like(o), ha.outputs["out"], n))
    want = hb.result()["out"]

    g = cox.Graph()
    with g.capture(s1, s2):
        ca = s1.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        ev = s1.record_event()
        s2.wait_event(ev)
        cb = s2.launch(_scale, grid=8, block=256,
                       args=(np.zeros_like(o), ca.outputs["out"], n))
        assert isinstance(cb.outputs["out"], GraphRef)
    # the event edge became a schedule dep of the second node
    assert g.nodes[0].idx in g.nodes[1].deps
    res = g.replay()
    np.testing.assert_array_equal(np.asarray(res["out"]),
                                  np.asarray(want))


def test_diamond_fanout_replay():
    """One producer feeding two consumers feeding a joint consumer —
    fan-out data edges, the DAG shape streams cannot express in one
    chain."""
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        p = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        left = s.launch(_scale, grid=8, block=256,
                        args=(np.zeros_like(o), p.outputs["out"], n))
        right = s.launch(_scale, grid=8, block=256,
                         args=(np.zeros_like(o), p.outputs["out"], n))
        s.launch(_saxpy, grid=8, block=256,
                 args=(np.zeros_like(o), left.outputs["out"],
                       right.outputs["out"], n))
    res = g.replay()
    base = 2.5 * x + y
    leg = base * 3.0 + 1.0
    want = (2.5 * leg + leg).astype(np.float32)
    np.testing.assert_allclose(np.asarray(res["out"]), want,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rebinding
# ---------------------------------------------------------------------------


def test_replay_with_rebound_inputs():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        h1 = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        s.launch(_scale, grid=8, block=256,
                 args=(np.zeros_like(o), h1.outputs["out"], n))
    first = g.replay()
    x2 = np.asarray(x) * -1.5
    res = g.replay(x=x2)
    want = ((2.5 * x2 + y) * 3.0 + 1.0).astype(np.float32)
    np.testing.assert_allclose(np.asarray(res["out"]), want,
                               rtol=1e-5, atol=1e-5)
    # rebinding persists (cudaGraphExecKernelNodeSetParams semantics)
    res2 = g.replay()
    np.testing.assert_array_equal(np.asarray(res2["out"]),
                                  np.asarray(res["out"]))
    assert not np.array_equal(np.asarray(first["out"]),
                              np.asarray(res["out"]))


def test_replay_rejects_unknown_input():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    with pytest.raises(KeyError):
        g.replay(bogus=np.zeros(4, np.float32))


def test_bare_name_rebinds_every_matching_input():
    """The same external buffer name on two nodes: a bare-name rebind
    updates both bindings; the suffixed name addresses just one."""
    d, s, _ = _fresh()
    o, x, y, n = _args(512)
    g = cox.Graph()
    with g.capture(s):
        s.launch(_scale, grid=2, block=256, args=(o, x, n))
        s.launch(_scale, grid=2, block=256, args=(np.zeros_like(o), x, n))
    exe = g.instantiate()
    assert "x_n0" in exe.input_names and "x_n1" in exe.input_names
    x2 = np.asarray(x) + 1.0
    res = exe.replay(x=x2)                # bare name: both nodes
    want = (x2 * 3.0 + 1.0).astype(np.float32)
    np.testing.assert_allclose(np.asarray(res["out_n0"]), want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res["out_n1"]), want, rtol=1e-5)
    res = exe.replay(x_n1=np.asarray(x))  # suffixed: one node only
    np.testing.assert_allclose(np.asarray(res["out_n0"]), want, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(res["out_n1"]),
        (np.asarray(x) * 3.0 + 1.0).astype(np.float32), rtol=1e-5)


# ---------------------------------------------------------------------------
# staging: double-instantiate + cache sharing with eager launches
# ---------------------------------------------------------------------------


def test_double_instantiate_is_a_stage_hit():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    e1 = g.instantiate()
    hits = d.stage_hits
    e2 = g.instantiate()
    assert d.stage_hits == hits + 1        # same DAG: staged once
    assert e1._exe is e2._exe              # one executable...
    assert e1 is not e2                    # ...two rebindable instances
    e2.replay(x=np.zeros_like(x))
    r1 = e1.replay()                       # e1's bindings are untouched
    np.testing.assert_array_equal(
        np.asarray(r1["out"]),
        np.asarray(s.launch(_saxpy, grid=8, block=256,
                            args=(o, x, y, n)).result()["out"]))


def test_structurally_identical_recapture_shares_executable():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g1 = cox.Graph()
    with g1.capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    e1 = g1.instantiate()
    g2 = cox.Graph()
    with g2.capture(s):                    # same kernel/geometry/structure
        s.launch(_saxpy, grid=8, block=256, args=(o, y, x, n))
    e2 = g2.instantiate()
    assert e1._exe is e2._exe              # stage hit across captures
    # but each keeps its own captured bindings (x/y swapped)
    np.testing.assert_array_equal(
        np.asarray(e2.replay()["out"]),
        np.asarray(_saxpy.launch(grid=8, block=256, args=(o, y, x, n))["out"]))


def test_graph_shares_traces_with_eager_launches():
    """The cache-sharing contract: eager launches populate the raw-fn
    cache, a graph over the same launch shapes re-traces nothing — and
    graph entries never leak into the kernel's `_launch_cache` view."""
    d, s, _ = _fresh()
    o, x, y, n = _args()
    s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n)).result()
    misses = d.stage_fn_misses
    g = cox.Graph()
    with g.capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    g.instantiate()
    assert d.stage_fn_misses == misses     # the graph re-traced nothing
    assert d.stage_fn_hits >= 1
    # graph executables live in the shared LRU under a "graph" tag,
    # invisible to the per-kernel cache view
    assert any(k[0] == "graph" for k in d._staged)
    ck = next(iter(_saxpy._cache.values()))
    assert all(isinstance(k[0], tuple)
               for k in d.cache_view([ck]))


# ---------------------------------------------------------------------------
# capture-time legality
# ---------------------------------------------------------------------------


def test_capture_rejects_synchronize():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    with cox.Graph().capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        with pytest.raises(CoxUnsupported):
            s.synchronize()
        with pytest.raises(CoxUnsupported):
            d.sync_all()
    assert not s.capturing                 # context manager still unwinds


def test_capture_rejects_donation():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        h1 = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        with pytest.raises(CoxUnsupported):
            s.launch(_scale, grid=8, block=256,
                     args=(np.zeros_like(o), h1.outputs["out"], n),
                     donate=True)


def test_capture_rejects_event_query_and_sync():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    with cox.Graph().capture(s):
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        ev = s.record_event()
        with pytest.raises(CoxUnsupported):
            ev.query()
        with pytest.raises(CoxUnsupported):
            ev.synchronize()


def test_capture_rejects_eager_event_wait():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    h = s1.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    eager_ev = s1.record_event()
    h.result()
    with cox.Graph().capture(s2):
        with pytest.raises(CoxUnsupported):
            s2.wait_event(eager_ev)        # CUDA invalidates the capture


def test_placeholder_escape_rejected():
    """A GraphRef consumed outside its capture must fail at enqueue —
    the placeholder never holds data."""
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        h = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        ref = h.outputs["out"]
    with pytest.raises(CoxUnsupported):
        s.launch(_scale, grid=8, block=256,
                 args=(np.zeros_like(o), ref, n))
    with pytest.raises(CoxUnsupported):    # and never as a scalar
        s.launch(_scale, grid=8, block=256, args=(o, x, ref))


def test_captured_handle_has_no_results():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    g = cox.Graph()
    with g.capture(s):
        h = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        with pytest.raises(CoxUnsupported):
            h.result()
        with pytest.raises(CoxUnsupported):
            h.done()


def test_empty_graph_and_nested_capture_rejected():
    d, s, _ = _fresh()
    g = cox.Graph()
    with pytest.raises(CoxUnsupported):
        g.instantiate()
    with g.capture(s):
        with pytest.raises(CoxUnsupported):
            s.begin_capture()              # already capturing
    o, x, y, n = _args()
    with g.capture(s):                     # re-open the same graph: fine
        s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    g.instantiate()
    with pytest.raises(CoxUnsupported):    # instantiated graphs are frozen
        s.begin_capture(g)


def test_capture_does_not_dispatch():
    """Capture records the schedule without running it: nothing pends,
    nothing dispatches, and eager launches on other streams proceed."""
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    logged = len(d.dispatch_log)
    g = cox.Graph()
    with g.capture(s1):
        s1.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
        # an eager launch on a non-capturing stream still flows
        r = s2.launch(_scale, grid=8, block=256, args=(o, x, n)).result()
        np.testing.assert_allclose(np.asarray(r["out"]),
                                   np.asarray(x) * 3.0 + 1.0, rtol=1e-5)
    assert len(d.dispatch_log) == logged + 1   # only the eager launch
    assert not d._pending
    g.replay()
    assert len(d.dispatch_log) == logged + 1   # replay bypasses dispatch
