"""Backend equivalence: scan ≡ vmap ≡ sharded.

The grid-execution backends (repro.core.backends) must agree exactly —
plain stores are single-writer-selected (no arithmetic on the payload),
so vmap/sharded outputs are bitwise-identical to the loop-carried scan
baseline; atomic deltas are integer-valued in these kernels, so their
sums are exact too.  Covers the full coverage suite (warp-feature
kernels included), atomics, grid sizes not divisible by the chunk size,
and the launch-cache / heuristic plumbing.
"""
import numpy as np
import pytest

from benchmarks.kernels_suite import EXTRA_KERNELS, all_kernels
from repro.core import cox
from repro.core import flat as cox_flat
from repro.core.backends import available_backends, get_backend
from repro.core.backends.plan import LaunchPlan
from repro.core.types import CoxUnsupported

RUNNABLE = [sk for sk in all_kernels() if sk.kernel is not None]


def _launch(sk, args=None, **kw):
    # make_args draws fresh RNG data — callers comparing backends must
    # build args once and pass them to every launch
    out = sk.kernel.launch(grid=sk.grid, block=sk.block,
                           args=sk.make_args() if args is None else args,
                           **kw)
    return {k: np.asarray(v) for k, v in out.items()}


@pytest.mark.parametrize("sk", RUNNABLE, ids=lambda sk: sk.name)
def test_vmap_bitwise_matches_scan(sk):
    """Full suite, chunk=3 so most grids (1, 2, 8, 16, 64) leave a
    ragged -1-padded tail chunk."""
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    got = _launch(sk, args, backend="vmap", chunk=3)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{sk.name}.{k}")


@pytest.mark.parametrize("name", ["vectorAdd", "MatrixMulCUDA", "reduce4",
                                  "shfl_scan_test", "VoteAnyKernel3",
                                  "histogram64", "blockCounter"])
def test_sharded_matches_scan_on_one_device_mesh(name):
    """shard_map × vmap recomposition on an in-process 1-device mesh
    (8-device semantics live in test_multidevice.py); representative
    features: plain, block-cg, warp-cg, shuffle, vote, atomics."""
    import jax
    sk = next(k for k in all_kernels() if k.name == name)
    mesh = jax.make_mesh((1,), ("data",))
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    got = _launch(sk, args, mesh=mesh, chunk=3)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{name}.{k}")


@pytest.mark.parametrize("chunk", [1, 2, 5, 7, 64])
def test_vmap_chunk_sizes_including_indivisible(chunk):
    sk = next(k for k in EXTRA_KERNELS if k.name == "histogram64")  # grid=16
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    got = _launch(sk, args, backend="vmap", chunk=chunk)
    np.testing.assert_array_equal(got["hist"], want["hist"])


def test_atomics_plus_stores_in_one_kernel():
    sk = next(k for k in EXTRA_KERNELS if k.name == "blockCounter")
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    for backend, kw in (("vmap", {"chunk": 3}), ("vmap", {"chunk": 8})):
        got = _launch(sk, args, backend=backend, **kw)
        np.testing.assert_array_equal(got["total"], want["total"])
        np.testing.assert_array_equal(got["partial"], want["partial"])
    assert want["total"][0] == 900


# ---------------------------------------------------------------------------
# atomic old-value capture (ticket pattern) — serial-only semantics
# ---------------------------------------------------------------------------


@cox.kernel
def _k_ticket(c, tickets: cox.Array(cox.i32), counter: cox.Array(cox.i32)):
    if c.thread_idx() == 0:
        t = c.atomic_add_old(counter, 0, 1)
        tickets[c.block_idx()] = t


def test_atomic_old_capture_is_serial_only():
    """Captured atomic old values are unique only under serial
    execution (on CUDA the ticket pattern is valid and deterministic):
    the auto heuristic must route such kernels to scan, the delta-merge
    backends must reject them outright, and scan must hand out exactly
    the tickets 0..grid-1."""
    assert cox_flat.captures_atomic_old(_k_ticket.ir)
    assert cox_flat.choose_backend(_k_ticket.ir, grid=8) == "scan"
    args = (np.full(8, -1, np.int32), np.zeros(1, np.int32))
    out = _k_ticket.launch(grid=8, block=32, args=args)
    assert sorted(np.asarray(out["tickets"]).tolist()) == list(range(8))
    assert np.asarray(out["counter"])[0] == 8
    for kw in ({"backend": "vmap"}, {"backend": "vmap", "chunk": 1}):
        with pytest.raises(CoxUnsupported):
            _k_ticket.launch(grid=8, block=32, args=args, **kw)


def test_atomic_old_capture_rejected_on_mesh():
    """A mesh forces the sharded backend, whose merge cannot reproduce
    ticket semantics either — reject at build, never run silently."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    args = (np.full(8, -1, np.int32), np.zeros(1, np.int32))
    with pytest.raises(CoxUnsupported):
        _k_ticket.launch(grid=8, block=32, args=args, mesh=mesh)


def test_plain_atomics_without_capture_still_take_vmap():
    """The scan-only carve-out is ticket kernels, not all atomics."""
    atomic_k = next(k for k in all_kernels() if k.name == "histogram64")
    assert not cox_flat.captures_atomic_old(atomic_k.kernel.ir)
    assert cox_flat.choose_backend(atomic_k.kernel.ir, grid=16) == "vmap"


# ---------------------------------------------------------------------------
# dispatch heuristic + plumbing
# ---------------------------------------------------------------------------


@cox.kernel
def _k_id(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    out[i] = a[i]


def test_choose_backend_heuristic():
    # streaming SPMD kernel: loop-carried scan wins regardless of grid
    assert cox_flat.choose_backend(_k_id.ir, grid=1) == "scan"
    assert cox_flat.choose_backend(_k_id.ir, grid=8) == "scan"
    # blockwise internal work (shared-memory tiles / atomics): vmap,
    # unless there is only one block
    shared_k = next(k for k in all_kernels() if k.name == "MatrixMulCUDA")
    atomic_k = next(k for k in all_kernels() if k.name == "histogram64")
    assert cox_flat.choose_backend(shared_k.kernel.ir, grid=16) == "vmap"
    assert cox_flat.choose_backend(atomic_k.kernel.ir, grid=16) == "vmap"
    assert cox_flat.choose_backend(shared_k.kernel.ir, grid=1) == "scan"
    assert cox_flat.choose_backend(_k_id.ir, grid=8, mesh=object()) \
        == "sharded"
    assert cox_flat.choose_backend(_k_id.ir, grid=8, requested="scan") \
        == "scan"
    with pytest.raises(ValueError):
        cox_flat.choose_backend(_k_id.ir, grid=8, requested="sharded")
    with pytest.raises(ValueError):
        cox_flat.choose_backend(_k_id.ir, grid=8, mesh=object(),
                                requested="vmap")
    with pytest.raises(ValueError):
        cox_flat.choose_backend(_k_id.ir, grid=8, requested="pthread")


def test_choose_mode_auto_unrolls_single_warp():
    assert cox_flat.choose_mode(_k_id.ir, n_warps=1, requested="auto") \
        == "jit"
    assert cox_flat.choose_mode(_k_id.ir, n_warps=8, requested="auto") \
        == "normal"
    assert cox_flat.choose_mode(_k_id.ir, n_warps=1, requested="normal") \
        == "normal"


def test_backend_registry():
    assert set(available_backends()) == {"scan", "vmap", "sharded"}
    with pytest.raises(ValueError):
        get_backend("pthread")


def test_launch_plan_chunking():
    ck = _k_id.compiled(block=64)
    plan = LaunchPlan.build(ck, grid=5, block=64, chunk=2)
    table = plan.chunked_bids()
    assert table.shape == (3, 2)
    assert table.tolist() == [[0, 1], [2, 3], [4, -1]]
    dev = plan.device_bid_table(2)     # per=3, padded to chunk multiple 4
    assert dev.shape == (2, 4)
    assert dev[0].tolist() == [0, 1, 2, -1]
    assert dev[1].tolist() == [3, 4, -1, -1]


def test_launch_cache_hits_on_repeat_and_splits_on_geometry():
    a = np.ones(128, np.float32)
    _k_id.launch(grid=2, block=64, args=(np.zeros(128, np.float32), a))
    n1 = len(_k_id._launch_cache)
    _k_id.launch(grid=2, block=64, args=(np.zeros(128, np.float32), a))
    assert len(_k_id._launch_cache) == n1          # repeat launch: cache hit
    _k_id.launch(grid=2, block=64, args=(np.zeros(128, np.float32), a),
                 backend="vmap")
    assert len(_k_id._launch_cache) == n1 + 1      # new backend: new entry


def test_mesh_key_is_content_based():
    """Two equivalent meshes must share a launch-cache key: id()-based
    keys can be recycled after GC and alias stale executables."""
    import jax
    from repro.core.api import _mesh_key
    m1 = jax.make_mesh((1,), ("data",))
    m2 = jax.make_mesh((1,), ("data",))
    k1, k2 = _mesh_key(m1), _mesh_key(m2)
    assert k1 == k2
    hash(k1)
    assert _mesh_key(None) is None


def test_scalar_args_do_not_retrace():
    """Scalar uniforms are traced arguments of the cached executable, so
    new scalar values reuse the staged computation."""
    sk = next(k for k in all_kernels() if k.name == "vectorAdd")
    out0, a, b, _ = sk.make_args()
    sk.kernel.launch(grid=sk.grid, block=sk.block, args=(out0, a, b, 512))
    n1 = len(sk.kernel._launch_cache)
    got = sk.kernel.launch(grid=sk.grid, block=sk.block,
                           args=(out0, a, b, 100))
    assert len(sk.kernel._launch_cache) == n1
    want = np.asarray(a[:100]) + np.asarray(b[:100])
    np.testing.assert_allclose(np.asarray(got["out"])[:100], want)
    np.testing.assert_array_equal(np.asarray(got["out"])[100:],
                                  np.zeros(412, np.float32))
