"""Backend equivalence: scan ≡ vmap ≡ sharded, serial ≡ batched warps.

The grid-execution backends (repro.core.backends) must agree exactly —
plain stores are single-writer-selected (no arithmetic on the payload),
so vmap/sharded outputs are bitwise-identical to the loop-carried scan
baseline; atomic deltas are integer-valued in these kernels, so their
sums are exact too.  The same bar holds one level down: warp-batched
execution (the (n_warps, W) lane plane) must be bitwise-identical to
the serial inter-warp loop across the full suite.  Covers the coverage
suite (warp-feature kernels included), atomics, grid sizes not
divisible by the chunk size, and the launch-cache / heuristic plumbing.
"""
import numpy as np
import pytest

from benchmarks.kernels_suite import EXTRA_KERNELS, all_kernels
from repro.core import cox
from repro.core import flat as cox_flat
from repro.core.kernel_ir import uses_grid_sync
from repro.core.backends import available_backends, get_backend
from repro.core.backends.plan import LaunchPlan
from repro.core.types import CoxUnsupported

RUNNABLE = [sk for sk in all_kernels() if sk.kernel is not None]


def _launch(sk, args=None, **kw):
    # make_args draws fresh RNG data — callers comparing backends must
    # build args once and pass them to every launch
    out = sk.kernel.launch(grid=sk.grid, block=sk.block,
                           args=sk.make_args() if args is None else args,
                           **kw)
    return {k: np.asarray(v) for k, v in out.items()}


@pytest.mark.parametrize("sk", RUNNABLE, ids=lambda sk: sk.name)
def test_vmap_bitwise_matches_scan(sk):
    """Full suite, chunk=3 so most grids (1, 2, 8, 16, 64) leave a
    ragged -1-padded tail chunk.  Cooperative (grid-sync) kernels pin
    their own chunk schedule — every block resident per phase — so they
    run with the plan's forced chunk instead."""
    args = sk.make_args()
    coop = uses_grid_sync(sk.kernel.ir)
    want = _launch(sk, args, backend="scan")
    got = _launch(sk, args, backend="vmap", **({} if coop else {"chunk": 3}))
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{sk.name}.{k}")


@pytest.mark.parametrize("name", ["vectorAdd", "MatrixMulCUDA", "reduce4",
                                  "shfl_scan_test", "VoteAnyKernel3",
                                  "histogram64", "blockCounter"])
def test_sharded_matches_scan_on_one_device_mesh(name):
    """shard_map × vmap recomposition on an in-process 1-device mesh
    (8-device semantics live in test_multidevice.py); representative
    features: plain, block-cg, warp-cg, shuffle, vote, atomics."""
    import jax
    sk = next(k for k in all_kernels() if k.name == name)
    mesh = jax.make_mesh((1,), ("data",))
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    got = _launch(sk, args, mesh=mesh, chunk=3)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{name}.{k}")


@pytest.mark.parametrize("chunk", [1, 2, 5, 7, 64])
def test_vmap_chunk_sizes_including_indivisible(chunk):
    sk = next(k for k in EXTRA_KERNELS if k.name == "histogram64")  # grid=16
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    got = _launch(sk, args, backend="vmap", chunk=chunk)
    np.testing.assert_array_equal(got["hist"], want["hist"])


def test_atomics_plus_stores_in_one_kernel():
    sk = next(k for k in EXTRA_KERNELS if k.name == "blockCounter")
    args = sk.make_args()
    want = _launch(sk, args, backend="scan")
    for backend, kw in (("vmap", {"chunk": 3}), ("vmap", {"chunk": 8})):
        got = _launch(sk, args, backend=backend, **kw)
        np.testing.assert_array_equal(got["total"], want["total"])
        np.testing.assert_array_equal(got["partial"], want["partial"])
    assert want["total"][0] == 900


# ---------------------------------------------------------------------------
# warp-batched execution: the (n_warps, W) lane plane ≡ the serial
# inter-warp loop, bitwise, across the full suite
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sk", RUNNABLE, ids=lambda sk: sk.name)
def test_warp_batched_bitwise_matches_serial(sk):
    """Full suite through warp_exec='batched' vs 'serial' on the scan
    backend — shared memory, warp collectives, peels, atomics, partial
    warps included."""
    args = sk.make_args()
    want = _launch(sk, args, backend="scan", warp_exec="serial")
    got = _launch(sk, args, backend="scan", warp_exec="batched")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{sk.name}.{k}")


@pytest.mark.parametrize("name", ["MatrixMulCUDA", "reduce0", "reduce4",
                                  "histogram64", "blockCounter"])
def test_warp_batched_composes_with_block_vmap(name):
    """grid-chunk × warp × lane batching all at once: the vmap backend
    with batched warps must still equal scan with serial warps."""
    sk = next(k for k in all_kernels() if k.name == name)
    args = sk.make_args()
    want = _launch(sk, args, backend="scan", warp_exec="serial")
    got = _launch(sk, args, backend="vmap", chunk=3, warp_exec="batched")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{name}.{k}")


def test_warp_batched_on_one_device_mesh():
    import jax
    sk = next(k for k in all_kernels() if k.name == "MatrixMulCUDA")
    mesh = jax.make_mesh((1,), ("data",))
    args = sk.make_args()
    want = _launch(sk, args, backend="scan", warp_exec="serial")
    got = _launch(sk, args, mesh=mesh, chunk=3, warp_exec="batched")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


@cox.kernel
def _k_warpstage(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    # n_warps>=4 acceptance kernel: shared memory + warp collective +
    # block barrier + cross-warp shared reads after the barrier
    tile = c.shared((4,), cox.f32)
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    s = c.red_add(v)
    if c.lane_id() == 0:
        tile[c.warp_id()] = s
    c.syncthreads()
    t = tile[tid % 4]
    out[c.block_idx() * c.block_dim() + tid] = v + t


@cox.kernel
def _k_warpstage_partial(c, out: cox.Array(cox.f32),
                         a: cox.Array(cox.f32), n: cox.i32):
    # same shape but with a partial last warp (launched at block=112:
    # 4 warps, the last one half dead)
    tile = c.shared((4,), cox.f32)
    tid = c.thread_idx()
    i = c.block_idx() * c.block_dim() + tid
    v = 0.0
    if i < n:
        v = a[i]
    s = c.red_add(v)
    if c.lane_id() == 0:
        tile[c.warp_id()] = s
    c.syncthreads()
    t = tile[tid % 4]
    if i < n:
        out[i] = v + t


def test_warp_batched_multiwarp_shared_collective_barrier():
    """The acceptance shape: n_warps >= 4, shared memory, warp
    collectives and block barriers — batched ≡ serial bitwise, and the
    auto heuristic actually picks batched for it."""
    from repro.core import flat as cf
    rng = np.random.default_rng(3)
    a = rng.integers(-8, 9, 256).astype(np.float32)
    args = (np.zeros(256, np.float32), a)
    want = _k_warpstage.launch(grid=2, block=128, args=args,
                               warp_exec="serial")
    got = _k_warpstage.launch(grid=2, block=128, args=args,
                              warp_exec="batched")
    np.testing.assert_array_equal(np.asarray(got["out"]),
                                  np.asarray(want["out"]))
    assert cf.choose_warp_exec(_k_warpstage.ir, n_warps=4) == "batched"


def test_warp_batched_partial_last_warp():
    rng = np.random.default_rng(4)
    n = 200  # block=112 -> 4 warps, last warp half dead; tail dead too
    a = rng.integers(-8, 9, 224).astype(np.float32)
    args = (np.zeros(224, np.float32), a, n)
    want = _k_warpstage_partial.launch(grid=2, block=112, args=args,
                                       warp_exec="serial")
    got = _k_warpstage_partial.launch(grid=2, block=112, args=args,
                                      warp_exec="batched")
    np.testing.assert_array_equal(np.asarray(got["out"]),
                                  np.asarray(want["out"]))


@cox.kernel
def _k_store_in_while(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                      n: cox.i32):
    # stores inside a While body cannot use the store log (log entries
    # can't escape a lax.while trace) — they must take the masked path
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    j = 0
    while j < i % 5:
        out[i * 5 + j] = a[i] + c.f32(j)
        j = j + 1


@cox.kernel
def _k_store_then_load(c, out: cox.Array(cox.f32), acc: cox.Array(cox.f32),
                       a: cox.Array(cox.f32)):
    # same-lane reload after a store in one PR: the stored array is
    # loaded in the PR, so it must not be logged (a logged store skips
    # the per-warp copy the reload would read)
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    acc[i] = a[i] * 2.0
    v = acc[i]
    out[i] = v + 1.0


@pytest.mark.parametrize("kern,args_fn", [
    (_k_store_in_while,
     lambda rng: (np.zeros(1280, np.float32),
                  rng.normal(size=256).astype(np.float32), 1280)),
    (_k_store_then_load,
     lambda rng: (np.zeros(128, np.float32), np.zeros(128, np.float32),
                  rng.normal(size=128).astype(np.float32))),
], ids=["store-in-while", "store-then-load"])
def test_store_log_ineligible_paths_stay_exact(kern, args_fn):
    rng = np.random.default_rng(9)
    args = args_fn(rng)
    want = kern.launch(grid=4, block=64, args=args, warp_exec="serial")
    for backend in ("scan", "vmap"):
        got = kern.launch(grid=4, block=64, args=args, backend=backend,
                          warp_exec="batched")
        for k in want:
            np.testing.assert_array_equal(
                np.asarray(got[k]), np.asarray(want[k]),
                err_msg=f"{kern.name}.{k} [{backend}]")


def test_pr_plan_classifies_store_paths():
    from repro.core.execute import _pr_plan
    from repro.core.regions import BlockPR
    ck = _k_store_then_load.compiled(block=64)
    plans = [_pr_plan(ck, n) for n in ck.machine.nodes
             if isinstance(n, BlockPR)]
    logged = {a for p in plans for a in p.logged}
    masked = {a for p in plans for a in p.masked}
    assert "out" in logged          # written, never read -> log path
    assert "acc" in masked          # reloaded after store -> masked path
    ck2 = _k_store_in_while.compiled(block=64)
    plans2 = [_pr_plan(ck2, n) for n in ck2.machine.nodes
              if isinstance(n, BlockPR)]
    assert "out" in {a for p in plans2 for a in p.masked}
    assert "out" not in {a for p in plans2 for a in p.logged}


def test_choose_warp_exec_heuristic():
    from repro.core import flat as cf
    from repro.core.regions import warp_peel_count
    mm = next(k for k in all_kernels() if k.name == "MatrixMulCUDA")
    r4 = next(k for k in all_kernels() if k.name == "reduce4")
    # shared-memory kernel, peel-free machine: batched
    ck = mm.kernel.compiled(block=mm.block)
    assert warp_peel_count(ck.machine) == 0
    assert cf.choose_warp_exec(mm.kernel.ir, n_warps=8,
                               machine=ck.machine) == "batched"
    # single warp: nothing to batch
    assert cf.choose_warp_exec(mm.kernel.ir, n_warps=1) == "serial"
    # no shared memory (streaming SPMD): per-PR lane work too small
    assert cf.choose_warp_exec(_k_id.ir, n_warps=8) == "serial"
    # peel-heavy warp graphs: batched switch runs every branch — serial
    ck4 = r4.kernel.compiled(block=r4.block)
    assert warp_peel_count(ck4.machine) > 0
    assert cf.choose_warp_exec(r4.kernel.ir, n_warps=8,
                               machine=ck4.machine) == "serial"
    # explicit requests pass through (peels and all)
    assert cf.choose_warp_exec(r4.kernel.ir, n_warps=8,
                               requested="batched") == "batched"
    assert cf.choose_warp_exec(mm.kernel.ir, n_warps=8,
                               requested="serial") == "serial"
    with pytest.raises(ValueError):
        cf.choose_warp_exec(mm.kernel.ir, n_warps=8, requested="simd")


def test_choose_warp_exec_shmem_budget():
    from repro.core import flat as cf

    @cox.kernel
    def _k_bigshared(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
        tile = c.shared((40000,), cox.f32)
        tid = c.thread_idx()
        tile[tid] = a[tid]
        c.syncthreads()
        out[tid] = tile[tid]

    # 160 KB of shared memory x 32 warps = 5 MB > the 4 MiB budget
    assert cf.shared_footprint(_k_bigshared.ir) == 160000
    assert cf.choose_warp_exec(_k_bigshared.ir, n_warps=32) == "serial"
    assert cf.choose_warp_exec(_k_bigshared.ir, n_warps=4) == "batched"


def test_warp_batched_rejects_atomic_old_capture():
    """Ticket semantics need a serial warp order: auto routes to
    serial, an explicit batched request is rejected — at the heuristic,
    at plan build, and in make_block_fn (defense in depth)."""
    from repro.core import flat as cf
    from repro.core.execute import make_block_fn
    assert cf.choose_warp_exec(_k_ticket.ir, n_warps=4) == "serial"
    with pytest.raises(CoxUnsupported):
        cf.choose_warp_exec(_k_ticket.ir, n_warps=4, requested="batched")
    ck = _k_ticket.compiled(block=64)
    with pytest.raises(CoxUnsupported):
        LaunchPlan.build(ck, grid=4, block=64, warp_exec="batched")
    with pytest.raises(CoxUnsupported):
        make_block_fn(ck, n_warps=2, warp_exec="batched")


def test_launch_plan_requires_resolved_knobs():
    ck = _k_id.compiled(block=64)
    with pytest.raises(ValueError):
        LaunchPlan.build(ck, grid=2, block=64, mode="auto")
    with pytest.raises(ValueError):
        LaunchPlan.build(ck, grid=2, block=64, warp_exec="auto")
    plan = LaunchPlan.build(ck, grid=2, block=64)
    assert plan.warp_exec == "serial" and plan.mode == "normal"


def test_launch_cache_splits_on_warp_exec():
    sk = next(k for k in all_kernels() if k.name == "MatrixMulCUDA")
    args = sk.make_args()
    sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                     warp_exec="serial")
    n1 = len(sk.kernel._launch_cache)
    sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                     warp_exec="batched")
    assert len(sk.kernel._launch_cache) == n1 + 1
    sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                     warp_exec="batched")
    assert len(sk.kernel._launch_cache) == n1 + 1


# ---------------------------------------------------------------------------
# atomic old-value capture (ticket pattern) — serial-only semantics
# ---------------------------------------------------------------------------


@cox.kernel
def _k_ticket(c, tickets: cox.Array(cox.i32), counter: cox.Array(cox.i32)):
    if c.thread_idx() == 0:
        t = c.atomic_add_old(counter, 0, 1)
        tickets[c.block_idx()] = t


def test_atomic_old_capture_is_serial_only():
    """Captured atomic old values are unique only under serial
    execution (on CUDA the ticket pattern is valid and deterministic):
    the auto heuristic must route such kernels to scan, the delta-merge
    backends must reject them outright, and scan must hand out exactly
    the tickets 0..grid-1."""
    assert cox_flat.captures_atomic_old(_k_ticket.ir)
    assert cox_flat.choose_backend(_k_ticket.ir, grid=8) == "scan"
    args = (np.full(8, -1, np.int32), np.zeros(1, np.int32))
    out = _k_ticket.launch(grid=8, block=32, args=args)
    assert sorted(np.asarray(out["tickets"]).tolist()) == list(range(8))
    assert np.asarray(out["counter"])[0] == 8
    for kw in ({"backend": "vmap"}, {"backend": "vmap", "chunk": 1}):
        with pytest.raises(CoxUnsupported):
            _k_ticket.launch(grid=8, block=32, args=args, **kw)


def test_atomic_old_capture_rejected_on_mesh():
    """A mesh forces the sharded backend, whose merge cannot reproduce
    ticket semantics either — reject at build, never run silently."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    args = (np.full(8, -1, np.int32), np.zeros(1, np.int32))
    with pytest.raises(CoxUnsupported):
        _k_ticket.launch(grid=8, block=32, args=args, mesh=mesh)


def test_plain_atomics_without_capture_still_take_vmap():
    """The scan-only carve-out is ticket kernels, not all atomics."""
    atomic_k = next(k for k in all_kernels() if k.name == "histogram64")
    assert not cox_flat.captures_atomic_old(atomic_k.kernel.ir)
    assert cox_flat.choose_backend(atomic_k.kernel.ir, grid=16) == "vmap"


# ---------------------------------------------------------------------------
# dispatch heuristic + plumbing
# ---------------------------------------------------------------------------


@cox.kernel
def _k_id(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    out[i] = a[i]


def test_choose_backend_heuristic():
    # streaming SPMD kernel: loop-carried scan wins regardless of grid
    assert cox_flat.choose_backend(_k_id.ir, grid=1) == "scan"
    assert cox_flat.choose_backend(_k_id.ir, grid=8) == "scan"
    # blockwise internal work (shared-memory tiles / atomics): vmap,
    # unless there is only one block
    shared_k = next(k for k in all_kernels() if k.name == "MatrixMulCUDA")
    atomic_k = next(k for k in all_kernels() if k.name == "histogram64")
    assert cox_flat.choose_backend(shared_k.kernel.ir, grid=16) == "vmap"
    assert cox_flat.choose_backend(atomic_k.kernel.ir, grid=16) == "vmap"
    assert cox_flat.choose_backend(shared_k.kernel.ir, grid=1) == "scan"
    assert cox_flat.choose_backend(_k_id.ir, grid=8, mesh=object()) \
        == "sharded"
    assert cox_flat.choose_backend(_k_id.ir, grid=8, requested="scan") \
        == "scan"
    with pytest.raises(ValueError):
        cox_flat.choose_backend(_k_id.ir, grid=8, requested="sharded")
    with pytest.raises(ValueError):
        cox_flat.choose_backend(_k_id.ir, grid=8, mesh=object(),
                                requested="vmap")
    with pytest.raises(ValueError):
        cox_flat.choose_backend(_k_id.ir, grid=8, requested="pthread")


def test_choose_mode_auto_unrolls_single_warp():
    assert cox_flat.choose_mode(_k_id.ir, n_warps=1, requested="auto") \
        == "jit"
    assert cox_flat.choose_mode(_k_id.ir, n_warps=8, requested="auto") \
        == "normal"
    assert cox_flat.choose_mode(_k_id.ir, n_warps=1, requested="normal") \
        == "normal"
    # 'auto' is the signature default, end to end
    import inspect
    from repro.core.api import KernelFn
    from repro.core.runtime import build_launcher, launch
    assert inspect.signature(cox_flat.choose_mode) \
        .parameters["requested"].default == "auto"
    for fn in (KernelFn.launch, build_launcher, launch):
        assert inspect.signature(fn).parameters["mode"].default == "auto"


def test_mode_auto_resolves_to_jit_for_single_warp_launch():
    """A default (mode='auto') single-warp launch stages a jit-mode
    plan — the resolved knob is what lands in the LaunchPlan."""
    args = (np.zeros(32, np.float32), np.ones(32, np.float32))
    _k_id.launch(grid=1, block=32, args=args)
    plans = [p for (p, _) in _k_id._launch_cache.values()]
    assert any(p.mode == "jit" and p.block == 32 for p in plans)


def test_backend_registry():
    assert set(available_backends()) == {"scan", "vmap", "sharded"}
    with pytest.raises(ValueError):
        get_backend("pthread")


def test_launch_plan_chunking():
    ck = _k_id.compiled(block=64)
    plan = LaunchPlan.build(ck, grid=5, block=64, chunk=2)
    table = plan.chunked_bids()
    assert table.shape == (3, 2)
    assert table.tolist() == [[0, 1], [2, 3], [4, -1]]
    dev = plan.device_bid_table(2)     # per=3, padded to chunk multiple 4
    assert dev.shape == (2, 4)
    assert dev[0].tolist() == [0, 1, 2, -1]
    assert dev[1].tolist() == [3, 4, -1, -1]


def test_launch_cache_hits_on_repeat_and_splits_on_geometry():
    a = np.ones(128, np.float32)
    _k_id.launch(grid=2, block=64, args=(np.zeros(128, np.float32), a))
    n1 = len(_k_id._launch_cache)
    _k_id.launch(grid=2, block=64, args=(np.zeros(128, np.float32), a))
    assert len(_k_id._launch_cache) == n1          # repeat launch: cache hit
    _k_id.launch(grid=2, block=64, args=(np.zeros(128, np.float32), a),
                 backend="vmap")
    assert len(_k_id._launch_cache) == n1 + 1      # new backend: new entry


def test_mesh_key_is_content_based():
    """Two equivalent meshes must share a launch-cache key: id()-based
    keys can be recycled after GC and alias stale executables."""
    import jax
    from repro.core.api import _mesh_key
    m1 = jax.make_mesh((1,), ("data",))
    m2 = jax.make_mesh((1,), ("data",))
    k1, k2 = _mesh_key(m1), _mesh_key(m2)
    assert k1 == k2
    hash(k1)
    assert _mesh_key(None) is None


def test_scalar_args_do_not_retrace():
    """Scalar uniforms are traced arguments of the cached executable, so
    new scalar values reuse the staged computation."""
    sk = next(k for k in all_kernels() if k.name == "vectorAdd")
    out0, a, b, _ = sk.make_args()
    sk.kernel.launch(grid=sk.grid, block=sk.block, args=(out0, a, b, 512))
    n1 = len(sk.kernel._launch_cache)
    got = sk.kernel.launch(grid=sk.grid, block=sk.block,
                           args=(out0, a, b, 100))
    assert len(sk.kernel._launch_cache) == n1
    want = np.asarray(a[:100]) + np.asarray(b[:100])
    np.testing.assert_allclose(np.asarray(got["out"])[:100], want)
    np.testing.assert_array_equal(np.asarray(got["out"])[100:],
                                  np.zeros(412, np.float32))
