"""CUDA streams & events: the async launch-dispatch layer.

Semantics under test (README "Streams & events"):

* in-order dispatch within a stream (program order);
* event edges enforce cross-stream ordering (`record` → `wait`);
* the default stream's legacy-sync semantics (ordered after every
  stream's tail, and every stream ordered after it);
* ``synchronize()`` idempotence;
* bitwise equality of any legal stream schedule vs serial issue, across
  the (scan/vmap) × (serial/batched) launch matrix;
* staging-cache sharing across streams (no duplicate staging for
  identical geometry);
* buffer donation: wired through the backends, observable via
  re-launch behavior (donated inputs are consumed), rejected where it
  cannot apply (sharded).
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import cox  # noqa: E402
from repro.core.streams import Dispatcher, Stream  # noqa: E402
from repro.core.types import CoxUnsupported  # noqa: E402


@cox.kernel
def _saxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
           y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


@cox.kernel
def _scale(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = x[i] * 3.0 + 1.0


@cox.kernel
def _tile_sum(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
              n: cox.i32):
    """Shared-memory kernel (so warp_exec='batched' is exercisable)."""
    tile = c.shared((256,), cox.f32)
    t = c.thread_idx()
    i = c.block_idx() * c.block_dim() + t
    tile[t] = c.select(i < n, x[i], 0.0)
    c.syncthreads()
    if t == 0:
        s = 0.0
        for k in range(256):
            s += tile[k]
        out[c.block_idx()] = s


def _args(n=2048, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    return (np.zeros(n, np.float32), x, y, np.int32(n))


def _fresh():
    """A private dispatcher + streams, isolated from the module-level
    default (so dispatch_log / dependency assertions are exact)."""
    d = Dispatcher()
    return d, Stream("a", d), Stream("b", d)


# ---------------------------------------------------------------------------
# ordering: program order, event edges, legacy default-stream sync
# ---------------------------------------------------------------------------


def test_in_order_within_stream():
    d, s, _ = _fresh()
    o, x, y, n = _args()
    h1 = s.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    h2 = s.launch(_scale, grid=8, block=256, args=(o, x, n))
    h3 = s.launch(_saxpy, grid=8, block=256, args=(o, y, x, n))
    # program order is the dependency chain
    assert h1.request.seq in h2.request.deps
    assert h2.request.seq in h3.request.deps
    d.flush()
    assert list(d.dispatch_log) == [h1.request.seq, h2.request.seq,
                                    h3.request.seq]


def test_event_edge_orders_across_streams():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    # enqueue s2's independent work first so only the event edge can
    # order it after s1's tail
    ha = s1.launch(_saxpy, grid=4, block=256, args=(o, x, y, n))
    ev = s1.record_event()
    s2.wait_event(ev)
    hb = s2.launch(_scale, grid=4, block=256, args=(o, x, n))
    hc = s2.launch(_scale, grid=4, block=256, args=(o, y, n))
    assert ha.request.seq in hb.request.deps      # the event edge
    assert hb.request.seq in hc.request.deps      # then program order
    d.flush()
    order = d.dispatch_log
    assert order.index(ha.request.seq) < order.index(hb.request.seq)


def test_wait_on_unrecorded_event_is_noop():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    ev = cox.Event()                      # never recorded
    s2.wait_event(ev)
    hb = s2.launch(_scale, grid=4, block=256, args=(o, x, n))
    assert hb.request.deps == ()          # no spurious edge
    d.sync_all()


def test_default_stream_legacy_sync():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    h1 = s1.launch(_saxpy, grid=4, block=256, args=(o, x, y, n))
    # a default-stream launch is ordered after every stream's tail
    hd = d.default.launch(_saxpy, grid=4, block=256, args=(o, y, x, n))
    assert h1.request.seq in hd.request.deps
    # and every stream's next launch is ordered after the default tail
    h2 = s2.launch(_scale, grid=4, block=256, args=(o, x, n))
    assert hd.request.seq in h2.request.deps
    d.flush()
    order = d.dispatch_log
    assert (order.index(h1.request.seq) < order.index(hd.request.seq)
            < order.index(h2.request.seq))


def test_independent_streams_have_no_edges():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    h1 = s1.launch(_saxpy, grid=4, block=256, args=(o, x, y, n))
    h2 = s2.launch(_scale, grid=4, block=256, args=(o, x, n))
    assert h1.request.deps == () and h2.request.deps == ()
    d.sync_all()


# ---------------------------------------------------------------------------
# synchronization
# ---------------------------------------------------------------------------


def test_synchronize_idempotent():
    d, s1, _ = _fresh()
    o, x, y, n = _args()
    h = s1.launch(_saxpy, grid=4, block=256, args=(o, x, y, n))
    s1.synchronize()
    n_dispatched = len(d.dispatch_log)
    s1.synchronize()                      # idle stream: no-op
    s1.synchronize()
    d.sync_all()
    d.sync_all()
    assert len(d.dispatch_log) == n_dispatched   # nothing re-dispatched
    r1 = h.result()
    r2 = h.result()                       # result() is repeatable too
    np.testing.assert_array_equal(np.asarray(r1["out"]),
                                  np.asarray(r2["out"]))


def test_event_synchronize_and_elapsed():
    d, s1, _ = _fresh()
    o, x, y, n = _args()
    start = cox.Event().record(s1)
    s1.launch(_saxpy, grid=4, block=256, args=(o, x, y, n))
    stop = s1.record_event()
    stop.synchronize()
    stop.synchronize()                    # idempotent
    ms = start.elapsed(stop)
    assert ms >= 0.0
    assert stop.query()


def test_event_elapsed_before_record_raises():
    ev = cox.Event()
    with pytest.raises(CoxUnsupported):
        ev.synchronize()


# ---------------------------------------------------------------------------
# bitwise equality: any legal stream schedule == serial issue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scan", "vmap"])
@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_stream_schedule_bitwise_equals_serial(backend, warp_exec):
    d, s1, s2 = _fresh()
    n = 2048
    o, x, y, n32 = _args(n)
    a1 = (o, x, n32)
    a2 = (np.zeros(8, np.float32), y, n32)
    kw = dict(backend=backend, warp_exec=warp_exec)
    # serial issue (launch + synchronize each; the classic path)
    want1 = _scale.launch(grid=8, block=256, args=a1, **kw)
    want2 = _tile_sum.launch(grid=8, block=256, args=a2, **kw)
    # two streams + an event edge — a different legal schedule
    h1 = s1.launch(_scale, grid=8, block=256, args=a1, **kw)
    ev = s1.record_event()
    s2.wait_event(ev)
    h2 = s2.launch(_tile_sum, grid=8, block=256, args=a2, **kw)
    got1, got2 = h1.result(), h2.result()
    np.testing.assert_array_equal(np.asarray(got1["out"]),
                                  np.asarray(want1["out"]))
    np.testing.assert_array_equal(np.asarray(got2["out"]),
                                  np.asarray(want2["out"]))


def test_handle_chaining_without_host_sync():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    h1 = s1.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    # feed h1's (still in-flight) flat output straight into s2's launch
    h2 = s2.launch(_scale, grid=8, block=256,
                   args=(o, h1.outputs["out"], n))
    want = (2.5 * x + y) * 3.0 + 1.0
    np.testing.assert_allclose(np.asarray(h2.result()["out"]), want,
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# staging-cache sharing
# ---------------------------------------------------------------------------


def test_cache_shared_across_streams():
    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    h1 = s1.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    h1.result()
    misses = d.stage_misses
    h2 = s2.launch(_saxpy, grid=8, block=256, args=(o, y, x, n))
    h3 = d.default.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    h2.result()
    h3.result()
    assert d.stage_misses == misses       # identical geometry: no restaging
    assert d.stage_hits >= 2


def test_kernelfn_launch_cache_view_still_works():
    """The public `_launch_cache` introspection view keeps its shape:
    token first, phase count second, (plan, exe) values."""
    o, x, y, n = _args()
    _saxpy.launch(grid=2, block=256, args=(o, x, y, n))
    cache = _saxpy._launch_cache
    assert len(cache) >= 1
    for key, (plan, exe) in cache.items():
        choice, ws = key[0]
        assert choice in ("flat", "hier") and isinstance(ws, int)
        assert key[1] == 1                # single-phase kernel
        assert callable(exe)


# ---------------------------------------------------------------------------
# error surfacing
# ---------------------------------------------------------------------------


def test_stage_error_surfaces_at_that_requests_sync():
    """A bad request (explicit vmap for a ticket kernel) must raise at
    *its own* sync, not poison unrelated launches."""

    @cox.kernel
    def ticket(c, out: cox.Array(cox.f32), cnt: cox.Array(cox.f32)):
        t = c.atomic_add_old(cnt, 0, 1.0)
        out[c.block_idx()] = t

    d, s1, s2 = _fresh()
    o, x, y, n = _args()
    bad = s1.launch(ticket, grid=4, block=32,
                    args=(np.zeros(4, np.float32),
                          np.zeros(1, np.float32)),
                    backend="vmap")
    good = s2.launch(_saxpy, grid=8, block=256, args=(o, x, y, n))
    # the good launch's sync flushes everything but raises nothing
    r = good.result()
    np.testing.assert_allclose(np.asarray(r["out"]), 2.5 * x + y,
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(CoxUnsupported):
        bad.result()
    # surfacing the error reclaims the bookkeeping entry (no leak), on
    # the async .outputs path just like the sync .result() path
    assert bad.request.seq not in d._inflight
    bad2 = s1.launch(ticket, grid=4, block=32,
                     args=(np.zeros(4, np.float32),
                           np.zeros(1, np.float32)),
                     backend="vmap")
    with pytest.raises(CoxUnsupported):
        _ = bad2.outputs
    assert bad2.request.seq not in d._inflight


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------


def test_donate_correct_and_consumes_inputs():
    """Donation is observable through re-launch behavior: outputs stay
    correct, and a donated (1-D, aliased) input is deleted — re-using
    it is an error, exactly JAX's donated-buffer contract."""
    n = 1024
    x = jnp.arange(n, dtype=jnp.float32)
    y = jnp.ones((n,), jnp.float32)
    o = jnp.zeros((n,), jnp.float32)
    want = 2.5 * np.arange(n, dtype=np.float32) + 1.0
    r = _saxpy.launch(grid=4, block=256, args=(o, x, y, n),
                      donate=True)
    np.testing.assert_allclose(np.asarray(r["out"]), want, rtol=1e-6)
    # the flat binding of a 1-D jax input aliases the caller's buffer:
    # after donation it is deleted, and re-launching with it raises
    with pytest.raises(Exception):
        _saxpy.launch(grid=4, block=256,
                      args=(jnp.zeros((n,), jnp.float32), x, y, n))


def test_donate_chained_stream_relaunch():
    """The donation payoff: an in-order stream re-launching over its own
    previous outputs — each step consumes the last step's buffer."""
    d, s, _ = _fresh()
    n = 1024
    cur = jnp.zeros((n,), jnp.float32)
    x = jnp.arange(n, dtype=jnp.float32) / n
    h = s.launch(_saxpy, grid=4, block=256,
                 args=(cur, x, jnp.zeros((n,), jnp.float32), n))
    for _ in range(3):
        h = s.launch(_scale, grid=4, block=256,
                     args=(h.outputs["out"],
                           h.outputs["out"], n))
    got = h.result()["out"]
    want = np.asarray(2.5 * np.asarray(x), np.float32)
    for _ in range(3):
        want = want * 3.0 + 1.0
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_donated_producer_output_does_not_break_bookkeeping():
    """Regression: a ``donate=True`` consumer deletes the producer's
    output buffer; the dispatcher's in-flight pruning and syncs must
    treat deleted outputs as complete instead of querying them."""
    d, s1, s2 = _fresh()
    n = 1024
    x = jnp.arange(n, dtype=jnp.float32) / n
    h1 = s1.launch(_scale, grid=4, block=256,
                   args=(jnp.zeros((n,), jnp.float32), x, n))
    h2 = s2.launch(_scale, grid=4, block=256,
                   args=(jnp.zeros((n,), jnp.float32),
                         h1.outputs["out"], n), donate=True)
    got = h2.result()["out"]              # flush + prune over deleted bufs
    d.sync_all()                          # and the stream/device syncs
    s1.synchronize()
    assert h1.done() and h2.done()
    want = (np.asarray(x) * 3.0 + 1.0) * 3.0 + 1.0
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_donate_uncached_runtime_launch():
    from repro.core import runtime
    n = 512
    ck = _saxpy.compiled(block=256)
    x = jnp.arange(n, dtype=jnp.float32)
    y = jnp.ones((n,), jnp.float32)
    out = runtime.launch(ck, grid=2, block=256,
                         args=(jnp.zeros((n,), jnp.float32), x, y, n),
                         donate=True)
    np.testing.assert_allclose(
        np.asarray(out["out"]),
        2.5 * np.arange(n, dtype=np.float32) + 1.0, rtol=1e-6)
    with pytest.raises(Exception):
        jnp.asarray(x) + 1.0              # donated input was consumed


def test_donate_splits_launch_cache():
    """A donating executable aliases its inputs; it must never be
    served to a non-donating launch of the same geometry."""
    o, x, y, n = _args(512)
    _saxpy.launch(grid=2, block=128, args=(o, x, y, n))
    n1 = len(_saxpy._launch_cache)
    _saxpy.launch(grid=2, block=128, args=(o, x, y, n), donate=True)
    assert len(_saxpy._launch_cache) == n1 + 1


def test_request_kernel_pool_on_per_slot_streams():
    """The serving path's per-request kernel pool: histograms issued on
    per-slot streams, collected with one sync, totals exact."""
    from repro.launch.serve import RequestKernelPool
    pool = RequestKernelPool(2, nbins=8)
    pool.submit(0, [1, 2, 3, 9])
    pool.submit(1, [4, 4, 4])
    pool.submit(0, [])                    # empty request: no launch
    hists = pool.collect()
    assert len(hists) == 2
    np.testing.assert_array_equal(
        hists[0], np.bincount(np.array([1, 2, 3, 9]) % 8, minlength=8))
    np.testing.assert_array_equal(
        hists[1], np.bincount(np.array([4, 4, 4]) % 8, minlength=8))
    assert {h.stream.name for h in pool.handles} == {"req-slot0",
                                                     "req-slot1"}


def test_donate_rejected_on_sharded():
    mesh = jax.make_mesh((1,), ("data",))
    o, x, y, n = _args(512)
    with pytest.raises(CoxUnsupported):
        _saxpy.launch(grid=2, block=128, args=(o, x, y, n),
                      donate=True, mesh=mesh)


# ---------------------------------------------------------------------------
# dispatch_log retention: bounded structurally, not by ad-hoc trims
# ---------------------------------------------------------------------------


def test_dispatch_log_is_bounded_deque():
    """A long launch loop must keep host bookkeeping flat: the log is a
    ``deque(maxlen=...)``, so it can never exceed its bound no matter
    how many launches a long-lived serving process issues — and it
    retains exactly the most recent dispatches, in order."""
    from collections import deque

    d = Dispatcher(dispatch_log_max=16)
    s = Stream("loop", d)
    assert isinstance(d.dispatch_log, deque)
    assert d.dispatch_log.maxlen == 16
    o, x, y, n = _args(256)
    handles = [s.launch(_saxpy, grid=1, block=64, args=(o, x, y, n))
               for _ in range(40)]
    s.synchronize()
    assert len(d.dispatch_log) == 16       # never grows past maxlen
    assert list(d.dispatch_log) == [h.request.seq for h in handles[-16:]]
    # the in-flight table drained too — no per-launch state survives
    assert not d._inflight and not d._pending
