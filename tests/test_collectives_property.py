"""Collectives parity across every execution flavor.

Two layers:

* **collective level** — every entry in ``collectives.VECTORIZED`` /
  ``SCALAR`` agrees with itself across (a) the SIMD lane-vector and
  per-lane-loop scalar backends and (b) 1-D ``(W,)`` buffers vs a
  leading warp axis ``(n_warps, W)`` (the batched executor's lane
  plane), including sub-warp tile widths and partial-last-warp masks.
  Deterministic parametrized cases always run; a hypothesis fuzz layer
  widens the input space when hypothesis is installed.
* **launch level** — kernels exercising each collective give identical
  results under ``simd=True/False`` × ``warp_exec='serial'/'batched'``,
  with sub-warp tiles and a partial last warp (block=48: the second
  warp has 16 dead lanes).

Buffers hold small-integer values so float reductions are exact in any
association order — parity can be asserted bitwise.
"""
import numpy as np
import pytest

from repro.core import collectives as C
from repro.core import cox

W = 32
RNG = np.random.default_rng(11)

FUNCS = sorted(C.VECTORIZED)
WIDTHS = (0, 8, 16)


def _extra_args(func):
    """Positional operand(s) each collective takes after the buffer."""
    if func in ("shfl_down", "shfl_up"):
        return (3,)
    if func == "shfl_xor":
        return (1,)
    if func == "shfl_idx":
        return (np.full(W, 2, np.int32),)
    return ()


def _buf(shape, func):
    if func in ("vote_all", "vote_any", "ballot"):
        return RNG.integers(0, 2, shape).astype(bool)
    return RNG.integers(-8, 9, shape).astype(np.float32)


def _mask(partial: bool):
    if not partial:
        return None
    m = np.zeros(W, bool)
    m[:16] = True  # a partial last warp: 16 live lanes
    return m


# ---------------------------------------------------------------------------
# collective level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("func", FUNCS)
@pytest.mark.parametrize("width", WIDTHS)
@pytest.mark.parametrize("partial", [False, True])
def test_leading_warp_axis_matches_per_warp(func, width, partial):
    """A (n_warps, W) plane through one call == each warp separately."""
    n_warps = 4
    buf = _buf((n_warps, W), func)
    mask = _mask(partial)
    extra = _extra_args(func)
    fn = C.VECTORIZED[func]
    plane = np.asarray(fn(buf, *extra, W=W, width=width, mask=mask))
    rows = np.stack([np.asarray(fn(buf[i], *extra, W=W, width=width,
                                   mask=mask)) for i in range(n_warps)])
    np.testing.assert_array_equal(plane, rows, err_msg=f"{func}/w={width}")


@pytest.mark.parametrize("func", FUNCS)
@pytest.mark.parametrize("width", WIDTHS)
@pytest.mark.parametrize("partial", [False, True])
@pytest.mark.parametrize("lead", [(), (4,)])
def test_scalar_backend_matches_vectorized(func, width, partial, lead):
    """Table 2's w/o-AVX per-lane loops == the lane-vector backend, on
    1-D buffers and on a leading warp axis."""
    buf = _buf(lead + (W,), func)
    mask = _mask(partial)
    extra = _extra_args(func)
    got = np.asarray(C.SCALAR[func](buf, *extra, W=W, width=width,
                                    mask=mask))
    want = np.asarray(C.VECTORIZED[func](buf, *extra, W=W, width=width,
                                         mask=mask))
    np.testing.assert_array_equal(got, want, err_msg=f"{func}/w={width}")


@pytest.mark.parametrize("func", ["shfl_down", "shfl_up", "shfl_xor"])
def test_scalar_backend_batches_array_extras(func):
    """Per-warp extra operands (a (n_warps, W) offset plane) must work
    through both backends — the scalar lift maps them with the buffer."""
    n_warps = 3
    buf = RNG.integers(-8, 9, (n_warps, W)).astype(np.float32)
    off = np.broadcast_to(RNG.integers(1, 4, (n_warps, 1)),
                          (n_warps, W)).astype(np.int32)
    want = np.stack([
        np.asarray(C.VECTORIZED[func](buf[i], off[i], W=W))
        for i in range(n_warps)])
    got_v = np.asarray(C.VECTORIZED[func](buf, off, W=W))
    got_s = np.asarray(C.SCALAR[func](buf, off, W=W))
    np.testing.assert_array_equal(got_v, want)
    np.testing.assert_array_equal(got_s, want)


def test_invalid_tile_width_rejected():
    from repro.core.types import CoxUnsupported
    for bad in (3, 12, 64):
        with pytest.raises(CoxUnsupported):
            C.VECTORIZED["red_add"](np.ones(W, np.float32), W=W, width=bad)


# hypothesis fuzz layer (skips cleanly when hypothesis is absent)
try:
    from hypothesis import given, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

if HAVE_HYP:
    # profile selection lives in tests/conftest.py (HYPOTHESIS_PROFILE)

    @given(
        func=st.sampled_from(FUNCS),
        width=st.sampled_from((0, 4, 8, 16, 32)),
        n_warps=st.integers(1, 6),
        live=st.integers(1, W),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hyp_collective_parity(func, width, n_warps, live, seed):
        rng = np.random.default_rng(seed)
        if func in ("vote_all", "vote_any", "ballot"):
            buf = rng.integers(0, 2, (n_warps, W)).astype(bool)
        else:
            buf = rng.integers(-8, 9, (n_warps, W)).astype(np.float32)
        mask = np.zeros(W, bool)
        mask[:live] = True
        extra = _extra_args(func)
        want = np.stack([
            np.asarray(C.VECTORIZED[func](buf[i], *extra, W=W, width=width,
                                          mask=mask))
            for i in range(n_warps)])
        plane_v = np.asarray(C.VECTORIZED[func](buf, *extra, W=W,
                                                width=width, mask=mask))
        plane_s = np.asarray(C.SCALAR[func](buf, *extra, W=W, width=width,
                                            mask=mask))
        np.testing.assert_array_equal(plane_v, want)
        np.testing.assert_array_equal(plane_s, want)


# ---------------------------------------------------------------------------
# launch level: every collective through the real executor, all flavors
# ---------------------------------------------------------------------------


@cox.kernel
def k_shfl_down(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.shfl_down(v, 3)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_shfl_down_tile8(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.shfl_down(v, 2, width=8)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_shfl_up(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.shfl_up(v, 5)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_shfl_xor(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.shfl_xor(v, 1)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_shfl_idx(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.shfl(v, 7)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_vote_all(c, out: cox.Array(cox.i32), a: cox.Array(cox.i32)):
    tid = c.thread_idx()
    r = c.vote_all(a[c.block_idx() * c.block_dim() + tid] > 0)
    out[c.block_idx() * c.block_dim() + tid] = c.i32(r)


@cox.kernel
def k_vote_any(c, out: cox.Array(cox.i32), a: cox.Array(cox.i32)):
    tid = c.thread_idx()
    r = c.vote_any(a[c.block_idx() * c.block_dim() + tid] > 1)
    out[c.block_idx() * c.block_dim() + tid] = c.i32(r)


@cox.kernel
def k_ballot(c, out: cox.Array(cox.u32), a: cox.Array(cox.i32)):
    tid = c.thread_idx()
    r = c.ballot(a[c.block_idx() * c.block_dim() + tid] > 0)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_red_add(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.red_add(v)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_red_add_tile16(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.red_add(v, width=16)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_red_max(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.red_max(v)
    out[c.block_idx() * c.block_dim() + tid] = r


@cox.kernel
def k_red_min(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = a[c.block_idx() * c.block_dim() + tid]
    r = c.red_min(v)
    out[c.block_idx() * c.block_dim() + tid] = r


LAUNCH_KERNELS = [
    k_shfl_down, k_shfl_down_tile8, k_shfl_up, k_shfl_xor, k_shfl_idx,
    k_vote_all, k_vote_any, k_ballot, k_red_add, k_red_add_tile16,
    k_red_max, k_red_min,
]


def _launch_args(kern, block):
    n = 2 * block
    if kern.name in ("k_vote_all", "k_vote_any", "k_ballot"):
        a = RNG.integers(0, 3, n).astype(np.int32)
        dt = np.uint32 if kern.name == "k_ballot" else np.int32
        return (np.zeros(n, dt), a)
    a = RNG.integers(-8, 9, n).astype(np.float32)
    return (np.zeros(n, np.float32), a)


@pytest.mark.parametrize("kern", LAUNCH_KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("block", [128, 48])  # 48: partial last warp
def test_launch_parity_all_flavors(kern, block):
    """simd × warp_exec parity through the real executor; block=48
    leaves the second warp with 16 dead lanes."""
    args = _launch_args(kern, block)
    want = np.asarray(kern.launch(grid=2, block=block, args=args,
                                  simd=True, warp_exec="serial")["out"])
    for simd in (True, False):
        for wexec in ("serial", "batched"):
            got = np.asarray(kern.launch(grid=2, block=block, args=args,
                                         simd=simd, warp_exec=wexec)["out"])
            np.testing.assert_array_equal(
                got, want,
                err_msg=f"{kern.name} block={block} simd={simd} {wexec}")
