"""Substrate tests: checkpoint atomicity/elasticity, fault-tolerant
restart, deterministic data, optimizer + gradient compression, and an
end-to-end mini training convergence check."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.configs import registry
from repro.data.pipeline import DataConfig, TokenSource
from repro.ft.watchdog import FailureInjector, StepWatchdog, retry_loop
from repro.launch.train import train
from repro.optim import adamw


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "step": jnp.int32(7)}}
    mgr.save(5, tree, blocking=True)
    assert mgr.latest_step() == 5
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = mgr.restore(5, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((4,), s, jnp.float32)}, blocking=True)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    assert len(steps) == 2  # gc keeps last 2
    assert mgr.latest_step() == 4
    # corrupt-shape detection
    like = {"x": jax.ShapeDtypeStruct((5,), jnp.float32)}
    with pytest.raises(ValueError):
        mgr.restore(4, like)


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore onto a different sharding (elastic: mesh change)."""
    mgr = CheckpointManager(str(tmp_path))
    x = jnp.arange(16, dtype=jnp.float32)
    mgr.save(0, {"x": x}, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    back = mgr.restore(0, {"x": jax.ShapeDtypeStruct((16,), jnp.float32)},
                       {"x": sh})
    assert back["x"].sharding == sh
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))


def test_data_pipeline_deterministic_and_resumable():
    cfg = registry.get("mamba2-130m", smoke=True)
    shape = ShapeConfig("t", 64, 4, "train")
    s1 = TokenSource(cfg, shape, DataConfig(seed=1))
    s2 = TokenSource(cfg, shape, DataConfig(seed=1))
    b1 = s1.batch_at(17)
    b2 = s2.batch_at(17)  # independent instance, same step -> same data
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < cfg.vocab
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_watchdog_strikes():
    wd = StepWatchdog(deadline_s=0.01, max_strikes=1)
    wd.start(0)
    import time
    time.sleep(0.05)
    with pytest.raises(TimeoutError):
        wd.check()


def test_retry_loop_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, {"x": jnp.zeros(1)}, blocking=True)
    calls = []

    def run_from(start):
        calls.append(start)
        if len(calls) == 1:
            raise RuntimeError("injected node failure")
        return 99

    assert retry_loop(run_from, ckpt_mgr=mgr) == 99
    assert calls == [10, 10]  # resumed from latest ckpt both times


def test_train_resume_after_injected_failure(tmp_path):
    """End-to-end drill: crash at step 12, auto-restart from step 9."""
    inj = FailureInjector({12: RuntimeError("simulated device loss")})
    out = train("mamba2-130m-smoke", steps=16, batch=4, seq=64,
                ckpt_dir=str(tmp_path), ckpt_every=5, injector=inj,
                log_every=100)
    assert out["final_step"] == 15
    # loop ran past the failure; more loss entries than steps (replayed)
    assert len(out["losses"]) >= 16


def test_adamw_compression_error_feedback():
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                            grad_compress=True, clip_norm=0.0,
                            weight_decay=0.0)
    params = {"w": jnp.ones((8,), jnp.float32)}
    st = adamw.init_state(params, cfg)
    g = {"w": jnp.full((8,), 1e-3, jnp.float32)}
    # many tiny identical grads: without error feedback int8 would crush
    # them to zero forever; with EF they accumulate and get applied.
    p = params
    for _ in range(50):
        p, st, _ = adamw.update(g, st, p, cfg)
    assert float(p["w"][0]) < 1.0  # the updates got through


def test_train_loss_decreases():
    out = train("granite-moe-1b-a400m-smoke", steps=40, batch=8, seq=64,
                log_every=100,
                opt_cfg=adamw.AdamWConfig(lr=5e-3, warmup_steps=5,
                                          total_steps=40))
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.05, f"no learning: {first:.3f} -> {last:.3f}"
