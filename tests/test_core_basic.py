"""End-to-end correctness of the COX pipeline on the paper's own examples."""
import numpy as np
import pytest

from repro.core import cox
from repro.core.oracle import run_grid as oracle_run


# ---- Paper Code 1: warp-shuffle reduction inside an if (motivating example)
@cox.kernel
def reduce_first_warp(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
    tid = c.thread_idx()
    v = val[tid]
    if tid < 32:
        offset = 16
        while offset > 0:
            s = c.shfl_down(v, offset)
            v = v + s
            offset = offset // 2
    if tid == 0:
        out[0] = v


# ---- Paper Code 4: warp vote
@cox.kernel
def vote_all_kernel(c, result: cox.Array(cox.i32)):
    tx = c.thread_idx()
    p = tx % 2
    r = c.vote_all(p)
    result[tx] = c.i32(r)


@cox.kernel
def vec_add(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
            b: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = a[i] + b[i]


# ---- block-barrier tree reduction in shared memory (SDK reduce0 shape)
@cox.kernel
def block_reduce_shared(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
    tile = c.shared((256,), cox.f32)
    tid = c.thread_idx()
    tile[tid] = val[c.block_idx() * c.block_dim() + tid]
    c.syncthreads()
    s = 128
    while s > 0:
        if tid < s:
            tile[tid] = tile[tid] + tile[tid + s]
        c.syncthreads()
        s = s // 2
    if tid == 0:
        out[c.block_idx()] = tile[0]


def test_code1_reduction_matches_oracle_and_math():
    b_size = 128
    val = np.arange(b_size, dtype=np.float32)
    out0 = np.zeros(1, np.float32)
    ref = oracle_run(reduce_first_warp.ir, grid=1, block=b_size,
                     args=(out0, val))
    assert np.allclose(ref["out"], val[:32].sum())
    got = reduce_first_warp.launch(grid=1, block=b_size, args=(out0, val))
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"])


@pytest.mark.parametrize("mode", ["jit", "normal"])
@pytest.mark.parametrize("simd", [True, False])
def test_vote_all_modes(mode, simd):
    res0 = np.zeros(64, np.int32)
    ref = oracle_run(vote_all_kernel.ir, grid=1, block=64, args=(res0,))
    got = vote_all_kernel.launch(grid=1, block=64, args=(res0,),
                                 mode=mode, simd=simd)
    np.testing.assert_array_equal(np.asarray(got["result"]), ref["result"])


@pytest.mark.parametrize("collapse", ["flat", "hier", "hybrid"])
def test_vec_add_collapse_modes(collapse):
    n = 1000
    a = np.random.default_rng(0).normal(size=1024).astype(np.float32)
    b = np.random.default_rng(1).normal(size=1024).astype(np.float32)
    out0 = np.zeros(1024, np.float32)
    got = vec_add.launch(grid=4, block=256, args=(out0, a, b, n),
                         collapse=collapse)
    want = np.where(np.arange(1024) < n, a + b, 0)
    np.testing.assert_allclose(np.asarray(got["out"]), want)


def test_block_reduce_shared_matches_oracle():
    val = np.random.default_rng(2).normal(size=512).astype(np.float32)
    out0 = np.zeros(2, np.float32)
    ref = oracle_run(block_reduce_shared.ir, grid=2, block=256,
                     args=(out0, val))
    got = block_reduce_shared.launch(grid=2, block=256, args=(out0, val))
    np.testing.assert_allclose(np.asarray(got["out"]), ref["out"], rtol=1e-5)
    np.testing.assert_allclose(ref["out"],
                               val.reshape(2, 256).sum(1), rtol=1e-4)


def test_flat_rejects_warp_features():
    from repro.core.flat import FlatUnsupported
    with pytest.raises(FlatUnsupported):
        reduce_first_warp.launch(grid=1, block=64,
                                 args=(np.zeros(1, np.float32),
                                       np.zeros(64, np.float32)),
                                 collapse="flat")


def test_hybrid_picks_flat_for_warp_free():
    assert not vec_add.uses_warp_features()
    assert reduce_first_warp.uses_warp_features()
