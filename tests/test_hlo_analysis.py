"""The while-aware HLO analyzer must agree with a fully-unrolled compile
(the validation behind every §Roofline number)."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.hlo_analysis import analyze, xla_cost
from repro.models import lm
from repro.models.params import tree_abstract


def _compile(cfg, unroll: bool):
    ab = tree_abstract(lm.lm_specs(cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 128), jnp.int32)}
    import repro.models.lm as lmod
    orig = lmod._scan_layers
    if unroll:
        def unrolled(layer_fn, stacked, x, remat, rules=None):
            L = jax.tree_util.tree_leaves(stacked)[0].shape[0]
            for i in range(L):
                lp = jax.tree_util.tree_map(lambda a: a[i], stacked)
                x = layer_fn(lp, x)
            return x
        lmod._scan_layers = unrolled
    try:
        def f(p, b):
            return lm.forward(cfg, p, b, backend="xla")[0]
        return jax.jit(f).lower(ab, batch).compile()
    finally:
        lmod._scan_layers = orig


def test_scan_corrected_flops_match_unrolled():
    cfg = dataclasses.replace(registry.get("qwen2.5-14b", smoke=True),
                              n_layers=4, remat="none")
    a_scan = analyze(_compile(cfg, unroll=False).as_text())
    c_unroll = _compile(cfg, unroll=True)
    a_unroll = analyze(c_unroll.as_text())
    # while-trip attribution == unrolled program, exactly
    assert abs(a_scan["flops"] - a_unroll["flops"]) \
        <= 0.01 * a_unroll["flops"]
    # and within 10% of XLA's own count on the unrolled module
    # (we count dot FLOPs only; XLA adds elementwise)
    xla = xla_cost(c_unroll)["flops"]
    assert a_unroll["flops"] <= xla
    assert a_unroll["flops"] >= 0.85 * xla


def test_scan_correction_is_large():
    """The raw cost_analysis undercount this analyzer exists to fix."""
    cfg = dataclasses.replace(registry.get("qwen2.5-14b", smoke=True),
                              n_layers=4, remat="none")
    c = _compile(cfg, unroll=False)
    corrected = analyze(c.as_text())["flops"]
    raw = xla_cost(c)["flops"]
    assert corrected > 1.5 * raw  # 4 scanned layers counted once in raw


def test_dot_flops_mixed_format_operands():
    """Mixed-format HLO: lhs printed as a bare name (symbol table), rhs
    with an inline type — the rhs shape must not be taken as the lhs."""
    hlo = """\
HloModule m

ENTRY %main (x: f32[8,16], y: f32[16,32]) -> f32[8,32] {
  %x = f32[8,16]{1,0} parameter(0)
  %y = f32[16,32]{1,0} parameter(1)
  ROOT %d = f32[8,32]{1,0} dot(%x, f32[16,32]{1,0} %y), \
lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    assert analyze(hlo)["flops"] == 2 * 8 * 32 * 16


def test_dot_flops_inline_lhs_type():
    """Both operands carrying inline types still resolves the lhs."""
    hlo = """\
HloModule m

ENTRY %main (x: f32[8,16], y: f32[16,32]) -> f32[8,32] {
  %x = f32[8,16]{1,0} parameter(0)
  %y = f32[16,32]{1,0} parameter(1)
  ROOT %d = f32[8,32]{1,0} dot(f32[8,16]{1,0} %x, f32[16,32]{1,0} %y), \
lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    assert analyze(hlo)["flops"] == 2 * 8 * 32 * 16
