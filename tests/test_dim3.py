"""dim3 launch geometry, end to end.

The contract under test: launch geometry is CUDA ``dim3`` at every
interface (frontend intrinsics, plan, runtime, cache) while the
internal schedule stays *linear* — threads linearize x-fastest into
warps (``lin = x + bdim.x * (y + bdim.y * z)``), blocks linearize the
same way into the grid walk.  Covers the decomposition round-trip
(hypothesis-randomized geometries incl. partial last warps and
non-multiple-of-32 x*y blocks), the per-thread oracle, CUDA's launch
limits, launch-cache normalization (``grid=4`` == ``grid=(4,1,1)``),
and bitwise backend x warp_exec equivalence for the 2-D suite kernels.
"""
import numpy as np
import pytest

from benchmarks.kernels_suite import all_kernels
from repro.core import cox
from repro.core.oracle import run_grid as oracle_run
from repro.core.types import CoxUnsupported, Dim3, as_dim3

try:  # hypothesis drives the randomized-geometry properties in CI; a
    # seeded numpy fallback keeps them exercised where it is absent
    from hypothesis import assume, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# normalization + limits
# ---------------------------------------------------------------------------


def test_as_dim3_normalizes():
    assert as_dim3(5) == Dim3(5, 1, 1)
    assert as_dim3((7,)) == Dim3(7, 1, 1)
    assert as_dim3((2, 3)) == Dim3(2, 3, 1)
    assert as_dim3([2, 3, 4]) == Dim3(2, 3, 4)
    assert as_dim3(Dim3(1, 2, 3)) == Dim3(1, 2, 3)
    assert as_dim3(np.int64(6)) == Dim3(6, 1, 1)
    assert as_dim3((2, 3)).total == 6
    with pytest.raises(ValueError):
        as_dim3(0)
    with pytest.raises(ValueError):
        as_dim3((4, -1))
    with pytest.raises(ValueError):
        as_dim3((1, 2, 3, 4))
    with pytest.raises(TypeError):
        as_dim3("x")
    with pytest.raises(TypeError):
        as_dim3((1.5, 2))


@cox.kernel
def _k_copy(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = a[i]


def _copy_args(n=64):
    return (np.zeros(n, np.float32), np.ones(n, np.float32), n)


def test_cuda_launch_limits_enforced():
    # total threads per block
    with pytest.raises(CoxUnsupported):
        _k_copy.launch(grid=1, block=(1024, 2), args=_copy_args())
    # per-axis block caps (total fine, z over 64)
    with pytest.raises(CoxUnsupported):
        _k_copy.launch(grid=1, block=(1, 1, 128), args=_copy_args())
    # grid y/z cap at 65535
    with pytest.raises(CoxUnsupported):
        _k_copy.launch(grid=(1, 70000), block=32, args=_copy_args())
    with pytest.raises(ValueError):
        _k_copy.launch(grid=0, block=32, args=_copy_args())


def test_axis_argument_validation():
    with pytest.raises(CoxUnsupported):
        @cox.kernel
        def _bad_lane(c, o: cox.Array(cox.f32)):
            i = c.lane_id('y')
            o[i] = 1.0
    with pytest.raises(CoxUnsupported):
        @cox.kernel
        def _bad_axis(c, o: cox.Array(cox.f32)):
            i = c.thread_idx('w')
            o[i] = 1.0
    with pytest.raises(CoxUnsupported):
        @cox.kernel
        def _bad_dynamic(c, o: cox.Array(cox.f32), ax: cox.i32):
            i = c.thread_idx(ax)
            o[i] = 1.0


# ---------------------------------------------------------------------------
# linearization / decomposition round-trip
# ---------------------------------------------------------------------------


@cox.kernel
def _k_geom(c, tx: cox.Array(cox.i32), ty: cox.Array(cox.i32),
            tz: cox.Array(cox.i32), bx: cox.Array(cox.i32),
            by: cox.Array(cox.i32), bz: cox.Array(cox.i32),
            cnt: cox.Array(cox.i32)):
    # re-linearize the decomposed ids x-fastest; a correct decomposition
    # makes g a bijection onto the launch's thread slots (cnt == 1)
    lin = c.thread_idx('x') + c.block_dim('x') * (
        c.thread_idx('y') + c.block_dim('y') * c.thread_idx('z'))
    blin = c.block_idx('x') + c.grid_dim('x') * (
        c.block_idx('y') + c.grid_dim('y') * c.block_idx('z'))
    nthreads = c.block_dim('x') * c.block_dim('y') * c.block_dim('z')
    g = blin * nthreads + lin
    tx[g] = c.thread_idx('x')
    ty[g] = c.thread_idx('y')
    tz[g] = c.thread_idx('z')
    bx[g] = c.block_idx('x')
    by[g] = c.block_idx('y')
    bz[g] = c.block_idx('z')
    cnt[g] += 1


def _geom_ref(grid3: Dim3, block3: Dim3):
    """Per-slot reference components, x-fastest linearization."""
    nt, nb = block3.total, grid3.total
    t = np.arange(nt, dtype=np.int32)
    b = np.arange(nb, dtype=np.int32)
    tx = t % block3.x
    ty = (t // block3.x) % block3.y
    tz = t // (block3.x * block3.y)
    bx = b % grid3.x
    by = (b // grid3.x) % grid3.y
    bz = b // (grid3.x * grid3.y)
    def tile(v):
        return np.tile(v, nb)

    def rep(v):
        return np.repeat(v, nt)
    return {"tx": tile(tx), "ty": tile(ty), "tz": tile(tz),
            "bx": rep(bx), "by": rep(by), "bz": rep(bz)}


def _check_geometry(grid, block, **launch_kw):
    grid3, block3 = as_dim3(grid), as_dim3(block)
    n = grid3.total * block3.total
    args = tuple(np.zeros(n, np.int32) for _ in range(7))
    out = _k_geom.launch(grid=grid, block=block, args=args, **launch_kw)
    ref = _geom_ref(grid3, block3)
    for k, want in ref.items():
        np.testing.assert_array_equal(np.asarray(out[k]), want,
                                      err_msg=f"{k} @ {grid3}x{block3}")
    # bijectivity: every slot written exactly once (also proves partial
    # last warps masked the dead lanes rather than scribbling)
    np.testing.assert_array_equal(np.asarray(out["cnt"]), np.ones(n))


@pytest.mark.parametrize("grid,block", [
    (2, 64),              # pure 1-D through the dim3 path
    ((2, 2), (16, 16)),   # the SDK's classic tile shape
    ((3, 2), (20, 3)),    # x*y = 60: 2 warps, partial last warp
    ((2, 1, 2), (33, 2)), # 66 threads: non-multiple-of-32 x*y, 3-D grid
    ((1, 2, 2), (7, 5, 3)),  # full 3-D, 105 threads
    ((5,), (1, 1, 64)),   # degenerate x, all threads along z
])
def test_geometry_round_trip_fixed(grid, block):
    _check_geometry(grid, block)


def test_geometry_round_trip_batched_warps():
    # the batched (n_warps, W) lane plane decomposes the same ids
    _check_geometry((2, 2), (16, 16), warp_exec="batched")
    _check_geometry((3, 2), (20, 3), warp_exec="batched")


def _pure_round_trip(bx, by, bz, lin):
    """decompose(lin) relinearizes to lin for every in-range linear id
    (the executor and oracle share this formula)."""
    x, y, z = lin % bx, (lin // bx) % by, lin // (bx * by)
    assert 0 <= x < bx and 0 <= y < by and 0 <= z < bz
    assert x + bx * (y + by * z) == lin


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(gx=st.integers(1, 3), gy=st.integers(1, 3), gz=st.integers(1, 2),
           bx=st.integers(1, 40), by=st.integers(1, 5), bz=st.integers(1, 3))
    def test_geometry_round_trip_random(gx, gy, gz, bx, by, bz):
        assume(bx * by * bz <= 128)
        _check_geometry((gx, gy, gz), (bx, by, bz))

    @settings(max_examples=200, deadline=None)
    @given(bx=st.integers(1, 64), by=st.integers(1, 64),
           bz=st.integers(1, 64), lin=st.integers(0, 1024 - 1))
    def test_decompose_relinearize_pure(bx, by, bz, lin):
        assume(lin < bx * by * bz)
        _pure_round_trip(bx, by, bz, lin)
else:
    def test_geometry_round_trip_random():
        rng = np.random.default_rng(1234)
        done = 0
        while done < 8:
            gx, gy, gz = rng.integers(1, 4), rng.integers(1, 4), \
                rng.integers(1, 3)
            bx, by, bz = rng.integers(1, 41), rng.integers(1, 6), \
                rng.integers(1, 4)
            if bx * by * bz > 128:
                continue
            _check_geometry((int(gx), int(gy), int(gz)),
                            (int(bx), int(by), int(bz)))
            done += 1

    def test_decompose_relinearize_pure():
        rng = np.random.default_rng(99)
        done = 0
        while done < 500:
            bx, by, bz = (int(v) for v in rng.integers(1, 65, size=3))
            lin = int(rng.integers(0, 1024))
            if lin >= bx * by * bz:
                continue
            _pure_round_trip(bx, by, bz, lin)
            done += 1


# ---------------------------------------------------------------------------
# oracle agreement + 1-D equivalence of bare intrinsics
# ---------------------------------------------------------------------------


def test_geom_probe_matches_oracle():
    grid, block = (2, 3), (8, 5)  # 40 threads: partial last warp
    n = 6 * 40
    args = tuple(np.zeros(n, np.int32) for _ in range(7))
    got = _k_geom.launch(grid=grid, block=block, args=args)
    ref = oracle_run(_k_geom.ir, grid=grid, block=block, args=args)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(got[k]), ref[k],
                                      err_msg=k)


def test_bare_intrinsics_are_axis_x():
    """A 1-D kernel launched with explicit dim3 tuples is bitwise
    identical to the bare int launch."""
    args = _copy_args()
    want = _k_copy.launch(grid=2, block=32, args=args)
    got = _k_copy.launch(grid=(2, 1, 1), block=(32,), args=args)
    np.testing.assert_array_equal(np.asarray(got["out"]),
                                  np.asarray(want["out"]))


# ---------------------------------------------------------------------------
# launch cache: normalized dim3 keys + stable compile token
# ---------------------------------------------------------------------------


@cox.kernel
def _k_cache(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    out[i] = a[i] + 1.0


def test_cache_hits_on_equivalent_dim3():
    args = (np.zeros(256, np.float32), np.ones(256, np.float32))
    _k_cache.launch(grid=4, block=64, args=args)
    n1 = len(_k_cache._launch_cache)
    _k_cache.launch(grid=(4, 1, 1), block=(64,), args=args)
    assert len(_k_cache._launch_cache) == n1      # grid=4 == (4,1,1): hit
    _k_cache.launch(grid=(2, 2), block=64, args=args)
    assert len(_k_cache._launch_cache) == n1 + 1  # same total, new shape:
    #                                               bid decomposition differs


def test_cache_token_is_stable_not_object_id():
    """The first key element is the pass-pipeline cache key, not an
    ``id()`` that a recycled allocation could alias."""
    args = (np.zeros(64, np.float32), np.ones(64, np.float32))
    _k_cache.launch(grid=1, block=64, args=args)
    tokens = {k[0] for k in _k_cache._launch_cache}
    for token in tokens:
        choice, ws = token
        assert choice in ("flat", "hier") and isinstance(ws, int)


def test_resolution_is_shared_between_api_and_runtime():
    """api.KernelFn.launch and runtime.launch resolve through the same
    path — same plan geometry, same resolved knobs, dim3 accepted by
    both."""
    from repro.core import runtime
    args = (np.zeros(256, np.float32), np.ones(256, np.float32))
    ck = _k_cache.compiled(block=(8, 8))
    rl = runtime.resolve_launch(ck, grid=(2, 2), block=(8, 8))
    assert rl.grid == Dim3(2, 2, 1) and rl.block == Dim3(8, 8, 1)
    # hybrid picks flat collapsing here (no warp features): the whole
    # 64-thread block is one "warp"
    assert rl.n_warps == -(-64 // ck.warp_size)
    assert rl.mode in ("normal", "jit")
    out = runtime.launch(ck, grid=(2, 2), block=(8, 8), args=args)
    want = _k_cache.launch(grid=(2, 2), block=(8, 8), args=args)
    np.testing.assert_array_equal(np.asarray(out["out"]),
                                  np.asarray(want["out"]))
    plans = [p for (p, _) in _k_cache._launch_cache.values()]
    assert any(p.grid == 4 and p.block == 64
               and p.grid_dim == Dim3(2, 2, 1)
               and p.block_dim == Dim3(8, 8, 1) for p in plans)


# ---------------------------------------------------------------------------
# the 2-D suite kernels: backend x warp_exec cells, bitwise
# ---------------------------------------------------------------------------

_DIM3_PICKS = ["MatrixMulCUDA", "transpose", "stencil2d"]


@pytest.mark.parametrize("name", _DIM3_PICKS)
def test_dim3_kernels_all_cells_bitwise_and_oracle(name):
    sk = next(k for k in all_kernels() if k.name == name)
    args = sk.make_args()
    base = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                            backend="scan", warp_exec="serial")
    ref = oracle_run(sk.kernel.ir, grid=sk.grid, block=sk.block, args=args)
    for k in ref:
        np.testing.assert_allclose(np.asarray(base[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name}.{k} vs oracle")
    if sk.check is not None:
        assert sk.check({k: np.asarray(v) for k, v in base.items()})
    for backend in ("scan", "vmap"):
        for we in ("serial", "batched"):
            got = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                                   backend=backend, warp_exec=we, chunk=3)
            for k in base:
                np.testing.assert_array_equal(
                    np.asarray(got[k]), np.asarray(base[k]),
                    err_msg=f"{name}.{k}: {backend}/{we} != scan/serial")


@pytest.mark.parametrize("name", _DIM3_PICKS)
def test_dim3_kernels_sharded_one_device_mesh(name):
    import jax
    sk = next(k for k in all_kernels() if k.name == name)
    mesh = jax.make_mesh((1,), ("data",))
    args = sk.make_args()
    want = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                            backend="scan")
    got = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                           mesh=mesh, chunk=3)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]),
                                      err_msg=f"{name}.{k}")


def test_natural_2d_matmul_equals_hand_flattened_1d():
    """The dim3 rewrite of MatrixMulCUDA computes bit-for-bit what the
    hand-flattened 1-D port computes (same linearized schedule, same
    operation order per thread)."""
    mm2 = next(k for k in all_kernels() if k.name == "MatrixMulCUDA")
    mm1 = next(k for k in all_kernels() if k.name == "matrixMul1D")
    args = mm2.make_args()
    got2 = mm2.kernel.launch(grid=mm2.grid, block=mm2.block, args=args)
    got1 = mm1.kernel.launch(grid=mm1.grid, block=mm1.block, args=args)
    np.testing.assert_array_equal(np.asarray(got2["out"]),
                                  np.asarray(got1["out"]))
