"""Multi-device semantics (8 host devices via subprocess — the device
count must be fixed before jax initializes, so these run out-of-process):
COX grid launch sharded over a mesh equals single-device execution;
atomics merge with psum; MoE EP on a 2×4 mesh matches the local path."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_worker(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900, cwd=ROOT)
    assert r.returncode == 0, f"worker failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_cox_grid_sharded_matches_single():
    run_worker("""
        import jax, numpy as np
        import tests.multidevice_kernels as mk
        from repro.core.oracle import run_grid as oracle_run
        assert len(jax.devices()) == 8
        a = np.arange(2048, dtype=np.float32)
        b = np.ones(2048, np.float32)
        out0 = np.zeros(2048, np.float32)
        args = (out0, a, b, 2000)
        mesh = jax.make_mesh((8,), ("data",))
        got = mk.vec_madd.launch(grid=8, block=256, args=args, mesh=mesh)
        want = mk.vec_madd.launch(grid=8, block=256, args=args)
        np.testing.assert_allclose(np.asarray(got["out"]),
                                   np.asarray(want["out"]), rtol=1e-6)
        ref = oracle_run(mk.vec_madd.ir, grid=8, block=256, args=args)
        np.testing.assert_allclose(np.asarray(got["out"]), ref["out"],
                                   rtol=1e-5)
        print("grid-sharded OK")
    """)


def test_cox_atomics_psum_merge():
    run_worker("""
        import jax, numpy as np
        import tests.multidevice_kernels as mk
        a = np.random.default_rng(0).integers(0, 16, 1024).astype(np.int32)
        hist0 = np.zeros(16, np.float32)
        mesh = jax.make_mesh((8,), ("data",))
        got = mk.histogram.launch(grid=8, block=128, args=(hist0, a, 1024),
                                  mesh=mesh)
        want = np.bincount(a, minlength=16).astype(np.float32)
        np.testing.assert_allclose(np.asarray(got["hist"]), want)
        print("atomics OK")
    """)


def test_cox_grid_sync_sharded_8dev():
    # cooperative grid barrier across a real mesh: each device keeps its
    # slice of the grid resident across phases, and the per-phase
    # masked-psum merge is what lets phase-1 blocks on one device read
    # phase-0 partials written on every other device
    run_worker("""
        import jax, numpy as np
        from benchmarks.kernels_suite import all_kernels
        from repro.core.oracle import run_grid as oracle_run
        assert len(jax.devices()) == 8
        sk = next(k for k in all_kernels() if k.name == "gridReduce")
        args = sk.make_args()
        mesh = jax.make_mesh((8,), ("data",))
        got = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args,
                               mesh=mesh)
        ref = oracle_run(sk.kernel.ir, grid=sk.grid, block=sk.block,
                         args=args)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]), err_msg=k)
        print("grid-sync sharded OK")
    """)


def test_moe_ep_on_2x4_mesh():
    run_worker("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import registry
        from repro.models import layers as L
        from repro.models.params import default_rules, init_params
        cfg = registry.get("granite-moe-1b-a400m", smoke=True)  # 4 experts
        p = init_params(L.moe_specs(cfg), jax.random.PRNGKey(1))
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(4, 8, cfg.d_model)).astype(np.float32))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = default_rules(mesh)
        got = L.moe_apply(p, x, cfg=cfg, rules=rules)
        want = L.moe_apply(p, x, cfg=cfg, rules=None)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-4, atol=2e-4)
        print("moe EP OK")
    """)


def test_train_step_on_2x4_mesh():
    run_worker("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import registry
        from repro.configs.base import ShapeConfig
        from repro.parallel import steps as steps_mod
        from repro.models.params import init_params
        from repro.optim import adamw
        from repro.data.pipeline import TokenSource, DataConfig
        cfg = registry.get("qwen2.5-14b", smoke=True)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 64, 4, "train")
        jitted, bundle, abstract = steps_mod.jit_train_step(cfg, mesh, shape)
        params = jax.device_put(
            init_params(bundle["specs"], jax.random.PRNGKey(0)),
            bundle["param_sh"])
        opt = jax.device_put(adamw.init_state(params, bundle["opt_cfg"]),
                             bundle["opt_sh"])
        src = TokenSource(cfg, shape, DataConfig())
        b = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        params, opt, m = jitted(params, opt, b)
        assert np.isfinite(float(m["loss"]))
        print("sharded train step OK, loss", float(m["loss"]))
    """)
