"""Cooperative-grid sync: ``c.grid_sync()`` / ``this_grid().sync()``.

The tentpole contract: a grid barrier phase-splits the kernel
(repro.core.phases) into one executable per inter-sync segment; global
memory and per-block persistent state (carried locals + shared memory)
thread between phases; all three backends × both warp-execution flavors
are bitwise-identical to the phase-split per-thread oracle; and the
cooperative-launch constraint (every block resident per phase) is
enforced with clear errors, as are the static-alignment rules (no sync
inside divergent control flow or loops).
"""
import numpy as np
import pytest

from benchmarks.kernels_suite import all_kernels
from repro.core import cox
from repro.core.backends.plan import LaunchPlan
from repro.core.oracle import run_grid as oracle_run
from repro.core.phases import split_phases
from repro.core.types import COOP_MAX_RESIDENT_BLOCKS, CoxUnsupported

GRID_REDUCE = next(k for k in all_kernels() if k.name == "gridReduce")


def _launch(sk, args, **kw):
    out = sk.kernel.launch(grid=sk.grid, block=sk.block, args=args, **kw)
    return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# the acceptance kernel: two-pass grid-wide reduce, no host round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scan", "vmap"])
@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_grid_reduce_bitwise_matches_oracle(backend, warp_exec):
    sk = GRID_REDUCE
    args = sk.make_args()
    ref = oracle_run(sk.kernel.ir, grid=sk.grid, block=sk.block, args=args)
    got = _launch(sk, args, backend=backend, warp_exec=warp_exec)
    for k in ref:
        np.testing.assert_array_equal(got[k], np.asarray(ref[k]),
                                      err_msg=f"{backend}/{warp_exec}.{k}")
    assert got["total"][0] == np.asarray(args[2])[:args[3]].sum()


@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_grid_reduce_sharded_one_device_mesh(warp_exec):
    import jax
    sk = GRID_REDUCE
    mesh = jax.make_mesh((1,), ("data",))
    args = sk.make_args()
    want = _launch(sk, args, backend="scan", warp_exec="serial")
    got = _launch(sk, args, mesh=mesh, warp_exec=warp_exec)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


def test_grid_reduce_hier_collapse_matches_flat():
    # both collapse strategies phase-split identically
    sk = GRID_REDUCE
    args = sk.make_args()
    want = _launch(sk, args, collapse="flat")
    got = _launch(sk, args, collapse="hier")
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


# ---------------------------------------------------------------------------
# per-thread locals and atomics across the barrier
# ---------------------------------------------------------------------------


@cox.kernel
def _k_carried(c, out: cox.Array(cox.f32), scratch: cox.Array(cox.f32),
               a: cox.Array(cox.f32)):
    # v is loaded before the sync and stored after it: CUDA semantics say
    # the register lives for the thread's lifetime, so v must be carried
    # per-thread through the phase split (as a (n_warps, W) block plane)
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    v = a[i] * 2.0
    scratch[i] = v
    c.grid_sync()
    w = scratch[(i + 64) % 256]
    out[i] = v + w


def test_carried_locals_cross_the_sync():
    rng = np.random.default_rng(3)
    a = rng.normal(size=256).astype(np.float32)
    args = (np.zeros(256, np.float32), np.zeros(256, np.float32), a)
    ref = oracle_run(_k_carried.ir, grid=4, block=64, args=args)
    for backend in ("scan", "vmap"):
        got = _k_carried.launch(grid=4, block=64, args=args, backend=backend)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]),
                                          err_msg=f"{backend}.{k}")
    # phase 1 reads another block's phase-0 write — the barrier guarantee
    np.testing.assert_array_equal(
        np.asarray(ref["out"]), a * 2.0 + np.roll(a * 2.0, -64))


@cox.kernel
def _k_atomic_sync(c, hist: cox.Array(cox.f32), flags: cox.Array(cox.f32),
                   data: cox.Array(cox.i32), n: cox.i32):
    # atomics before the sync, reads of the settled totals after it: the
    # vmap/sharded delta merges must fold at the phase boundary
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, data[i], 1.0)
    c.grid_sync()
    if i < 64:
        flags[i] = 1.0 if hist[i] > 8.0 else 0.0


def test_atomics_settle_at_the_phase_boundary():
    rng = np.random.default_rng(5)
    data = rng.integers(0, 64, size=600).astype(np.int32)
    args = (np.zeros(64, np.float32), np.zeros(64, np.float32), data, 600)
    ref = oracle_run(_k_atomic_sync.ir, grid=6, block=128, args=args)
    for backend in ("scan", "vmap"):
        got = _k_atomic_sync.launch(grid=6, block=128, args=args,
                                    backend=backend)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]),
                                          err_msg=f"{backend}.{k}")


@cox.kernel
def _k_cg_alias(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    out[i] = a[i] + 1.0
    c.this_grid().sync()
    out[i] = out[i] + out[(i + 32) % 128]


def test_this_grid_sync_alias_parses_to_a_grid_barrier():
    assert len(split_phases(_k_cg_alias.ir)) == 2
    a = np.arange(128, dtype=np.float32)
    args = (np.zeros(128, np.float32), a)
    ref = oracle_run(_k_cg_alias.ir, grid=4, block=32, args=args)
    got = _k_cg_alias.launch(grid=4, block=32, args=args)
    np.testing.assert_array_equal(np.asarray(got["out"]),
                                  np.asarray(ref["out"]))


@cox.kernel
def _k_trailing_sync(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32)):
    i = c.thread_idx()
    out[i] = a[i] * 3.0
    c.grid_sync()


def test_trailing_sync_yields_an_empty_final_phase():
    assert len(split_phases(_k_trailing_sync.ir)) == 2
    a = np.ones(32, np.float32)
    got = _k_trailing_sync.launch(grid=1, block=32,
                                  args=(np.zeros(32, np.float32), a))
    np.testing.assert_array_equal(np.asarray(got["out"]), a * 3.0)


# ---------------------------------------------------------------------------
# static-alignment rejections: clear errors, not wrong answers
# ---------------------------------------------------------------------------


@cox.kernel
def _k_sync_in_if(c, out: cox.Array(cox.f32)):
    if c.block_idx() == 0:
        c.grid_sync()
    out[c.thread_idx()] = 1.0


@cox.kernel
def _k_sync_in_loop(c, out: cox.Array(cox.f32)):
    t = 0
    while t < 4:
        c.grid_sync()
        t = t + 1
    out[c.thread_idx()] = 1.0


def test_sync_inside_divergent_control_flow_rejected():
    with pytest.raises(CoxUnsupported, match="divergent control flow"):
        _k_sync_in_if.launch(grid=2, block=32, args=(np.zeros(32),))


def test_sync_inside_loop_rejected():
    with pytest.raises(CoxUnsupported, match="loop body"):
        _k_sync_in_loop.launch(grid=2, block=32, args=(np.zeros(32),))


def test_return_before_sync_rejected():
    import repro.core.kernel_ir as K
    from repro.core.types import BarrierLevel
    bad = K.Kernel("bad", list(_k_trailing_sync.ir.params), [], [
        K.Return(), K.Barrier(BarrierLevel.GRID)])
    with pytest.raises(CoxUnsupported, match="return before"):
        split_phases(bad)


# ---------------------------------------------------------------------------
# cooperative-launch constraint: every block resident per phase
# ---------------------------------------------------------------------------


def test_resident_capacity_enforced_when_chunked_pinned():
    """An explicit schedule='chunked' pins the all-resident wave, so a
    grid beyond the capacity still fails CUDA's occupancy rule."""
    sk = GRID_REDUCE
    with pytest.raises(CoxUnsupported, match="resident capacity"):
        sk.kernel.launch(grid=COOP_MAX_RESIDENT_BLOCKS + 1, block=sk.block,
                         args=sk.make_args(), schedule="chunked")


def test_resident_capacity_lowers_to_grid_stride():
    """Left on auto, a cooperative grid beyond the resident capacity is
    grid-strided — a capacity-sized wave pages blocks through each
    phase — instead of rejected (the PR 4 hard cap is now a lowering
    decision)."""
    from repro.core.runtime import resolve_launch
    ck = GRID_REDUCE.kernel.compiled(collapse="hier")
    rl = resolve_launch(ck, grid=COOP_MAX_RESIDENT_BLOCKS + 1,
                        block=GRID_REDUCE.block)
    assert rl.schedule == "grid_stride"
    assert rl.schedule_source == "cooperative"
    assert rl.n_resident == COOP_MAX_RESIDENT_BLOCKS
    assert rl.chunk == COOP_MAX_RESIDENT_BLOCKS


def test_explicit_chunk_that_splits_the_grid_rejected():
    sk = GRID_REDUCE
    with pytest.raises(CoxUnsupported, match="resident per"):
        sk.kernel.launch(grid=sk.grid, block=sk.block, args=sk.make_args(),
                         backend="vmap", chunk=3)


def test_coop_plan_pins_chunk_to_the_grid():
    ck = GRID_REDUCE.kernel.compiled(collapse="hier")
    plan = LaunchPlan.build(ck, grid=8, block=128)
    assert plan.n_phases == 2
    assert plan.chunk == 8
    assert plan.chunked_bids().shape == (1, 8)


# ---------------------------------------------------------------------------
# phase-split plumbing: single-phase identity + cache keys
# ---------------------------------------------------------------------------


def test_single_phase_kernels_compile_to_the_pre_phase_program():
    sk = next(k for k in all_kernels() if k.name == "vectorAdd")
    assert split_phases(sk.kernel.ir) == [sk.kernel.ir]
    ck = sk.kernel.compiled(collapse="hier")
    assert ck.phases == () and ck.n_phases == 1 and ck.carried == ()
    plan = LaunchPlan.build(ck, grid=2, block=256)
    assert plan.n_phases == 1 and plan.persist_spec() is None
    assert len(plan.block_fns(track_writes=False)) == 1


def test_launch_cache_keys_distinguish_phase_counts():
    sk_coop = GRID_REDUCE
    sk_plain = next(k for k in all_kernels() if k.name == "vectorAdd")
    sk_coop.kernel.launch(grid=sk_coop.grid, block=sk_coop.block,
                          args=sk_coop.make_args())
    sk_plain.kernel.launch(grid=sk_plain.grid, block=sk_plain.block,
                           args=sk_plain.make_args())
    # the phase count sits right after the compile token in every key
    coop_keys = list(sk_coop.kernel._launch_cache)
    plain_keys = list(sk_plain.kernel._launch_cache)
    assert all(k[1] == 2 for k in coop_keys)
    assert all(k[1] == 1 for k in plain_keys)
    # repeat launches hit the staged executable (no new entries)
    n = len(coop_keys)
    sk_coop.kernel.launch(grid=sk_coop.grid, block=sk_coop.block,
                          args=sk_coop.make_args())
    assert len(sk_coop.kernel._launch_cache) == n
