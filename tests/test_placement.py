"""Multi-device stream placement: streams mapped onto mesh devices.

The multi-device cases run out-of-process (the XLA host device count
must be fixed before jax initializes); the placement-independent
semantics — priorities, single-device no-op defaults, the device=/mesh=
contract, per-device sticky scoping — run in-process on the normal
single-device pool.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import cox
from repro.core.streams import Dispatcher

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_worker(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900, cwd=ROOT)
    assert r.returncode == 0, f"worker failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


PREAMBLE = """
    import jax, numpy as np
    from repro.core import cox
    from repro.core.streams import Dispatcher
    from repro.launch.mesh import device_pool
    # file-backed kernel (inspect.getsource can't see `python -c` code);
    # vec_madd computes out = 2*x + y
    from tests.multidevice_kernels import vec_madd as placeSaxpy
    assert len(jax.devices()) == 4

    grid, block = 8, 256
    n = grid * block
    rng = np.random.default_rng(0)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    o = np.zeros(n, np.float32)
    args = (o, x, y, n)
"""


# ---------------- multi-device (subprocess) ----------------


def test_round_robin_spread_and_bitwise_equality():
    # 4 streams over a 4-device pool: round-robin gives each stream its
    # own device (kept — affinity), every (backend, warp_exec) cell's
    # output is bitwise-equal to the unplaced single-device launch
    run_worker(PREAMBLE + """
    want = placeSaxpy.launch(grid=grid, block=block, args=args)["out"]
    d = Dispatcher(devices=device_pool(4))
    streams = [cox.Stream(f"s{i}", dispatcher=d) for i in range(4)]
    cells = [("scan", "serial"), ("scan", "batched"),
             ("vmap", "serial"), ("vmap", "batched")]
    for backend, we in cells:
        hs = [s.launch(placeSaxpy, grid=grid, block=block, args=args,
                       backend=backend, warp_exec=we) for s in streams]
        for h in hs:
            np.testing.assert_array_equal(
                np.asarray(h.result()["out"]), np.asarray(want),
                err_msg=f"{backend}/{we}")
    devs = [s.device for s in streams]
    assert all(dv is not None for dv in devs), devs
    assert len({dv.id for dv in devs}) == 4, devs  # spread, one each
    health = d.device_health()
    used = {k: c for k, c in health.items() if c["dispatches"] > 0}
    assert len(used) == 4, health
    # affinity: a second round keeps every stream on its device
    hs = [s.launch(placeSaxpy, grid=grid, block=block, args=args)
          for s in streams]
    for h in hs:
        h.result()
    assert [s.device.id for s in streams] == [dv.id for dv in devs]
    print("spread OK")
    """)


def test_cross_device_event_and_data_edges():
    # producer pinned to device 0, consumer pinned to device 1: the
    # data edge crosses devices through an explicit transfer, the event
    # edge orders them, and the consumer's output lives on device 1
    run_worker(PREAMBLE + """
    d = Dispatcher(devices=device_pool(4))
    dev0, dev1 = d.devices[0], d.devices[1]
    s0 = cox.Stream("prod", dispatcher=d, device=dev0)
    s1 = cox.Stream("cons", dispatcher=d, device=dev1)
    h0 = s0.launch(placeSaxpy, grid=grid, block=block, args=args)
    ev = s0.record_event()
    s1.wait_event(ev)
    h1 = s1.launch(placeSaxpy, grid=grid, block=block,
                   args=(o, h0.outputs["out"], y, n))
    out = h1.result()["out"]
    assert set(out.devices()) == {dev1}, out.devices()
    want = 2.0 * (2.0 * x + y) + y
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)
    assert set(h0.result()["out"].devices()) == {dev0}
    print("cross-device edges OK")
    """)


def test_health_aware_routing_and_device_reset():
    # a sticky device fault poisons ONE device: placement routes new
    # work around it, the poisoned stream re-places off it, and
    # device_reset(device=...) restores just that device
    run_worker(PREAMBLE + """
    d = Dispatcher(devices=device_pool(4),
                   placement=cox.HealthAwarePlacement())
    s = cox.Stream("victim", dispatcher=d)
    with cox.faults.inject("vec_madd", site="sticky-device", times=1):
        h = s.launch(placeSaxpy, grid=grid, block=block, args=args)
        try:
            h.result()
            raise SystemExit("sticky fault did not surface")
        except cox.CoxDeviceError:
            pass
    bad = s.device
    health = d.health()
    assert len(health["sticky_devices"]) == 1, health["sticky_devices"]
    assert health["devices"][str(bad)]["failures"] == 1, health["devices"]
    # enqueue still works: healthy devices remain, placement avoids bad
    others = [cox.Stream(f"n{i}", dispatcher=d) for i in range(6)]
    hs = [st.launch(placeSaxpy, grid=grid, block=block, args=args)
          for st in others]
    for h2 in hs:
        np.testing.assert_array_equal(np.asarray(h2.result()["out"]),
                                      2.0 * x + y)
    assert all(st.device.id != bad.id for st in others), \\
        [(st.name, st.device) for st in others]
    # the poisoned stream itself routes off its old device and recovers
    h3 = s.launch(placeSaxpy, grid=grid, block=block, args=args)
    h3.result()
    assert s.device.id != bad.id, (s.device, bad)
    # single-device recovery: only the poisoned device's state clears
    d.device_reset(device=bad)
    assert d.health()["sticky_devices"] == {}
    fresh = cox.Stream("fresh", dispatcher=d)
    fresh.launch(placeSaxpy, grid=grid, block=block, args=args).result()
    print("health routing OK")
    """)


def test_graph_replay_on_placed_device():
    # a graph captured on a pinned stream inherits the pin: the fused
    # replay executable runs there and its outputs live there
    run_worker(PREAMBLE + """
    d = Dispatcher(devices=device_pool(4))
    dev2 = d.devices[2]
    s = cox.Stream("gcap", dispatcher=d, device=dev2)
    g = cox.Graph(name="placed-chain")
    with g.capture(s):
        h = s.launch(placeSaxpy, grid=grid, block=block, args=args)
        s.launch(placeSaxpy, grid=grid, block=block,
                 args=(o, h.outputs["out"], y, n))
    exe = g.instantiate()
    assert exe.device is dev2, exe.device
    out = exe.replay()["out"]
    assert set(out.devices()) == {dev2}, out.devices()
    he = s.launch(placeSaxpy, grid=grid, block=block, args=args)
    he2 = s.launch(placeSaxpy, grid=grid, block=block,
                   args=(o, he.outputs["out"], y, n))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(he2.result()["out"]))
    print("placed graph replay OK")
    """)


# ---------------- placement-independent semantics (in-process) ----------


@cox.kernel
def prioAdd(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
            n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = x[i] + 1.0


def _req(kern, n=256):
    x = np.arange(n, dtype=np.float32)
    return kern.make_request(grid=1, block=n,
                             args=(np.zeros(n, np.float32), x, n))


def test_priority_orders_ready_set():
    # among simultaneously-ready independent requests the dispatcher
    # serves lower priority numbers first (CUDA stream priorities);
    # enqueue via the dispatcher directly so nothing flushes early
    d = Dispatcher()
    lo = cox.Stream("lo", dispatcher=d, priority=5)
    hi = cox.Stream("hi", dispatcher=d, priority=-5)
    mid = cox.Stream("mid", dispatcher=d)
    hs = [d.enqueue(_req(prioAdd), lo),
          d.enqueue(_req(prioAdd), mid),
          d.enqueue(_req(prioAdd), hi)]
    d.flush()
    for h in hs:
        h.result()
    seqs = {h.request.seq: h.stream.name for h in hs}
    order = [seqs[s] for s in d.dispatch_log if s in seqs]
    assert order == ["hi", "mid", "lo"], order
    assert [h.request.priority for h in hs] == [5, 0, -5]


def test_program_order_beats_priority_within_stream():
    # priority never reorders one stream's in-order queue: a stream's
    # second launch stays behind its first even if a higher-priority
    # request from another stream lands between them
    d = Dispatcher()
    lo = cox.Stream("lo2", dispatcher=d, priority=5)
    hi = cox.Stream("hi2", dispatcher=d, priority=-5)
    h1 = d.enqueue(_req(prioAdd), lo)
    h2 = d.enqueue(_req(prioAdd), lo)
    h3 = d.enqueue(_req(prioAdd), hi)
    d.flush()
    for h in (h1, h2, h3):
        h.result()
    pos = {h.request.seq: i for i, h in enumerate((h1, h2, h3))}
    order = [pos[s] for s in d.dispatch_log if s in pos]
    assert order.index(0) < order.index(1), order
    assert order[0] == 2, order  # hi dispatched first overall


def test_single_device_pool_is_legacy_path():
    # one device in the pool: no placement, no transfers — request
    # device stays None and the stage key's device slot records that
    d = Dispatcher()
    assert len(d.devices) == 1
    s = cox.Stream("solo", dispatcher=d)
    h = s.launch(prioAdd, grid=1, block=256,
                 args=(np.zeros(256, np.float32),
                       np.arange(256, dtype=np.float32), 256))
    h.result()
    assert h.request.device is None
    assert h.request.stage_key()[-1] is None
    assert s.device is None


def test_explicit_device_pin_single_pool():
    # an explicit device= runs there even on a 1-device pool, and the
    # staged executable is keyed per-device
    dev0 = jax.devices()[0]
    d = Dispatcher()
    s = cox.Stream("pin", dispatcher=d, device=dev0)
    x = np.arange(256, dtype=np.float32)
    h = s.launch(prioAdd, grid=1, block=256,
                 args=(np.zeros(256, np.float32), x, 256))
    out = h.result()["out"]
    assert h.request.device is dev0
    assert h.request.stage_key()[-1] == dev0.id
    np.testing.assert_array_equal(np.asarray(out), x + 1.0)


def test_device_and_mesh_are_mutually_exclusive():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(cox.CoxUnsupported, match="mutually exclusive"):
        prioAdd.make_request(grid=1, block=256,
                             args=(np.zeros(256, np.float32),
                                   np.arange(256, dtype=np.float32), 256),
                             device=jax.devices()[0], mesh=mesh)


def test_per_device_sticky_scoped_and_reset():
    # a sticky fault on a placed launch poisons that device, blocks the
    # (exhausted) pool, and device_reset(device=...) — not a full
    # reset — restores it
    dev0 = jax.devices()[0]
    d = Dispatcher()
    s = cox.Stream("sick", dispatcher=d, device=dev0)
    x = np.arange(256, dtype=np.float32)
    arr = (np.zeros(256, np.float32), x, 256)
    with cox.faults.inject("prioAdd", site="sticky-device", times=1):
        h = s.launch(prioAdd, grid=1, block=256, args=arr)
        with pytest.raises(cox.CoxDeviceError):
            h.result()
    assert list(d.health()["sticky_devices"]) == [str(dev0)]
    # every pool device is poisoned -> enqueue fails fast, CUDA-style
    s2 = cox.Stream("after", dispatcher=d)
    with pytest.raises(cox.CoxDeviceError):
        s2.launch(prioAdd, grid=1, block=256, args=arr)
    d.device_reset(device=dev0)
    assert d.health()["sticky_devices"] == {}
    out = s2.launch(prioAdd, grid=1, block=256, args=arr).result()["out"]
    np.testing.assert_array_equal(np.asarray(out), x + 1.0)
