"""Per-architecture smoke tests: reduced config, one forward (train) step
and one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import encdec, lm
from repro.models.params import init_params

ARCHS = registry.names()


def make_batch(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        return {
            "frontend": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        }
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.n_frontend_tokens:
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model))
            .astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = registry.get(arch, smoke=True)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    if cfg.family == "encdec":
        specs = encdec.encdec_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        loss, logits = jax.jit(
            lambda p, b: encdec.forward(cfg, p, b, backend="xla"))(params, batch)
        assert logits.shape[:2] == (B, S)
    else:
        specs = lm.lm_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        loss, logits = jax.jit(
            lambda p, b: lm.forward(cfg, p, b, backend="xla"))(params, batch)
        assert logits.shape[:2] == (B, S)
    assert logits.shape[-1] >= cfg.vocab
    assert np.isfinite(float(loss)), f"loss not finite: {loss}"
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # sane CE at init: close to log(vocab)
    assert float(loss) < np.log(cfg.vocab) + 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg = registry.get(arch, smoke=True)
    B, S = 2, 64
    key = jax.random.PRNGKey(1)
    if cfg.family == "encdec":
        specs = encdec.encdec_specs(cfg)
        params = init_params(specs, key)
        cache = init_params(encdec.cache_specs(cfg, B, S, enc_len=16),
                            jax.random.PRNGKey(2))
        tokens = jnp.zeros((B,), jnp.int32)
        pos = jnp.array([3, 7], jnp.int32)
        logits, new_cache = jax.jit(
            lambda p, c, t, q: encdec.decode_step(cfg, p, c, t, q,
                                                  backend="xla"))(
            params, cache, tokens, pos)
    else:
        specs = lm.lm_specs(cfg)
        params = init_params(specs, key)
        cache = init_params(lm.cache_specs(cfg, B, S), jax.random.PRNGKey(2))
        tokens = jnp.zeros((B,), jnp.int32)
        pos = jnp.array([3, 7], jnp.int32)
        logits, new_cache = jax.jit(
            lambda p, c, t, q: lm.decode_step(cfg, p, c, t, q,
                                              backend="xla"))(
            params, cache, tokens, pos)
    assert logits.shape[0] == B
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = registry.get("qwen2.5-14b", smoke=True)
    B, S = 1, 8
    specs = lm.lm_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    _, full_logits = lm.forward(cfg, params, batch, backend="xla")

    cache = init_params(lm.cache_specs(cfg, B, S), jax.random.PRNGKey(1))
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(cfg, params, cache, tokens[:, t],
                                       jnp.full((B,), t, jnp.int32),
                                       backend="xla")
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    cfg = registry.get("mamba2-130m", smoke=True)
    B, S = 1, 8
    specs = lm.lm_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    _, full_logits = lm.forward(cfg, params, batch, backend="xla")
    cache = init_params(lm.cache_specs(cfg, B, S), jax.random.PRNGKey(1))
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(cfg, params, cache, tokens[:, t],
                                       jnp.full((B,), t, jnp.int32),
                                       backend="xla")
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
