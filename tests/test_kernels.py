"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import norms, ref, softmax as sm, ssd_scan, warp_reduce

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale).astype(dtype)


@pytest.mark.parametrize("rows,cols", [(8, 128), (16, 256), (3, 128),
                                       (8, 4096), (1, 512)])
@pytest.mark.parametrize("op", ["sum", "max", "absmax"])
def test_row_reduce(rows, cols, op):
    x = rand((rows, cols))
    got = warp_reduce.row_reduce(x, op, interpret=True)
    want = ref.row_reduce(x, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 128), (4, 16, 256), (2, 8, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_softmax(shape, dtype):
    x = rand(shape, dtype, scale=3.0)
    got = sm.softmax(x, interpret=True)
    want = ref.softmax(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-3)


@pytest.mark.parametrize("shape", [(8, 128), (16, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = rand(shape, dtype)
    w = rand((shape[-1],), dtype, 0.5)
    got = norms.rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


def test_layernorm():
    x = rand((8, 256))
    w = rand((256,), scale=0.5)
    b = rand((256,), scale=0.1)
    got = norms.layernorm(x, w, b, interpret=True)
    want = ref.layernorm(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,H,Hkv,D", [(256, 4, 4, 64), (256, 8, 2, 64),
                                       (128, 4, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(S, H, Hkv, D, causal):
    q = rand((S, H, D), scale=0.5)
    k = rand((S, Hkv, D), scale=0.5)
    v = rand((S, Hkv, D), scale=0.5)
    got = fa.flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                             interpret=True)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_windowed():
    S, H, D = 256, 2, 64
    q, k, v = rand((S, H, D)), rand((S, H, D)), rand((S, H, D))
    got = fa.flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64,
                             interpret=True)
    want = ref.attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,H,Hkv,D,kvlen", [(512, 8, 2, 64, 300),
                                             (256, 4, 1, 64, 256),
                                             (512, 4, 4, 128, 17)])
def test_flash_decode(S, H, Hkv, D, kvlen):
    q = rand((H, D), scale=0.5)
    k = rand((S, Hkv, D), scale=0.5)
    v = rand((S, Hkv, D), scale=0.5)
    got = fa.flash_decode(q, k, v, kvlen, bk=128, interpret=True)
    want = ref.decode_attention(q, k, v, kvlen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,H,P,N,chunk", [(256, 2, 64, 32, 64),
                                           (128, 4, 32, 16, 128),
                                           (512, 1, 128, 64, 128)])
def test_ssd_scan(S, H, P, N, chunk):
    x = rand((S, H, P), scale=0.5)
    a = -jnp.abs(rand((S, H), scale=0.3)) - 0.05   # log-decay ≤ 0
    b = rand((S, N), scale=0.3)
    c = rand((S, N), scale=0.3)
    got = ssd_scan.ssd_scan(x, a, b, c, chunk=chunk, interpret=True)
    want = ref.ssd_scan(x, a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
