"""Model-component equivalence tests: capacity MoE vs dense-dispatch
oracle, chunked SSD vs sequential scan, head padding exactness, encdec
decode vs teacher forcing, zero1 sharding specs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ref
from repro.models import layers as L
from repro.models.params import (ParamSpec, default_rules, init_params,
                                 zero1_pspec)

RNG = np.random.default_rng(11)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale).astype(dtype)


def test_moe_capacity_matches_dense_oracle():
    cfg = registry.get("deepseek-moe-16b", smoke=True)  # cf=8: no drops
    p = init_params(L.moe_specs(cfg), jax.random.PRNGKey(0))
    x = rand((2, 16, cfg.d_model))
    got = L.moe_apply(p, x, cfg=cfg, rules=None)
    want = L.moe_apply_dense(p, x, cfg=cfg, rules=None)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_when_tight():
    cfg = dataclasses.replace(registry.get("deepseek-moe-16b", smoke=True),
                              capacity_factor=0.5)
    p = init_params(L.moe_specs(cfg), jax.random.PRNGKey(0))
    x = rand((2, 16, cfg.d_model))
    got = L.moe_apply(p, x, cfg=cfg, rules=None)  # must not crash
    assert np.isfinite(np.asarray(got, np.float32)).all()


def test_moe_shard_map_path_matches_local():
    """The EP shard_map path on a 1x1 mesh equals the local path."""
    cfg = registry.get("granite-moe-1b-a400m", smoke=True)
    p = init_params(L.moe_specs(cfg), jax.random.PRNGKey(1))
    x = rand((2, 8, cfg.d_model))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    got = L.moe_apply(p, x, cfg=cfg, rules=rules)
    want = L.moe_apply(p, x, cfg=cfg, rules=None)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,H,P,N,chunk", [(128, 2, 16, 8, 32),
                                           (256, 1, 32, 16, 64)])
def test_ssd_chunked_matches_sequential(S, H, P, N, chunk):
    x = rand((S, H, P), scale=0.5)
    a = -jnp.abs(rand((S, H), scale=0.3)) - 0.05
    b = rand((S, N), scale=0.3)
    c = rand((S, N), scale=0.3)
    got = ref.ssd_scan_chunked(x, a, b, c, chunk=chunk)
    want = ref.ssd_scan(x, a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_grads_finite():
    x = rand((128, 2, 16), scale=0.5)
    a = -jnp.abs(rand((128, 2), scale=0.5)) - 0.05
    b = rand((128, 8), scale=0.3)
    c = rand((128, 8), scale=0.3)

    def loss(x, a, b, c):
        return (ref.ssd_scan_chunked(x, a, b, c, chunk=32) ** 2).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(x, a, b, c)
    for g in grads:
        assert np.isfinite(np.asarray(g, np.float32)).all()


def test_head_padding_exactness():
    """Padded execution (tp_pad) must equal unpadded outputs exactly —
    the group-aligned masked padding from DESIGN.md."""
    base = registry.get("yi-34b", smoke=True)       # 4 heads, kv=2, g=2
    base = dataclasses.replace(base, n_heads=6, n_kv=2, d_head=16)  # g=3
    padded = dataclasses.replace(base, tp_pad=4)    # Hp: g 3->4 => 8 heads
    Hp, gp, g = padded.head_padding()
    assert (Hp, gp, g) == (8, 4, 3)

    x = rand((2, 16, base.d_model))
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))
    p_base = init_params(L.attention_specs(base), jax.random.PRNGKey(2))
    p_pad = init_params(L.attention_specs(padded), jax.random.PRNGKey(3))
    # copy true-head weights into the padded layout (kv-major groups)
    wq = np.array(p_pad["wq"], np.float32)
    wo = np.array(p_pad["wo"], np.float32)
    wqb = np.asarray(p_base["wq"], np.float32).reshape(
        base.d_model, base.n_kv, g, base.d_head)
    wob = np.asarray(p_base["wo"], np.float32).reshape(
        base.n_kv, g, base.d_head, base.d_model)
    wq = wq.reshape(base.d_model, base.n_kv, gp, base.d_head)
    wo = wo.reshape(base.n_kv, gp, base.d_head, base.d_model)
    wq[:, :, :g] = wqb
    wo[:, :g] = wob  # padded slots' wo irrelevant (masked)
    p_pad = dict(p_pad,
                 wq=jnp.asarray(wq.reshape(base.d_model, Hp, base.d_head),
                                p_pad["wq"].dtype),
                 wo=jnp.asarray(wo.reshape(Hp, base.d_head, base.d_model),
                                p_pad["wo"].dtype),
                 wk=p_base["wk"], wv=p_base["wv"])
    got = L.attention_apply(p_pad, x, pos, cfg=padded, backend="xla")
    want = L.attention_apply(p_base, x, pos, cfg=base, backend="xla")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_zero1_adds_data_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    spec = ParamSpec((4, 8), jnp.float32, (None, "mlp"))
    ps = zero1_pspec(rules, spec)
    # with data=1 nothing changes; structure is a valid PartitionSpec
    assert len(ps) <= 2


def test_attention_q_chunking_equivalence():
    q = rand((256, 4, 32), scale=0.5)
    k = rand((256, 2, 32), scale=0.5)
    v = rand((256, 2, 32), scale=0.5)
    a1 = ref.attention(q, k, v, causal=True, q_chunk=64)
    a2 = ref.attention(q, k, v, causal=True, q_chunk=256)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-5, atol=1e-5)
