"""dim3 shared-memory indexing sugar: `tile[ty][tx]` chained subscripts.

Real SDK sources declare `__shared__ float tile[16][17]` and index it
`tile[ty][tx]`; the frontend lowers chained subscripts on `c.shared`
arrays to the same row-major linearization as the tuple spelling
`tile[ty, tx]`, so the two forms compile to identical programs.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.core import cox  # noqa: E402
from repro.core.oracle import run_grid as oracle_run  # noqa: E402
from repro.core.types import CoxUnsupported  # noqa: E402


@cox.kernel
def _transpose_chained(c, o: cox.Array(cox.f32), i: cox.Array(cox.f32),
                       n: cox.i32):
    tile = c.shared((16, 17), cox.f32)
    x = c.block_idx('x') * 16 + c.thread_idx('x')
    y = c.block_idx('y') * 16 + c.thread_idx('y')
    tile[c.thread_idx('y')][c.thread_idx('x')] = i[y * n + x]
    c.syncthreads()
    o[(c.block_idx('x') * 16 + c.thread_idx('y')) * n
      + c.block_idx('y') * 16 + c.thread_idx('x')] = \
        tile[c.thread_idx('x')][c.thread_idx('y')]


@cox.kernel
def _transpose_tuple(c, o: cox.Array(cox.f32), i: cox.Array(cox.f32),
                     n: cox.i32):
    tile = c.shared((16, 17), cox.f32)
    x = c.block_idx('x') * 16 + c.thread_idx('x')
    y = c.block_idx('y') * 16 + c.thread_idx('y')
    tile[c.thread_idx('y'), c.thread_idx('x')] = i[y * n + x]
    c.syncthreads()
    o[(c.block_idx('x') * 16 + c.thread_idx('y')) * n
      + c.block_idx('y') * 16 + c.thread_idx('x')] = \
        tile[c.thread_idx('x'), c.thread_idx('y')]


def _transpose_args(n=64, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.standard_normal((n * n,)).astype(np.float32)
    return (np.zeros(n * n, np.float32), src, np.int32(n)), src


def test_chained_equals_tuple_ir():
    """Both spellings lower to the identical kernel IR body."""
    assert repr(_transpose_chained.ir.body) == repr(_transpose_tuple.ir.body)


@pytest.mark.parametrize("backend", ["scan", "vmap"])
@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_chained_transpose_matches_tuple_and_oracle(backend, warp_exec):
    n = 64
    args, src = _transpose_args(n)
    kw = dict(grid=(n // 16, n // 16), block=(16, 16), args=args)
    got = _transpose_chained.launch(backend=backend, warp_exec=warp_exec,
                                    **kw)
    want = _transpose_tuple.launch(backend=backend, warp_exec=warp_exec,
                                   **kw)
    np.testing.assert_array_equal(np.asarray(got["o"]),
                                  np.asarray(want["o"]))
    np.testing.assert_array_equal(
        np.asarray(got["o"]).reshape(n, n),
        src.reshape(n, n).T)
    ref = oracle_run(_transpose_chained.ir, grid=(n // 16, n // 16),
                     block=(16, 16), args=args)
    np.testing.assert_array_equal(np.asarray(got["o"]),
                                  np.asarray(ref["o"], np.float32))


def test_chained_3d_and_augassign():
    @cox.kernel
    def k3(c, o: cox.Array(cox.f32), n: cox.i32):
        buf = c.shared((2, 3, 4), cox.f32)
        t = c.thread_idx()
        z = t // 12
        rem = t % 12
        y = rem // 4
        x = rem % 4
        if t < 24:
            buf[z][y][x] = c.f32(t)
            buf[z][y][x] += 1.0
        c.syncthreads()
        if t < 24:
            o[t] = buf[z][y][x]

    out = k3.launch(grid=1, block=32, args=(np.zeros(24, np.float32), 24))
    np.testing.assert_array_equal(np.asarray(out["o"]),
                                  np.arange(24, dtype=np.float32) + 1.0)


def test_chained_on_global_rejected():
    with pytest.raises(CoxUnsupported, match="chained"):
        @cox.kernel
        def bad(c, o: cox.Array(cox.f32), a: cox.Array(cox.f32)):
            o[c.thread_idx()] = a[0][1]


def test_chained_rank_mismatch_rejected():
    with pytest.raises(CoxUnsupported, match="rank"):
        @cox.kernel
        def bad(c, o: cox.Array(cox.f32)):
            tile = c.shared((4, 4), cox.f32)
            tile[0][1][2] = 1.0
            o[0] = tile[0, 0]


def test_mixed_tuple_and_chain_rejected():
    with pytest.raises(CoxUnsupported, match="mixing"):
        @cox.kernel
        def bad(c, o: cox.Array(cox.f32)):
            cube = c.shared((2, 3, 4), cox.f32)
            cube[0, 1][2] = 1.0
            o[0] = cube[0, 0, 0]


def test_linear_index_on_2d_shared_still_works():
    """The pre-sugar escape hatch — a single linear index into a 2-D
    tile — keeps its meaning."""
    @cox.kernel
    def lin(c, o: cox.Array(cox.f32)):
        tile = c.shared((4, 4), cox.f32)
        t = c.thread_idx()
        if t < 16:
            tile[t] = c.f32(t) * 2.0
        c.syncthreads()
        if t < 16:
            o[t] = tile[t // 4][t % 4]

    out = lin.launch(grid=1, block=32, args=(np.zeros(16, np.float32),))
    np.testing.assert_array_equal(np.asarray(out["o"]),
                                  np.arange(16, dtype=np.float32) * 2.0)
