"""Whitebox tests of the hierarchical-collapsing pass pipeline:
CFG invariants, extra-barrier placement, PR discovery (incl. the paper's
literal Algorithm 2), hierarchical nesting (Fig. 7), replication classes
(paper §3.6), and loop peeling structure."""
import pytest

from repro.core import cox
from repro.core.cfg import Br
from repro.core.execute import compile_kernel
from repro.core.passes import find_parallel_regions_alg2
from repro.core.regions import BlockPR, BlockPeel, WarpPR, WarpPeel
from repro.core.types import BarrierLevel, CoxUnsupported
from repro.core import kernel_ir as K


@cox.kernel
def code1(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
    """Paper Code 1."""
    v = val[c.thread_idx()]
    if c.thread_idx() < 32:
        offset = 16
        while offset > 0:
            s = c.shfl_down(v, offset)
            v = v + s
            offset = offset // 2
    if c.thread_idx() == 0:
        out[0] = v


@cox.kernel
def fig5(c, a: cox.Array(cox.f32)):
    """Paper Fig. 5: barrier inside a for-loop."""
    tid = c.thread_idx()
    for i in range(12):
        a[tid] = a[tid] + 1.0
        a[tid] = a[tid] + 2.0
        c.syncthreads()
        a[tid] = a[tid] + 3.0


@cox.kernel
def warp_free(c, a: cox.Array(cox.f32)):
    tid = c.thread_idx()
    if tid < 16:
        a[tid] = a[tid] * 2.0


def test_code1_hierarchical_structure():
    ck = compile_kernel(code1.ir)
    bprs = [n for n in ck.machine.nodes if isinstance(n, BlockPR)]
    bpeels = [n for n in ck.machine.nodes if isinstance(n, BlockPeel)]
    # Code 1 has no block-level barriers except entry/exit: a single
    # block-level PR spans the whole kernel body (plus entry/exit strips)
    assert len(bpeels) == 0
    # the warp-level machine inside must contain peels (the tid<32 branch
    # + the loop) and multiple warp PRs — the Fig. 7 hierarchy
    wpeels = sum(sum(isinstance(w, WarpPeel) for w in n.warp.nodes)
                 for n in bprs)
    wprs = sum(sum(isinstance(w, WarpPR) for w in n.warp.nodes)
               for n in bprs)
    assert wpeels >= 2
    assert wprs >= 3


def test_code1_replication_classes():
    ck = compile_kernel(code1.ir)
    # v is written before the if and read after -> lives across warp PRs
    # within a single block-level PR: the paper replicates it ×32
    # (warp class, unless it crosses a block PR)
    assert ck.classes["v"] in ("warp", "block")
    # warp buffers are always warp-replicated (RAW/WAR bracketing)
    assert all(v == "warp" for k, v in ck.classes.items()
               if k.startswith(".warpbuf"))


def test_fig5_loop_barriers_make_two_prs_per_iteration():
    ck = compile_kernel(fig5.ir)
    # the loop body splits at the syncthreads: +1/+2 form one PR,
    # +3 another (paper Fig. 5c)
    bprs = [n for n in ck.machine.nodes if isinstance(n, BlockPR)]
    assert len(bprs) >= 3  # pre-loop, body-pre-barrier, body-post-barrier
    peels = [n for n in ck.machine.nodes if isinstance(n, BlockPeel)]
    assert len(peels) == 1  # the loop condition (peeled, block level)


def test_every_barrier_ends_its_block():
    ck = compile_kernel(code1.ir)
    for blk in ck.cfg.blocks.values():
        for i, ins in enumerate(blk.instrs):
            if isinstance(ins, K.Barrier):
                assert i == len(blk.instrs) - 1, \
                    f"barrier mid-block in {blk.name}"


def test_branch_blocks_are_pure():
    ck = compile_kernel(code1.ir)
    for blk in ck.cfg.blocks.values():
        if isinstance(blk.term, Br):
            assert not blk.instrs, f"{blk.name} has instrs before Br"


def test_warp_prs_nest_inside_block_prs():
    """Paper §3.5: every warp-level PR is a subset of a block-level PR."""
    for kern in (code1, fig5, warp_free):
        ck = compile_kernel(kern.ir)
        for node in ck.machine.nodes:
            if not isinstance(node, BlockPR):
                continue
            for w in node.warp.nodes:
                if isinstance(w, WarpPR):
                    assert set(w.blocks) <= set(node.blocks)


def test_alg2_matches_constructive_partition():
    """The literal Algorithm 2 transliteration and the constructive
    edge-cut partition agree on warp-level PR contents."""
    for kern in (code1, fig5):
        ck = compile_kernel(kern.ir)
        alg2 = find_parallel_regions_alg2(ck.cfg, BarrierLevel.WARP)
        alg2_blocks = set()
        for pr in alg2:
            alg2_blocks |= pr
        mine = set()
        for node in ck.machine.nodes:
            if isinstance(node, BlockPR):
                for w in node.warp.nodes:
                    if isinstance(w, WarpPR):
                        mine |= set(w.blocks)
        # Alg2 includes only blocks reachable backward from barrier
        # blocks; constructive partition covers all non-peel blocks.
        # Every Alg2 PR block must appear in the constructive partition.
        assert alg2_blocks <= mine


def test_flat_uses_single_warp():
    ck = warp_free.compiled(collapse="flat", block=64)
    assert ck.warp_size == 64  # one block-wide "warp" = flat collapsing


def test_dynamic_coop_group_rejected():
    with pytest.raises(CoxUnsupported):
        @cox.kernel
        def bad(c, out: cox.Array(cox.f32)):
            _g = c.coalesced_threads()


def test_barrier_insertion_adds_entry_exit():
    ck = compile_kernel(warp_free.ir)
    entry = ck.cfg.blocks[ck.cfg.entry]
    assert any(isinstance(i, K.Barrier) and i.source == "entry"
               for i in entry.instrs)
    exit_b = ck.cfg.blocks[ck.cfg.exit]
    assert any(isinstance(i, K.Barrier) and i.source == "exit"
               for i in exit_b.instrs)


def test_warp_intrinsic_lowering_emits_raw_war():
    ck = compile_kernel(code1.ir)
    sources = [ins.source for blk in ck.cfg.blocks.values()
               for ins in blk.instrs if isinstance(ins, K.Barrier)]
    assert "raw" in sources and "war" in sources
