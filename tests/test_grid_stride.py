"""Grid-stride lowering: resident waves loop over oversubscribed grids.

The tentpole contract: ``schedule='grid_stride'`` runs a fixed number
of resident block slots (``n_resident``) that loop over strided block
ids, so the host never materializes the O(grid) chunk tables and the
per-wave working set stays inside ``COX_FOOTPRINT_BUDGET`` regardless
of grid size.  Wave *i* covers exactly the contiguous bids of chunk
row *i* of a ``chunk=n_resident`` chunked schedule, so the two are
bitwise-identical by construction — verified here across all three
backends × both warp-exec flavors, atomics, a partial last wave, dim3
grids, captured-graph replay, and placed multi-device runs.  The
footprint verdict (``costmodel.schedule_verdict``), its provenance
(``schedule_source``), the ``COX_FOOTPRINT_BUDGET`` override, and the
autotuner's grid-stride candidate cells are pinned alongside.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from benchmarks.kernels_suite import all_kernels
from repro.core import cox
from repro.core import autotune as _autotune
from repro.core import costmodel
from repro.core.backends.plan import DEFAULT_CHUNK, LaunchPlan
from repro.core.runtime import resolve_launch, resolve_schedule
from repro.core.streams import Dispatcher, Stream
from repro.core.types import CoxUnsupported

jax = pytest.importorskip("jax")

VECTOR_ADD = next(k for k in all_kernels() if k.name == "vectorAdd")
HISTOGRAM = next(k for k in all_kernels() if k.name == "histogram64")
GRID_REDUCE = next(k for k in all_kernels() if k.name == "gridReduce")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@cox.kernel
def _saxpy(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
           y: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


def _saxpy_args(grid, block, seed=0):
    n = grid * block
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    return (np.zeros(n, np.float32), x, y, np.int32(n))


def _np(out):
    return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# bitwise equivalence: grid-stride == chunked, backends × warp-exec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scan", "vmap"])
@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_stride_matches_chunked_bitwise(backend, warp_exec):
    # grid=10, n_resident=3: four waves, the last one a single live slot
    grid, block = 10, 64
    args = _saxpy_args(grid, block)
    kw = dict(grid=grid, block=block, args=args, backend=backend,
              warp_exec=warp_exec)
    want = _np(_saxpy.launch(**kw, chunk=3))
    got = _np(_saxpy.launch(**kw, schedule="grid_stride", n_resident=3))
    np.testing.assert_array_equal(got["out"], want["out"],
                                  err_msg=f"{backend}/{warp_exec}")


@pytest.mark.parametrize("warp_exec", ["serial", "batched"])
def test_stride_matches_chunked_sharded(warp_exec):
    mesh = jax.make_mesh((1,), ("data",))
    grid, block = 10, 64
    args = _saxpy_args(grid, block)
    kw = dict(grid=grid, block=block, args=args, mesh=mesh,
              warp_exec=warp_exec)
    want = _np(_saxpy.launch(**kw, chunk=3))
    got = _np(_saxpy.launch(**kw, schedule="grid_stride", n_resident=3))
    np.testing.assert_array_equal(got["out"], want["out"],
                                  err_msg=f"sharded/{warp_exec}")


@pytest.mark.parametrize("backend", ["scan", "vmap"])
def test_stride_atomics_match(backend):
    # histogram64: atomic_add deltas must fold identically per wave
    sk = HISTOGRAM
    args = sk.make_args()
    kw = dict(grid=sk.grid, block=sk.block, args=args, backend=backend)
    want = _np(sk.kernel.launch(**kw))
    got = _np(sk.kernel.launch(**kw, schedule="grid_stride", n_resident=5))
    np.testing.assert_array_equal(got["hist"], want["hist"],
                                  err_msg=backend)
    assert got["hist"].sum() == np.asarray(args[2])


def test_stride_partial_last_wave():
    # grid=7, n_resident=4: wave 1 has three live slots and one pad —
    # padded bids must write nothing and contribute zero atomic delta
    grid, block = 7, 32
    args = _saxpy_args(grid, block, seed=2)
    kw = dict(grid=grid, block=block, args=args, backend="vmap")
    want = _np(_saxpy.launch(**kw))
    got = _np(_saxpy.launch(**kw, schedule="grid_stride", n_resident=4))
    np.testing.assert_array_equal(got["out"], want["out"])


@cox.kernel
def _saxpy2d(c, out: cox.Array(cox.f32), x: cox.Array(cox.f32),
             y: cox.Array(cox.f32), n: cox.i32):
    # CUDA 2-D grid idiom: linearize blockIdx x-fastest
    b = c.block_idx('x') + c.grid_dim('x') * c.block_idx('y')
    i = b * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = 2.5 * x[i] + y[i]


def test_stride_dim3_grid():
    # dim3 geometry linearizes before scheduling: (5, 2) == 10 blocks,
    # strided 3 at a time across both grid rows
    block = 64
    args = _saxpy_args(10, block)
    want = _np(_saxpy2d.launch(grid=(5, 2), block=block, args=args,
                               backend="vmap", chunk=3))
    got = _np(_saxpy2d.launch(grid=(5, 2), block=block, args=args,
                              backend="vmap", schedule="grid_stride",
                              n_resident=3))
    np.testing.assert_array_equal(got["out"], want["out"])
    np.testing.assert_allclose(
        want["out"], np.float32(2.5) * args[1] + args[2],
        rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["scan", "vmap", "sharded"])
def test_stride_cooperative_pages_blocks_through_phases(backend):
    # multi-phase gridReduce with a 3-slot wave: all waves of phase p
    # complete before phase p+1, per-block persist state pages in and
    # out of the capacity window — results stay bitwise-equal to the
    # all-resident cooperative launch
    sk = GRID_REDUCE
    args = sk.make_args()
    kw = dict(grid=sk.grid, block=sk.block, args=args)
    if backend == "sharded":
        kw["mesh"] = jax.make_mesh((1,), ("data",))
    else:
        kw["backend"] = backend
    want = _np(sk.kernel.launch(**kw))
    got = _np(sk.kernel.launch(**kw, schedule="grid_stride", n_resident=3))
    for k in want:
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{backend}.{k}")
    assert got["total"][0] == got["partial"].sum()


def test_stride_graph_replay_bitwise_equals_eager():
    d = Dispatcher()
    s = Stream("gs", d)
    grid, block = 10, 64
    args = _saxpy_args(grid, block, seed=4)
    kw = dict(backend="vmap", schedule="grid_stride", n_resident=3)
    want = s.launch(_saxpy, grid=grid, block=block, args=args,
                    **kw).result()["out"]
    g = cox.Graph()
    with g.capture(s):
        s.launch(_saxpy, grid=grid, block=block, args=args, **kw)
    res = g.replay()
    np.testing.assert_array_equal(np.asarray(res["out"]), np.asarray(want))
    res2 = g.replay()
    np.testing.assert_array_equal(np.asarray(res2["out"]),
                                  np.asarray(res["out"]))


def test_stride_placed_multi_device_bitwise():
    # 4 host devices: each mesh device strides its own contiguous bid
    # stripe; the cross-device merge must reproduce the single-device
    # launch exactly (grid=10 over 4 devices: uneven 3/3/3/1 stripes)
    code = textwrap.dedent("""
        import jax, numpy as np
        from tests.multidevice_kernels import vec_madd
        assert len(jax.devices()) == 4
        grid, block = 10, 128
        n = grid * block
        rng = np.random.default_rng(0)
        x = rng.normal(size=n).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        args = (np.zeros(n, np.float32), x, y, n)
        want = vec_madd.launch(grid=grid, block=block, args=args)["out"]
        mesh = jax.make_mesh((4,), ("data",))
        got = vec_madd.launch(grid=grid, block=block, args=args, mesh=mesh,
                              schedule="grid_stride", n_resident=2)["out"]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        print("stride-placed-ok")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900, cwd=ROOT)
    assert r.returncode == 0, f"worker failed:\n{r.stdout}\n{r.stderr}"
    assert "stride-placed-ok" in r.stdout


# ---------------------------------------------------------------------------
# the footprint verdict: oversubscribed grids auto-route to grid-stride
# ---------------------------------------------------------------------------


def test_oversubscribed_grid_never_materializes_table_over_budget(
        monkeypatch):
    # satellite regression: grid >> budget.  The chunk table alone is
    # ~4 MiB at grid 2**20; under a 64 KiB budget no chunk value can
    # fit, so the verdict must stride — and the stride footprint is
    # grid-independent, inside budget by construction.
    budget = 64 << 10
    monkeypatch.setenv(costmodel.ENV_BUDGET, str(budget))
    grid = 1 << 20
    ck = _saxpy.compiled(block=64)
    rl = resolve_launch(ck, grid=grid, block=64)
    shapes = {"out": (256,), "x": (256,), "y": (256,)}
    rl = resolve_schedule(ck, rl, shapes)
    assert rl.schedule == "grid_stride"
    assert rl.schedule_source == "heuristic"
    assert rl.n_resident is not None and rl.n_resident >= 1
    assert costmodel.stride_footprint(
        ck, shapes, n_resident=rl.n_resident,
        n_warps=rl.n_warps, warp_exec=rl.warp_exec) <= budget
    # every chunked alternative would have blown the budget on the
    # table term alone — the clamp loop cannot help, only striding can
    for chunk in costmodel.RESIDENT_CANDIDATES:
        assert costmodel.bid_table_bytes(grid, chunk) > budget
    # and the staged plan carries the stride schedule (chunk == wave
    # width, so any chunk-shaped state is O(n_resident), not O(grid))
    plan = LaunchPlan.build(ck, grid=grid, block=64, chunk=rl.chunk,
                            warp_exec=rl.warp_exec,
                            schedule=rl.schedule, n_resident=rl.n_resident)
    assert plan.schedule == "grid_stride"
    assert plan.chunk == plan.n_resident == rl.n_resident
    assert plan.n_stride_waves() == -(-grid // rl.n_resident)


def test_oversubscribed_launch_runs_and_matches(monkeypatch):
    # end-to-end: a tiny budget forces the stride path on a real
    # launch; the answer must not change
    grid, block = 16, 64
    args = _saxpy_args(grid, block, seed=6)
    want = _np(_saxpy.launch(grid=grid, block=block, args=args,
                             backend="vmap"))
    monkeypatch.setenv(costmodel.ENV_BUDGET, "64")
    req = _saxpy.make_request(grid=grid, block=block, args=args,
                              backend="vmap")
    assert req.rl.schedule == "grid_stride"
    assert req.rl.schedule_source == "heuristic"
    got = _np(_saxpy.launch(grid=grid, block=block, args=args,
                            backend="vmap"))
    np.testing.assert_array_equal(got["out"], want["out"])


def test_scan_verdict_keys_on_the_bid_sequence_alone():
    # scan holds one copy of global memory under every schedule; its
    # only O(grid) state is the arange it scans — stride width 1
    ck = _saxpy.compiled(block=64)
    shapes = {"out": (256,), "x": (256,), "y": (256,)}
    sched, n_res = costmodel.schedule_verdict(
        ck, shapes, grid=1 << 20, chunk=DEFAULT_CHUNK, n_warps=2,
        backend="scan", budget=64 << 10)
    assert (sched, n_res) == ("grid_stride", 1)
    sched, n_res = costmodel.schedule_verdict(
        ck, shapes, grid=64, chunk=DEFAULT_CHUNK, n_warps=2,
        backend="scan", budget=64 << 10)
    assert (sched, n_res) == ("chunked", None)


def test_explicit_schedule_is_never_overridden(monkeypatch):
    monkeypatch.setenv(costmodel.ENV_BUDGET, "64")
    grid, block = 16, 64
    args = _saxpy_args(grid, block)
    req = _saxpy.make_request(grid=grid, block=block, args=args,
                              backend="vmap", schedule="chunked")
    assert req.rl.schedule == "chunked"
    assert req.rl.schedule_source == "explicit"


def test_n_resident_implies_grid_stride():
    ck = _saxpy.compiled(block=64)
    rl = resolve_launch(ck, grid=10, block=64, n_resident=3)
    assert rl.schedule == "grid_stride"
    assert rl.schedule_source == "explicit"
    assert rl.n_resident == 3
    with pytest.raises(ValueError, match="n_resident"):
        resolve_launch(ck, grid=10, block=64, schedule="chunked",
                       n_resident=3)


def test_explicit_grid_stride_without_width_gets_the_sized_wave():
    grid, block = 10, 64
    args = _saxpy_args(grid, block)
    req = _saxpy.make_request(grid=grid, block=block, args=args,
                              backend="vmap", schedule="grid_stride")
    assert req.rl.schedule == "grid_stride"
    assert req.rl.n_resident is not None
    assert 1 <= req.rl.n_resident <= grid


# ---------------------------------------------------------------------------
# COX_FOOTPRINT_BUDGET: validated override
# ---------------------------------------------------------------------------


def test_budget_env_validation(monkeypatch):
    monkeypatch.delenv(costmodel.ENV_BUDGET, raising=False)
    assert costmodel.footprint_budget() == costmodel.FOOTPRINT_BUDGET
    monkeypatch.setenv(costmodel.ENV_BUDGET, "1048576")
    assert costmodel.footprint_budget() == 1048576
    monkeypatch.setenv(costmodel.ENV_BUDGET, "lots")
    with pytest.raises(ValueError, match="integer byte count"):
        costmodel.footprint_budget()
    monkeypatch.setenv(costmodel.ENV_BUDGET, "0")
    with pytest.raises(ValueError, match="positive"):
        costmodel.footprint_budget()
    monkeypatch.setenv(costmodel.ENV_BUDGET, "-3")
    with pytest.raises(ValueError, match="positive"):
        costmodel.footprint_budget()
    monkeypatch.setenv(costmodel.ENV_BUDGET, "  ")
    assert costmodel.footprint_budget() == costmodel.FOOTPRINT_BUDGET


# ---------------------------------------------------------------------------
# autotune: grid-stride cells replace the blind chunk clamp
# ---------------------------------------------------------------------------


def test_autotune_candidates_stride_when_no_chunk_fits(monkeypatch):
    monkeypatch.setenv(costmodel.ENV_BUDGET, str(4 << 10))
    ck = _saxpy.compiled(block=64)
    rl = resolve_launch(ck, grid=4096, block=64, backend="vmap",
                        warp_exec="serial")
    shapes = {"out": (256,), "x": (256,), "y": (256,)}
    rl = resolve_schedule(ck, rl, shapes)
    assert rl.schedule == "grid_stride"
    # every chunked cell is over budget (bid table >= 16 KiB) …
    assert _autotune._chunk_candidates(ck, rl, shapes, warp_exec="serial",
                                       tunable_chunk=True,
                                       allow_empty=True) == []
    # … so the candidate set is pure grid-stride
    cands = _autotune._candidates(ck, rl, shapes,
                                  tunable=(False, False, True, True))
    assert cands, "no candidates"
    assert all(c.schedule == "grid_stride" for c in cands)
    assert all(c.label.split("/")[-1].startswith("gs") for c in cands)
    # widths come from the cost-model sizer (plus the resolver's own
    # pick) — never wider, and in particular never the O(grid) table
    exp = {costmodel.resident_slots(ck, shapes, grid=4096,
                                    n_warps=rl.n_warps,
                                    warp_exec="serial"), rl.n_resident}
    assert {c.n_resident for c in cands} <= exp


def test_autotune_clamp_survives_only_when_chunked_is_pinned(monkeypatch):
    # schedule='chunked' pins the table walk; with nothing fitting the
    # budget the old clamp remains the last resort (wave-only term)
    monkeypatch.setenv(costmodel.ENV_BUDGET, "64")
    ck = _saxpy.compiled(block=64)
    rl = resolve_launch(ck, grid=4096, block=64, backend="vmap",
                        warp_exec="serial", schedule="chunked")
    shapes = {"out": (256,), "x": (256,), "y": (256,)}
    chunks = _autotune._chunk_candidates(ck, rl, shapes,
                                         warp_exec="serial",
                                         tunable_chunk=True)
    assert chunks == [1]
    cands = _autotune._candidates(ck, rl, shapes,
                                  tunable=(False, False, True, False))
    assert all(c.schedule == "chunked" for c in cands)


# ---------------------------------------------------------------------------
# telemetry: schedule provenance reaches the dispatcher rows
# ---------------------------------------------------------------------------


def test_telemetry_records_schedule_and_provenance():
    d = Dispatcher()
    s = Stream("tel", d)
    grid, block = 10, 64
    args = _saxpy_args(grid, block, seed=8)
    s.launch(_saxpy, grid=grid, block=block, args=args, backend="vmap",
             schedule="grid_stride", n_resident=3).result()
    s.launch(_saxpy, grid=grid, block=block, args=args,
             backend="vmap").result()
    rows = d.telemetry()
    by_sched = {r["schedule"]: r for r in rows
                if r["kernel"] == "_saxpy"}
    assert "grid_stride" in by_sched and "chunked" in by_sched
    gs = by_sched["grid_stride"]
    assert gs["n_resident"] == 3
    assert gs["schedule_source"] == "explicit"
    assert by_sched["chunked"]["n_resident"] is None
    health = d.health()
    assert health["schedules"]["grid_stride"] >= 1
    assert health["schedules"]["chunked"] >= 1
