"""Module-scope kernels for the multi-device subprocess tests
(inspect.getsource needs file-backed sources)."""
from repro.core import cox


@cox.kernel
def vec_madd(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
             b: cox.Array(cox.f32), n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        out[i] = a[i] * 2.0 + b[i]


@cox.kernel
def histogram(c, hist: cox.Array(cox.f32), data: cox.Array(cox.i32),
              n: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, data[i], 1.0)
