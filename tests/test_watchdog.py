"""StepWatchdog unit tests: strike reset, restart, and fire/stop races.

The watchdog is the per-step (and, via the dispatcher, per-launch)
deadline primitive, so its state machine has to be exact:

* a healthy ``start``/``stop`` cycle resets the consecutive-strike
  count (only *consecutive* stragglers escalate);
* ``start`` while already armed replaces the previous timer instead of
  leaking it (no double-fire for one step);
* a timer that fires after ``stop`` (the fire/stop race) is a stale
  generation and must not strike the *next* step.
"""
import threading
import time

import pytest

from repro.ft.watchdog import StepWatchdog


def test_strikes_reset_after_healthy_step():
    wd = StepWatchdog(deadline_s=0.02, max_strikes=3)
    wd.start(step=0)
    time.sleep(0.08)
    assert wd.fired and wd.strikes == 1
    wd.stop()
    # a healthy step clears the consecutive-straggler count
    wd.start(step=1)
    wd.stop()
    assert wd.strikes == 0
    wd.check()                      # no escalation after recovery


def test_straggler_streak_escalates_at_max_strikes():
    wd = StepWatchdog(deadline_s=0.01, max_strikes=2)
    for step in range(2):
        wd.start(step=step)
        time.sleep(0.05)
        wd.stop()
    assert wd.strikes == 2
    with pytest.raises(TimeoutError, match="straggler"):
        wd.check()


def test_double_start_replaces_timer_without_double_fire():
    events = []
    wd = StepWatchdog(deadline_s=0.03, max_strikes=10,
                      on_straggler=lambda step, strikes:
                      events.append((step, strikes)))
    wd.start(step=0)
    wd.start(step=1)                # re-arm before step 0's timer fires
    time.sleep(0.1)
    wd.stop()
    # exactly one fire, attributed to the re-armed step
    assert wd.strikes == 1
    assert events == [(1, 1)]


def test_stale_fire_after_stop_is_ignored():
    wd = StepWatchdog(deadline_s=0.05, max_strikes=3)
    wd.start(step=0)
    wd.stop()                       # healthy: cancel before the deadline
    # even if the cancelled timer thread were to run, its generation is
    # stale — simulate the race by invoking the callback directly
    wd._fire(wd._gen - 1)
    assert wd.strikes == 0 and not wd.fired
    wd.start(step=1)
    wd._fire(wd._gen - 1)           # stale fire must not strike step 1
    assert not wd.fired
    wd.stop()
    assert wd.strikes == 0


def test_fired_is_per_generation():
    wd = StepWatchdog(deadline_s=0.01, max_strikes=10)
    wd.start(step=0)
    time.sleep(0.05)
    assert wd.fired
    wd.stop()
    wd.start(step=1)                # new generation: not fired yet
    assert not wd.fired
    wd.stop()


def test_on_straggler_called_outside_lock():
    """The callback may reenter the watchdog (e.g. to read strikes)
    without deadlocking."""
    seen = {}
    done = threading.Event()
    wd = StepWatchdog(deadline_s=0.01, max_strikes=10)

    def cb(step, strikes):
        seen["strikes"] = wd.strikes      # reentrant read
        seen["step"] = step
        done.set()

    wd.on_straggler = cb
    wd.start(step=7)
    assert done.wait(timeout=2.0)
    wd.stop()
    assert seen == {"strikes": 1, "step": 7}


def test_events_record_step_and_strike_count():
    wd = StepWatchdog(deadline_s=0.01, max_strikes=10)
    wd.start(step=3)
    time.sleep(0.05)
    wd.stop()
    assert len(wd.events) == 1
    ev = wd.events[0]
    assert ev["step"] == 3 and ev["strikes"] == 1 and "time" in ev
