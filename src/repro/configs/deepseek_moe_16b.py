"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
    vocab=102400, n_experts=64, top_k=6, n_shared=2, d_expert=1408,
    act="swiglu", norm="rms",
    notes="per-expert d_ff=1408; shared experts = 2 x 1408; MHA (kv=16)")
