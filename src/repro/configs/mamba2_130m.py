"""mamba2-130m — attention-free SSD. [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64,
    act="swiglu", norm="rms",
    notes="d_inner=1536, 24 SSD heads of P=64, N=128; no attention, "
          "no MLP (Mamba2 block only)")
