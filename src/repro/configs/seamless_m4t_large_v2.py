"""seamless-m4t-large-v2 — enc-dec multimodal backbone; speech frontend is
a STUB (precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=8192, vocab=256206, act="gelu", norm="ln",
    tie_embeddings=True,
    notes="24 enc + 24 dec layers; MHA kv=16; frame embeddings "
          "precomputed by the stub frontend")
