"""Model / shape configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0             # default d_model // n_heads
    qkv_bias: bool = False
    act: str = "swiglu"         # swiglu | gelu
    norm: str = "rms"           # rms | ln
    tie_embeddings: bool = True
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_inner: int = 0          # default 2*d_model
    conv_k: int = 4
    ssd_chunk: int = 128        # SSD chunk length (perf knob, §Perf)
    # --- hybrid (Zamba2-style shared attention) ---
    attn_every: int = 0         # apply the shared attention block every N
    # --- enc-dec ---
    enc_layers: int = 0
    # --- vlm/audio stubs ---
    n_frontend_tokens: int = 0  # precomputed patch/frame embeddings
    # --- execution ---
    window: int = 0             # sliding-window attention (0 = full)
    remat: str = "full"         # none | full
    param_dtype: object = jnp.bfloat16
    tp_pad: int = 0             # runtime: pad q-heads to this TP degree
                                # (group-aligned, masked — exact math)
    notes: str = ""

    def head_padding(self):
        """(Hp, gp, g_true): padded head count, padded group size, true
        group size.  Padding happens inside each kv group so the
        head→kv mapping is preserved exactly; padded heads are masked
        before the output projection, so results equal the true arch."""
        H, Hkv = self.n_heads, self.n_kv
        if not H or not Hkv:
            return H, 0, 0
        g = H // Hkv
        if not self.tp_pad or H % self.tp_pad == 0:
            return H, g, g
        gp = g
        while (gp * Hkv) % self.tp_pad != 0:
            gp += 1
        return gp * Hkv, gp, g

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            self.d_head = self.d_model // self.n_heads
        if self.family in ("ssm", "hybrid") and self.ssm_inner == 0:
            self.ssm_inner = 2 * self.d_model
        if self.family in ("ssm", "hybrid") and self.ssm_heads == 0:
            self.ssm_heads = self.ssm_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters N (for 6·N·D roofline accounting)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        H, Hkv, Dh = self.n_heads, self.n_kv, self.d_head
        attn = d * (H + 2 * Hkv) * Dh + H * Dh * d + \
            (H * Dh + 2 * Hkv * Dh if self.qkv_bias else 0)
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            fe = self.d_expert or f
            moe = self.n_experts * 3 * d * fe + d * self.n_experts
            if self.n_shared:
                moe += 3 * d * fe * self.n_shared
            per_layer = attn + moe + 2 * d
            body = self.n_layers * per_layer
        elif self.family == "ssm":
            di, N, Hs = self.ssm_inner, self.ssm_state, self.ssm_heads
            mamba = d * (2 * di + 2 * N + Hs) + di * d + \
                self.conv_k * (di + 2 * N) + 3 * Hs + di
            body = self.n_layers * (mamba + d)
        elif self.family == "hybrid":
            di, N, Hs = self.ssm_inner, self.ssm_state, self.ssm_heads
            mamba = d * (2 * di + 2 * N + Hs) + di * d + \
                self.conv_k * (di + 2 * N) + 3 * Hs + di
            shared = attn + mlp + 2 * d
            body = self.n_layers * (mamba + d) + shared
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + mlp + 2 * d)
            dec = self.n_layers * (2 * attn + mlp + 3 * d)
            body = enc + dec
        else:  # dense / vlm
            per_layer = attn + mlp + 2 * d
            body = self.n_layers * per_layer
        emb = V * d * (1 if self.tie_embeddings else 2)
        return body + emb + d

    def active_param_count(self) -> int:
        """Activated parameters (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, V = self.d_model, self.vocab
        H, Hkv, Dh = self.n_heads, self.n_kv, self.d_head
        fe = self.d_expert or self.d_ff
        attn = d * (H + 2 * Hkv) * Dh + H * Dh * d
        act_moe = (self.top_k + self.n_shared) * 3 * d * fe + d * self.n_experts
        body = self.n_layers * (attn + act_moe + 2 * d)
        return body + V * d + d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# architectures for which long_500k is runnable (sub-quadratic decode)
LONG_CONTEXT_OK = {"mamba2-130m", "zamba2-1.2b"}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test twin: same family/topology, tiny dims."""
    c = dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)) if cfg.n_kv else 0,
        d_head=16,
        d_ff=128,
        vocab=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_shared=min(cfg.n_shared, 1),
        d_expert=32 if cfg.d_expert else 0,
        capacity_factor=8.0,  # no token drops in smoke tests
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_heads=0, ssm_inner=0,
        ssm_head_dim=16,
        attn_every=2 if cfg.attn_every else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
        window=min(cfg.window, 64) if cfg.window else 0,
        remat="none",
        param_dtype=jnp.float32,
    )
    return c
