"""granite-34b — llama-arch code model, MQA (kv=1), 88 layers.
[arXiv:2405.04324; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv=1, d_ff=24576,
    vocab=49152, act="gelu", norm="ln",
    notes="MQA kv=1; depth-extended granite-20b")
