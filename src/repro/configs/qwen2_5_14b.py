"""qwen2.5-14b — GQA with QKV bias. [hf:Qwen/Qwen2.5-*; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=13824,
    vocab=152064, qkv_bias=True, act="swiglu", norm="rms",
    notes="40 heads not divisible by model=16 -> baseline replicates "
          "head sharding; see §Perf head-padding optimization")
