"""Architecture registry: the 10 assigned configs + smoke twins."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig, reduced

# Import side registers each arch module's CONFIG
from . import (deepseek_moe_16b, granite_moe_1b_a400m, granite_20b,
               granite_34b, qwen2_5_14b, yi_34b, zamba2_1_2b,
               llava_next_34b, mamba2_130m, seamless_m4t_large_v2)

_MODULES = [deepseek_moe_16b, granite_moe_1b_a400m, granite_20b,
            granite_34b, qwen2_5_14b, yi_34b, zamba2_1_2b,
            llava_next_34b, mamba2_130m, seamless_m4t_large_v2]

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str, smoke: bool = False) -> ModelConfig:
    base = name[:-6] if name.endswith("-smoke") else name
    cfg = ARCHS[base]
    return reduced(cfg) if (smoke or name.endswith("-smoke")) else cfg


def names():
    return sorted(ARCHS)
