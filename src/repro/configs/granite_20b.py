"""granite-20b — llama-arch code model, MQA (kv=1), gelu 4x MLP.
[arXiv:2405.04324; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576,
    vocab=49152, act="gelu", norm="ln",
    notes="MQA kv=1; gpt-bigcode-style gelu MLP (d_ff = 4*d)")
