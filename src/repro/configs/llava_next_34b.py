"""llava-next-34b — yi-34b language backbone; anyres vision frontend is a
STUB (precomputed patch embeddings). [hf:llava-hf/llava-v1.6-*; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480,
    vocab=64000, act="swiglu", norm="rms",
    n_frontend_tokens=2880,
    notes="anyres tiling ~ 2880 image tokens supplied pre-embedded")
