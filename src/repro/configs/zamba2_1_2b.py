"""zamba2-1.2b — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_head_dim=64,
    attn_every=6, window=4096, act="gelu", norm="rms",
    notes="38 Mamba2 blocks; one SHARED attention+MLP block applied "
          "every 6 blocks (Zamba2 weight sharing); 4k sliding window "
          "for long-context decode (DESIGN §Arch-applicability)")
