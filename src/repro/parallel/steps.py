"""pjit step builders: training and serving, with full sharding tables.

``make_train_step``/``make_serve_step`` return (jitted fn, in/out
shardings, abstract inputs) so the same builder serves the dry-run
(lower+compile only), the real trainer, and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..launch import specs as S
from ..models import encdec, lm
from ..models.params import (AxisRules, ParamSpec, default_rules, is_spec,
                             tree_abstract, zero1_pspec)
from ..optim import adamw


def model_specs(cfg: ModelConfig):
    return encdec.encdec_specs(cfg) if cfg.family == "encdec" \
        else lm.lm_specs(cfg)


def param_shardings(rules: AxisRules, spec_tree):
    return rules.tree_shardings(spec_tree)


def opt_shardings(rules: AxisRules, spec_tree, opt_cfg: adamw.AdamWConfig):
    """ZeRO-1: moments take the param sharding + 'data' on a free axis."""
    def sh(spec: ParamSpec):
        return NamedSharding(rules.mesh, zero1_pspec(rules, spec))
    moments = jax.tree_util.tree_map(sh, spec_tree, is_leaf=is_spec)
    out = {"m": moments, "v": moments,
           "step": NamedSharding(rules.mesh, P())}
    if opt_cfg.grad_compress:
        out["err"] = moments
    return out


def batch_shardings(rules: AxisRules, cfg, shape):
    axes = S.batch_pspec_axes(cfg, shape)
    bspecs = S.batch_specs(cfg, shape)
    return {k: NamedSharding(rules.mesh,
                             rules.pspec_for(bspecs[k].shape, axes[k],
                                             what=f"batch.{k}"))
            for k in bspecs}


# ---------------------------------------------------------------------------


def _with_tp_pad(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Record the mesh's TP degree on the config: enables group-aligned
    head padding (exact math; see ModelConfig.head_padding) and the
    row-parallel KV fallback in attention_specs."""
    tp = mesh.shape.get("model", 1)
    if tp > 1 and cfg.n_heads:
        return dataclasses.replace(cfg, tp_pad=tp)
    return cfg


def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    opt_cfg: Optional[adamw.AdamWConfig] = None,
                    rules: Optional[AxisRules] = None,
                    backend: str = "xla", strategy: str = "tp"):
    """Returns (step_fn, bundle) where step_fn(params, opt_state, batch)
    -> (params, opt_state, metrics), fully sharded and donated."""
    cfg = _with_tp_pad(cfg, mesh)
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    rules = rules or default_rules(mesh, strategy)
    spec_tree = model_specs(cfg)
    fwd = encdec.forward if cfg.family == "encdec" else lm.forward

    # ZeRO-2: gradients are reduce-scattered onto the data axis right out
    # of backward (same placement as the ZeRO-1 moments), so no device
    # ever holds a full gradient replica.
    z1_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, zero1_pspec(rules, s)),
        spec_tree, is_leaf=is_spec)

    def step(params, opt_state, batch):
        def loss_fn(p):
            loss, _ = fwd(cfg, p, batch, rules=rules, backend=backend)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.tree_util.tree_map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
            grads, z1_sh)
        params, opt_state, metrics = adamw.update(grads, opt_state, params,
                                                  opt_cfg)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    p_sh = param_shardings(rules, spec_tree)
    o_sh = opt_shardings(rules, spec_tree, opt_cfg)
    # batch shardings are supplied by the caller per shape
    return step, {"rules": rules, "specs": spec_tree, "param_sh": p_sh,
                  "opt_sh": o_sh, "opt_cfg": opt_cfg}


def jit_train_step(cfg, mesh, shape: ShapeConfig,
                   opt_cfg: Optional[adamw.AdamWConfig] = None,
                   backend: str = "xla", rules=None, strategy: str = "tp"):
    step, bundle = make_train_step(cfg, mesh, opt_cfg, rules=rules,
                                   backend=backend, strategy=strategy)
    rules = bundle["rules"]
    b_sh = batch_shardings(rules, cfg, shape)
    metrics_sh = {"grad_norm": NamedSharding(mesh, P()),
                  "lr": NamedSharding(mesh, P()),
                  "loss": NamedSharding(mesh, P())}
    jitted = jax.jit(
        step,
        in_shardings=(bundle["param_sh"], bundle["opt_sh"], b_sh),
        out_shardings=(bundle["param_sh"], bundle["opt_sh"], metrics_sh),
        donate_argnums=(0, 1),
    )
    abstract = (tree_abstract(bundle["specs"]),
                _opt_abstract(bundle["specs"], bundle["opt_cfg"]),
                S.batch_specs(cfg, shape))
    return jitted, bundle, abstract


def _opt_abstract(spec_tree, opt_cfg):
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), spec_tree,
        is_leaf=is_spec)
    out = {"m": mom, "v": mom, "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if opt_cfg.grad_compress:
        out["err"] = mom
    return out


# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, mesh: Mesh,
                    rules: Optional[AxisRules] = None,
                    backend: str = "xla", strategy: str = "tp"):
    cfg = _with_tp_pad(cfg, mesh)
    rules = rules or default_rules(mesh, strategy)
    spec_tree = model_specs(cfg)
    dec = encdec.decode_step if cfg.family == "encdec" else lm.decode_step

    def step(params, cache, tokens, pos):
        logits, new_cache = dec(cfg, params, cache, tokens, pos,
                                rules=rules, backend=backend)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    # raw_step: the un-jitted body, re-traceable inside larger programs
    # (the serving driver scans it over a whole prompt for one-call
    # batched prefill instead of one jitted dispatch per token)
    return step, {"rules": rules, "specs": spec_tree, "raw_step": step,
                  "param_sh": param_shardings(rules, spec_tree)}


def jit_serve_step(cfg, mesh, shape: ShapeConfig, backend: str = "xla",
                   rules=None, strategy: str = "tp"):
    step, bundle = make_serve_step(cfg, mesh, rules=rules, backend=backend,
                                   strategy=strategy)
    rules = bundle["rules"]
    cache_tree = S.cache_spec_tree(cfg, shape)
    cache_sh = rules.tree_shardings(cache_tree)
    b_sh = batch_shardings(rules, cfg, shape)
    tok_sh = NamedSharding(mesh, rules.pspec_for(
        (shape.global_batch,), ("batch",), what="tokens_out"))
    jitted = jax.jit(
        step,
        in_shardings=(bundle["param_sh"], cache_sh, b_sh["tokens"],
                      b_sh["pos"]),
        out_shardings=(tok_sh, cache_sh),
        donate_argnums=(1,),
    )
    abstract = (tree_abstract(bundle["specs"]),
                tree_abstract(cache_tree),
                S.batch_specs(cfg, shape)["tokens"],
                S.batch_specs(cfg, shape)["pos"])
    return jitted, bundle, abstract
