"""Shared Pallas utilities."""
from __future__ import annotations

try:  # TPU-specific namespace (present in jax 0.8)
    import jax.experimental.pallas.tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def compiler_params(dimension_semantics):
    """Best-effort TPU compiler params (ignored in interpret mode)."""
    if pltpu is None:
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=tuple(dimension_semantics))
            except TypeError:
                continue
    return None


def vmem_scratch(shape, dtype):
    if pltpu is None:
        raise RuntimeError("pallas tpu namespace unavailable")
    return pltpu.VMEM(shape, dtype)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


NEG_INF = -1e30
