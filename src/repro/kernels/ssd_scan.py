"""Mamba2 SSD (state-space duality) chunked scan kernel.

COX mapping: the chunk loop is the *inter-warp loop* (sequential grid
dimension carrying the (N, P) state in VMEM scratch — the role of the
paper's replicated cross-PR variables); intra-chunk work is the
*intra-warp* part, done as dense MXU matmuls via the SSD dual form:

    y_intra = ((C Bᵀ) ⊙ L) X          L[i,j] = exp(A_i − A_j)·[i ≥ j]
    y_inter = exp(A) ⊙ (C h_in)
    h_out   = exp(A_C) h_in + (B ⊙ exp(A_C − A))ᵀ X

with A the within-chunk cumulative log-decay (A_C its total).  a ≤ 0
(decay), so every exponent is ≤ 0 — numerically safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import compiler_params, vmem_scratch

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, chunk: int):
    h, ci = pl.program_id(0), pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[:, 0, :].astype(jnp.float32)        # (C, P)
    a = a_ref[:, 0].astype(jnp.float32)           # (C,)
    b = b_ref[...].astype(jnp.float32)            # (C, N)
    c = c_ref[...].astype(jnp.float32)            # (C, N)

    A = jnp.cumsum(a)                             # within-chunk log decay
    A_total = A[-1]

    # intra-chunk (dual/matmul form — MXU work)
    i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask the exponent before exp (overflow hygiene; see ref.py)
    L = jnp.exp(jnp.where(i >= j, A[:, None] - A[None, :], -jnp.inf))
    s = (c @ b.T) * L                             # (C, C)
    y = s @ x                                     # (C, P)

    # inter-chunk contribution from carried state
    h_in = h_scr[...]                             # (N, P)
    y = y + jnp.exp(A)[:, None] * (c @ h_in)

    # state update for the next chunk
    w = b * jnp.exp(A_total - A)[:, None]         # (C, N)
    h_scr[...] = jnp.exp(A_total) * h_in + w.T @ x

    y_ref[:, 0, :] = y.astype(y_ref.dtype)


def ssd_scan(x, a, b, c, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = True):
    """x: (S, H, P); a: (S, H); b, c: (S, N) -> y: (S, H, P)."""
    S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to chunk multiple"
    nc = S // chunk

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(H, nc),
        in_specs=[
            pl.BlockSpec((chunk, 1, P), lambda h, ci: (ci, h, 0)),
            pl.BlockSpec((chunk, 1), lambda h, ci: (ci, h)),
            pl.BlockSpec((chunk, N), lambda h, ci: (ci, 0)),
            pl.BlockSpec((chunk, N), lambda h, ci: (ci, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, 1, P), lambda h, ci: (ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H, P), x.dtype),
        scratch_shapes=[vmem_scratch((N, P), jnp.float32)],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, b, c)
    return out
