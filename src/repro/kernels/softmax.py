"""Row softmax kernel (numerically stable, single VMEM pass).

COX mapping: row tile = warp batch; lane-axis max/sum are the warp
collectives (`red_max`, `red_add`) that the paper implements with AVX —
one VPU reduction here instead of a 32-step scalar loop (Table 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, compiler_params

ROWS_PER_TILE = 8


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = x.max(axis=1, keepdims=True)          # warp red_max
    e = jnp.exp(x - m)
    s = e.sum(axis=1, keepdims=True)          # warp red_add
    o_ref[...] = (e / s).astype(o_ref.dtype)


def softmax(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    rows, cols = x2.shape
    rt = min(ROWS_PER_TILE, rows)
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(cdiv(rows, rt),),
        in_specs=[pl.BlockSpec((rt, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)
