"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels (interpret=True on
CPU, compiled on TPU) are tested against with shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def row_reduce(x: jnp.ndarray, op: str = "sum") -> jnp.ndarray:
    """(rows, cols) -> (rows,) reduction."""
    if op == "sum":
        return x.sum(axis=-1)
    if op == "max":
        return x.max(axis=-1)
    if op == "absmax":
        return jnp.abs(x).max(axis=-1)
    raise ValueError(op)


def softmax(x: jnp.ndarray) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    m = x32.max(axis=-1, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / e.sum(axis=-1, keepdims=True)).astype(x.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = (x32 * x32).mean(axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)


ATTN_Q_CHUNK = 1024  # q-chunking bound on the S² logits working set


def attention(q, k, v, *, causal: bool = True, scale=None,
              window: int = 0, q_chunk: int = ATTN_Q_CHUNK) -> jnp.ndarray:
    """q: (S, H, D); k/v: (S, Hkv, D) — GQA by head-group broadcast.

    Queries are processed in chunks (lax.map + remat) so the logits
    working set is (H, q_chunk, S) rather than (H, S, S): the XLA-path
    analogue of the Pallas flash kernel's blocking."""
    S, H, D = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    k32 = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    v32 = jnp.repeat(v.astype(jnp.float32), g, axis=1)

    def chunk(args):
        qc, q0 = args                                   # (Cq, H, D), ()
        q32 = qc.astype(jnp.float32) * scale
        logits = jnp.einsum("qhd,khd->hqk", q32, k32)   # (H, Cq, S)
        if causal:
            qi = q0 + jnp.arange(qc.shape[0])[:, None]
            kj = jnp.arange(S)[None, :]
            msk = qi >= kj
            if window:
                msk = msk & (qi - kj < window)
            logits = jnp.where(msk[None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("hqk,khd->qhd", p, v32)

    if S <= q_chunk:
        out = chunk((q, jnp.int32(0)))
        return out.astype(q.dtype)
    assert S % q_chunk == 0
    nq = S // q_chunk
    qs = q.reshape(nq, q_chunk, H, D)
    starts = (jnp.arange(nq) * q_chunk).astype(jnp.int32)
    out = jax.lax.map(jax.checkpoint(chunk), (qs, starts))
    return out.reshape(S, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_len=None, scale=None):
    """Single-token decode: q (H, D); caches (S, Hkv, D)."""
    H, D = q.shape
    S, Hkv, _ = k_cache.shape
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    q32 = q.astype(jnp.float32) * scale
    k32 = jnp.repeat(k_cache.astype(jnp.float32), g, axis=1)
    v32 = jnp.repeat(v_cache.astype(jnp.float32), g, axis=1)
    logits = jnp.einsum("hd,shd->hs", q32, k32)
    if kv_len is not None:
        logits = jnp.where(jnp.arange(S)[None, :] < kv_len, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hs,shd->hd", p, v32).astype(q.dtype)


def ssd_scan(x, a, b, c, *, chunk: int = 0):
    """Mamba2 SSD (state-space dual) sequential reference.

    x: (S, H, P)  input per head
    a: (S, H)     log-decay (a = -softplus(...)); decay factor exp(a)
    b: (S, N)     input projection (shared across heads)
    c: (S, N)     output projection
    Returns y: (S, H, P); state update  h_t = exp(a_t) h_{t-1} + b_t x_tᵀ.
    """
    S, H, P = x.shape
    N = b.shape[-1]

    def step(h, inp):
        xt, at, bt, ct = inp
        h = jnp.exp(at)[:, None, None] * h + \
            jnp.einsum("n,hp->hnp", bt, xt)
        y = jnp.einsum("n,hnp->hp", ct, h)
        return h, y

    h0 = jnp.zeros((H, N, P), jnp.float32)
    _, y = jax.lax.scan(step, h0, (x.astype(jnp.float32),
                                   a.astype(jnp.float32),
                                   b.astype(jnp.float32),
                                   c.astype(jnp.float32)))
    return y.astype(x.dtype)


def ssd_scan_chunked(x, a, b, c, *, chunk: int = 128):
    """Chunked SSD — the dual (matmul) form, same math as the Pallas
    kernel but in pure jnp.  O(S·C) work and O(S/C) scan steps instead of
    O(S) steps: this is the production XLA path (sequential `ssd_scan`
    stays as the oracle).

    x: (S,H,P); a: (S,H); b,c: (S,N) -> (S,H,P)
    """
    S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xc = x.reshape(nc, chunk, H, P).astype(jnp.float32)
    ac = a.reshape(nc, chunk, H).astype(jnp.float32)
    bc = b.reshape(nc, chunk, N).astype(jnp.float32)
    cc = c.reshape(nc, chunk, N).astype(jnp.float32)

    A = jnp.cumsum(ac, axis=1)                       # (nc, C, H)
    A_tot = A[:, -1]                                 # (nc, H)
    i = jnp.arange(chunk)[:, None]
    j = jnp.arange(chunk)[None, :]
    causal = i >= j
    # L: (nc, H, C, C).  Mask the exponent BEFORE exp: the non-causal side
    # has positive exponents that overflow, and inf-in-the-dead-branch
    # poisons the backward (0·inf = NaN through jnp.where).
    diff = (A.transpose(0, 2, 1)[:, :, :, None]
            - A.transpose(0, 2, 1)[:, :, None, :])
    L = jnp.exp(jnp.where(causal[None, None], diff, -jnp.inf))
    cb = jnp.einsum("gin,gjn->gij", cc, bc)          # (nc, C, C)
    y_intra = jnp.einsum("ghij,gij,gjhp->gihp", L, cb, xc)

    # inter-chunk: scan over chunks carrying h (H, N, P)
    w = jnp.einsum("gjn,gjh->gjhn", bc, jnp.exp(A_tot[:, None] - A))
    h_add = jnp.einsum("gjhn,gjhp->ghnp", w, xc)     # (nc, H, N, P)

    def step(h, inp):
        atot, hadd = inp
        y_state_in = h                                # state entering chunk
        h = jnp.exp(atot)[:, None, None] * h + hadd
        return h, y_state_in

    h0 = jnp.zeros((H, N, P), jnp.float32)
    _, h_in = jax.lax.scan(step, h0, (A_tot, h_add))  # (nc, H, N, P)
    y_inter = jnp.einsum("gin,ghnp,gih->gihp", cc, h_in, jnp.exp(A))
    y = (y_intra + y_inter).reshape(S, H, P)
    return y.astype(x.dtype)


def topk_gate(logits, k: int):
    """MoE router: top-k over experts, softmax over the selected subset.
    logits: (T, E) -> (weights (T, k), indices (T, k))."""
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    w = jax.nn.softmax(vals, axis=-1)
    return w, idx


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down
