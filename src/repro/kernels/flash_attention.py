"""Blocked (flash) attention kernels: causal/windowed prefill + decode.

COX mapping (DESIGN.md §2): the Pallas grid over KV blocks is the
*inter-warp loop*; the online-softmax running max / running sum are the
warp collectives (`red_max` / `red_add`) vectorized over lanes; loop
peeling appears as the `pl.when` causal-block skip — the whole-warp
uniform branch of the paper's §3.3.1.

GQA is expressed through BlockSpec index maps (a q-head group reads its
shared KV head), so no repeated KV is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import NEG_INF, compiler_params, vmem_scratch

DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    h, iq, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = q @ k.T                                       # MXU (bq, bk)
        if causal:
            msk = q_pos >= k_pos
            if window:
                msk = msk & (q_pos - k_pos < window)
            s = jnp.where(msk, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))        # warp red_max
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)   # warp red_add
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    if causal:
        # peeled uniform branch (paper §3.3.1): whole KV blocks above the
        # diagonal are skipped — all "lanes" take the same direction
        pl.when((ik * bk) <= (iq * bq + bq - 1))(_body)
    else:
        _body()

    @pl.when(ik == nk - 1)
    def _finish():
        lsum = l_scr[...]
        lsum = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0] = (acc_scr[...] / lsum[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True):
    """q: (S, H, D); k/v: (S, Hkv, D) -> (S, H, D)."""
    S, H, D = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, "pad sequence to block multiple"
    nq, nk = S // bq, S // bk

    qt = q.transpose(1, 0, 2)   # (H, S, D)
    kt = k.transpose(1, 0, 2)   # (Hkv, S, D)
    vt = v.transpose(1, 0, 2)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=(H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda h, iq, ik: (h // g, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda h, iq, ik: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((H, S, D), q.dtype),
        scratch_shapes=[vmem_scratch((bq,), jnp.float32),
                        vmem_scratch((bq,), jnp.float32),
                        vmem_scratch((bq, D), jnp.float32)],
        compiler_params=compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# decode: one new token against a long KV cache
# ---------------------------------------------------------------------------


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, bk: int, nk: int):
    hkv, ik = pl.program_id(0), pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    g = q_ref.shape[1]

    @pl.when(ik * bk < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (g, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                       # (g, bk)
        pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        lsum = l_scr[...]
        lsum = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0] = (acc_scr[...] / lsum[:, None]).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, kv_len, *, scale=None,
                 bk: int = 512, interpret: bool = True):
    """q: (H, D); caches: (S, Hkv, D); kv_len: () int32 -> (H, D)."""
    H, D = q.shape
    S, Hkv, _ = k_cache.shape
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk

    qg = q.reshape(Hkv, g, D)
    kt = k_cache.transpose(1, 0, 2)
    vt = v_cache.transpose(1, 0, 2)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk),
        grid=(Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda h, ik: (0,)),
            pl.BlockSpec((1, g, D), lambda h, ik: (h, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda h, ik: (h, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda h, ik: (h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, D), lambda h, ik: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Hkv, g, D), q.dtype),
        scratch_shapes=[vmem_scratch((g,), jnp.float32),
                        vmem_scratch((g,), jnp.float32),
                        vmem_scratch((g, D), jnp.float32)],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len, qg, kt, vt)
    return out.reshape(H, D)
