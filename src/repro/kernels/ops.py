"""Kernel dispatch layer: jit'd wrappers selecting Pallas vs XLA (ref).

Policy:
* ``backend="pallas"``  — compiled Pallas TPU kernels (real hardware);
* ``backend="interpret"`` — Pallas interpret mode (CPU validation; the
  kernel *body* runs, slowly, through XLA);
* ``backend="xla"``     — the pure-jnp reference math (used by the model
  stack for CPU dry-runs: identical numerics, compact HLO);
* ``backend="auto"``    — pallas on TPU, xla elsewhere.

This is the hook the §Perf iterations toggle per-op.
"""
from __future__ import annotations

import os

import jax

from . import flash_attention as _fa
from . import norms as _norms
from . import ref as _ref
from . import softmax as _sm
from . import ssd_scan as _ssd
from . import warp_reduce as _wr

_DEFAULT = os.environ.get("REPRO_KERNEL_BACKEND", "auto")


def resolve(backend: str = "auto") -> str:
    if backend == "auto":
        backend = _DEFAULT
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def softmax(x, backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        return _ref.softmax(x)
    return _sm.softmax(x, interpret=(b == "interpret"))


def rmsnorm(x, w, eps: float = 1e-6, backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        return _ref.rmsnorm(x, w, eps)
    return _norms.rmsnorm(x, w, eps=eps, interpret=(b == "interpret"))


def layernorm(x, w, bias, eps: float = 1e-6, backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        return _ref.layernorm(x, w, bias, eps)
    return _norms.layernorm(x, w, bias, eps=eps, interpret=(b == "interpret"))


def row_reduce(x, op: str = "sum", backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        return _ref.row_reduce(x, op)
    return _wr.row_reduce(x, op, interpret=(b == "interpret"))


def attention(q, k, v, causal: bool = True, window: int = 0,
              backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        return _ref.attention(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(b == "interpret"))


def decode_attention(q, k_cache, v_cache, kv_len, backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        return _ref.decode_attention(q, k_cache, v_cache, kv_len)
    return _fa.flash_decode(q, k_cache, v_cache, kv_len,
                            interpret=(b == "interpret"))


def ssd_scan(x, a, bmat, cmat, chunk: int = 128, backend: str = "auto"):
    b = resolve(backend)
    if b == "xla":
        # chunked dual form: same math, production XLA path
        return _ref.ssd_scan_chunked(x, a, bmat, cmat, chunk=chunk)
    return _ssd.ssd_scan(x, a, bmat, cmat, chunk=chunk,
                         interpret=(b == "interpret"))


def topk_gate(logits, k: int):
    return _ref.topk_gate(logits, k)
