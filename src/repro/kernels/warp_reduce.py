"""Row-wise reduction kernel — the COX warp-collective pattern on TPU.

Hierarchical-collapsing mapping (DESIGN.md §2): the Pallas grid iterates
row tiles (the *inter-warp loop*); within a tile the (sublane × lane)
VREG layout holds 8×128 elements and the reduction over the lane axis is
the *intra-warp collective* (`red_add`/`red_max` — the AVX role from the
paper's Table 2, performed by the VPU in one shot instead of 32 scalar
iterations).

Tiling: rows are processed ROWS_PER_TILE at a time; the full column
extent of a tile lives in VMEM (cols ≤ ~64K f32 per 8-row tile is far
under the 16 MiB/core budget).  Column-tiled accumulation is used above
COL_TILE to bound VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, compiler_params

ROWS_PER_TILE = 8
COL_TILE = 2048


def _reduce_kernel(x_ref, o_ref, *, op: str, n_col_tiles: int, cols: int):
    acc = None
    for t in range(n_col_tiles):  # inter-warp loop over column tiles
        lo = t * COL_TILE
        width = min(COL_TILE, cols - lo)
        blk = x_ref[:, lo:lo + width].astype(jnp.float32)
        if op == "absmax":
            blk = jnp.abs(blk)
        # intra-warp collective: lane-axis reduction
        part = blk.sum(axis=1) if op == "sum" else blk.max(axis=1)
        if acc is None:
            acc = part
        else:
            acc = acc + part if op == "sum" else jnp.maximum(acc, part)
    o_ref[:, 0] = acc


def row_reduce(x: jnp.ndarray, op: str = "sum", *,
               interpret: bool = True) -> jnp.ndarray:
    """(rows, cols) -> (rows,). op in {sum, max, absmax}."""
    rows, cols = x.shape
    rt = min(ROWS_PER_TILE, rows)
    grid = (cdiv(rows, rt),)
    out = pl.pallas_call(
        functools.partial(_reduce_kernel, op=op,
                          n_col_tiles=cdiv(cols, COL_TILE), cols=cols),
        grid=grid,
        in_specs=[pl.BlockSpec((rt, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x)
    return out[:, 0].astype(x.dtype if op != "sum" else jnp.float32)
