"""RMSNorm / LayerNorm kernels.

COX mapping: the mean/variance reductions are warp `red_add` collectives
on the lane axis; rows are the inter-warp loop (grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, compiler_params

ROWS_PER_TILE = 8


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = (x * x).mean(axis=1, keepdims=True)      # warp red_add / n
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-6, interpret: bool = True):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    rows, cols = x2.shape
    rt = min(ROWS_PER_TILE, rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(cdiv(rows, rt),),
        in_specs=[pl.BlockSpec((rt, cols), lambda i: (i, 0)),
                  pl.BlockSpec((1, cols), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rt, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x2, w.reshape(1, -1))
    return out.reshape(shape)


def _layernorm_kernel(x_ref, w_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = x.mean(axis=1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * w_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def layernorm(x, w, b, *, eps: float = 1e-6, interpret: bool = True):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    rows, cols = x2.shape
    rt = min(ROWS_PER_TILE, rows)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(cdiv(rows, rt),),
        in_specs=[pl.BlockSpec((rt, cols), lambda i: (i, 0)),
                  pl.BlockSpec((1, cols), lambda i: (0, 0)),
                  pl.BlockSpec((1, cols), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rt, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x2, w.reshape(1, -1), b.reshape(1, -1))
    return out.reshape(shape)
