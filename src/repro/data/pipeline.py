"""Deterministic sharded data pipeline.

Design for the 1000-node posture:
* every (step, dp_rank) pair maps to a unique deterministic sample set —
  resume after failure or *elastic re-partitioning* (different dp world
  size) never replays or skips data;
* the iterator is stateless (`batch_at(step)`), so checkpoints only need
  the step counter — no iterator state to persist;
* sources: synthetic LM stream (default; token statistics controllable)
  or a memory-mapped token file (binary .npy of uint16/uint32).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    kind: str = "synthetic"       # synthetic | file
    path: Optional[str] = None    # token file for kind="file"
    zipf_a: float = 1.2           # synthetic vocabulary skew


class TokenSource:
    """Deterministic token batches: batch_at(step) -> {tokens, labels}."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        if data_cfg.kind == "file":
            if not data_cfg.path:
                raise ValueError("file source needs path")
            self._tokens = np.load(data_cfg.path, mmap_mode="r")
        else:
            self._tokens = None

    def _rng(self, step: int) -> np.random.Generator:
        h = hashlib.sha256(
            f"{self.data_cfg.seed}/{self.shape.name}/{step}".encode()
        ).digest()
        return np.random.default_rng(int.from_bytes(h[:8], "little"))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B = self.shape.global_batch
        S = self.shape.seq_len
        cfg = self.cfg
        if cfg.n_frontend_tokens and cfg.family != "encdec":
            S_text = S - cfg.n_frontend_tokens
        else:
            S_text = S
        rng = self._rng(step)
        if self._tokens is not None:
            n = self._tokens.shape[0] - (S_text + 1)
            starts = rng.integers(0, n, size=B)
            toks = np.stack([self._tokens[s:s + S_text + 1] for s in starts])
            toks = toks.astype(np.int32) % cfg.vocab
        else:
            # zipf-ish synthetic stream with局 local structure (bigram walk)
            toks = rng.zipf(self.data_cfg.zipf_a,
                            size=(B, S_text + 1)).astype(np.int64)
            toks = (toks - 1) % cfg.vocab
            toks = toks.astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "encdec":
            batch["frontend"] = rng.normal(
                size=(B, S, cfg.d_model)).astype(np.float32)
        elif cfg.n_frontend_tokens:
            batch["frontend"] = rng.normal(
                size=(B, cfg.n_frontend_tokens, cfg.d_model)
            ).astype(np.float32)
        return batch

    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
