"""Serving driver: batched greedy decoding with a continuous slot pool.

Requests enter a fixed-size batch of decode slots; finished sequences
free their slot for the next queued request (continuous batching).  The
serve step is the same jitted function the dry-run lowers.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m-smoke \
        --batch 4 --ctx 128 --requests 8 --tokens 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..configs.base import ShapeConfig
from ..models.params import init_params
from ..parallel import steps as steps_mod
from .mesh import make_host_mesh
from . import specs as S


class BatchedServer:
    def __init__(self, arch: str, *, batch: int = 4, ctx: int = 128,
                 mesh=None, seed: int = 0, params=None):
        self.cfg = registry.get(arch)
        self.shape = ShapeConfig(f"serve_{ctx}", ctx, batch, "decode")
        self.mesh = mesh or make_host_mesh(data=1, model=1)
        self.step_fn, self.bundle, _ = steps_mod.jit_serve_step(
            self.cfg, self.mesh, self.shape)
        if params is None:
            params = init_params(self.bundle["specs"],
                                 jax.random.PRNGKey(seed))
        self.params = jax.device_put(params, self.bundle["param_sh"])
        self.batch = batch
        self.ctx = ctx
        self.reset()

    def reset(self):
        cache_tree = S.cache_spec_tree(self.cfg, self.shape)
        from ..models.params import init_params as ip
        self.cache = jax.device_put(
            ip(cache_tree, jax.random.PRNGKey(1)),
            self.bundle["rules"].tree_shardings(cache_tree))
        self.pos = np.zeros((self.batch,), np.int32)
        self.tokens = np.zeros((self.batch,), np.int32)
        self.active = np.zeros((self.batch,), bool)
        self.outputs: List[List[int]] = [[] for _ in range(self.batch)]

    def prefill_prompt(self, slot: int, prompt: List[int]):
        """Feed a prompt token-by-token through the decode path (simple
        prefill; a chunked prefill kernel is the production option)."""
        self.pos[slot] = 0
        self.outputs[slot] = []
        self.active[slot] = True
        for t in prompt:
            self.tokens[slot] = t
            self._step_all()
        return self

    def _step_all(self):
        toks = jnp.asarray(self.tokens)
        pos = jnp.asarray(self.pos)
        nxt, self.cache = self.step_fn(self.params, self.cache, toks, pos)
        nxt = np.asarray(nxt)
        for i in range(self.batch):
            if self.active[i]:
                self.pos[i] += 1
        return nxt

    def decode(self, max_tokens: int, eos: Optional[int] = None):
        for _ in range(max_tokens):
            nxt = self._step_all()
            for i in range(self.batch):
                if not self.active[i]:
                    continue
                t = int(nxt[i])
                self.outputs[i].append(t)
                self.tokens[i] = t
                if eos is not None and t == eos:
                    self.active[i] = False
                if self.pos[i] >= self.ctx - 1:
                    self.active[i] = False
            if not self.active.any():
                break
        return self.outputs


def serve_requests(arch: str, *, batch: int, ctx: int, n_requests: int,
                   max_tokens: int, seed: int = 0) -> Dict[str, Any]:
    """Continuous batching over a queue of synthetic prompt requests."""
    rng = np.random.default_rng(seed)
    server = BatchedServer(arch, batch=batch, ctx=ctx, seed=seed)
    queue = [list(rng.integers(1, server.cfg.vocab, size=8))
             for _ in range(n_requests)]
    done: List[List[int]] = []
    t0 = time.time()
    while queue or server.active.any():
        for slot in range(batch):
            if not server.active[slot] and queue:
                server.prefill_prompt(slot, queue.pop(0))
        server.decode(max_tokens)
        for slot in range(batch):
            if not server.active[slot] and server.outputs[slot]:
                done.append(server.outputs[slot])
                server.outputs[slot] = []
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in done)
    return {"completed": len(done), "tokens": total_tokens,
            "wall_s": dt, "tok_per_s": total_tokens / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    out = serve_requests(args.arch, batch=args.batch, ctx=args.ctx,
                         n_requests=args.requests, max_tokens=args.tokens)
    print(f"served {out['completed']} requests, {out['tokens']} tokens, "
          f"{out['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
