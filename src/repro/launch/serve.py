"""Serving driver: batched greedy decoding with a continuous slot pool.

Requests enter a fixed-size batch of decode slots; finished sequences
free their slot for the next queued request (continuous batching).  The
serve step is the same jitted function the dry-run lowers.

Per-request kernel work rides **cox streams** (`--postproc`): each
decode slot owns a stream, and a finished request's postprocessing
kernel (a token histogram here — the stand-in for dedup/stats/safety
passes) is *enqueued* on its slot's stream and left in flight while the
server keeps decoding.  Independent requests' kernels overlap with each
other and with the decode steps; everything is synchronized once at the
end (``RequestKernelPool.collect``).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m-smoke \
        --batch 4 --ctx 128 --requests 8 --tokens 16 --postproc
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..configs.base import ShapeConfig
from ..core import cox
from ..models.params import init_params
from ..parallel import steps as steps_mod
from .mesh import make_host_mesh
from . import specs as S


@cox.kernel
def _token_hist(c, hist: cox.Array(cox.i32), toks: cox.Array(cox.i32),
                n: cox.i32, nbins: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, toks[i] % nbins, 1)


class RequestKernelPool:
    """Per-request kernel postprocessing on per-slot cox streams.

    ``submit`` enqueues the request's kernel on its slot's stream and
    returns immediately (the handle is a future — XLA async dispatch);
    the serving loop never blocks on postprocessing.  ``collect``
    synchronizes every stream once, at the end."""

    def __init__(self, n_slots: int, nbins: int = 64):
        self.nbins = nbins
        self.streams = [cox.Stream(name=f"req-slot{i}")
                        for i in range(n_slots)]
        self.handles: List[cox.LaunchHandle] = []

    def submit(self, slot: int, tokens: List[int]) -> None:
        toks = np.asarray(tokens, np.int32)
        n = int(toks.size)
        if n == 0:
            return
        block = 64
        h = self.streams[slot].launch(
            _token_hist, grid=-(-n // block), block=block,
            args=(np.zeros(self.nbins, np.int32), toks, n, self.nbins))
        self.handles.append(h)

    def collect(self) -> List[np.ndarray]:
        """Synchronize all streams and return each request's histogram
        (in completion order)."""
        return [np.asarray(h.result()["hist"]) for h in self.handles]


class BatchedServer:
    def __init__(self, arch: str, *, batch: int = 4, ctx: int = 128,
                 mesh=None, seed: int = 0, params=None):
        self.cfg = registry.get(arch)
        self.shape = ShapeConfig(f"serve_{ctx}", ctx, batch, "decode")
        self.mesh = mesh or make_host_mesh(data=1, model=1)
        self.step_fn, self.bundle, _ = steps_mod.jit_serve_step(
            self.cfg, self.mesh, self.shape)
        if params is None:
            params = init_params(self.bundle["specs"],
                                 jax.random.PRNGKey(seed))
        self.params = jax.device_put(params, self.bundle["param_sh"])
        self.batch = batch
        self.ctx = ctx
        self.reset()

    def reset(self):
        cache_tree = S.cache_spec_tree(self.cfg, self.shape)
        from ..models.params import init_params as ip
        self.cache = jax.device_put(
            ip(cache_tree, jax.random.PRNGKey(1)),
            self.bundle["rules"].tree_shardings(cache_tree))
        self.pos = np.zeros((self.batch,), np.int32)
        self.tokens = np.zeros((self.batch,), np.int32)
        self.active = np.zeros((self.batch,), bool)
        self.outputs: List[List[int]] = [[] for _ in range(self.batch)]

    def prefill_prompt(self, slot: int, prompt: List[int]):
        """Feed a prompt token-by-token through the decode path (simple
        prefill; a chunked prefill kernel is the production option)."""
        self.pos[slot] = 0
        self.outputs[slot] = []
        self.active[slot] = True
        for t in prompt:
            self.tokens[slot] = t
            self._step_all()
        return self

    def _step_all(self):
        toks = jnp.asarray(self.tokens)
        pos = jnp.asarray(self.pos)
        nxt, self.cache = self.step_fn(self.params, self.cache, toks, pos)
        nxt = np.asarray(nxt)
        for i in range(self.batch):
            if self.active[i]:
                self.pos[i] += 1
        return nxt

    def decode(self, max_tokens: int, eos: Optional[int] = None):
        for _ in range(max_tokens):
            nxt = self._step_all()
            for i in range(self.batch):
                if not self.active[i]:
                    continue
                t = int(nxt[i])
                self.outputs[i].append(t)
                self.tokens[i] = t
                if eos is not None and t == eos:
                    self.active[i] = False
                if self.pos[i] >= self.ctx - 1:
                    self.active[i] = False
            if not self.active.any():
                break
        return self.outputs


def serve_requests(arch: str, *, batch: int, ctx: int, n_requests: int,
                   max_tokens: int, seed: int = 0,
                   postproc: bool = False) -> Dict[str, Any]:
    """Continuous batching over a queue of synthetic prompt requests.

    With ``postproc=True`` every finished request's token histogram is
    issued on that slot's cox stream and left in flight — per-request
    kernel work overlaps across requests and with subsequent decode
    steps; one synchronize at the end collects everything."""
    rng = np.random.default_rng(seed)
    server = BatchedServer(arch, batch=batch, ctx=ctx, seed=seed)
    pool = RequestKernelPool(batch) if postproc else None
    queue = [list(rng.integers(1, server.cfg.vocab, size=8))
             for _ in range(n_requests)]
    done: List[List[int]] = []
    t0 = time.time()
    while queue or server.active.any():
        for slot in range(batch):
            if not server.active[slot] and queue:
                server.prefill_prompt(slot, queue.pop(0))
        server.decode(max_tokens)
        for slot in range(batch):
            if not server.active[slot] and server.outputs[slot]:
                done.append(server.outputs[slot])
                if pool is not None:
                    pool.submit(slot, server.outputs[slot])
                server.outputs[slot] = []
    out: Dict[str, Any] = {}
    if pool is not None:
        hists = pool.collect()          # one sync for all streams
        out["postproc"] = {
            "requests": len(hists),
            "hist_tokens": int(sum(int(h.sum()) for h in hists)),
        }
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in done)
    out.update({"completed": len(done), "tokens": total_tokens,
                "wall_s": dt, "tok_per_s": total_tokens / max(dt, 1e-9)})
    if pool is not None:
        # the histograms were binned from exactly the emitted tokens
        assert out["postproc"]["hist_tokens"] == total_tokens
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--postproc", action="store_true",
                    help="per-request postprocess kernels on per-slot "
                         "cox streams (overlapped, one final sync)")
    args = ap.parse_args()
    out = serve_requests(args.arch, batch=args.batch, ctx=args.ctx,
                         n_requests=args.requests, max_tokens=args.tokens,
                         postproc=args.postproc)
    msg = (f"served {out['completed']} requests, {out['tokens']} tokens, "
           f"{out['tok_per_s']:.1f} tok/s")
    if args.postproc:
        msg += (f" (+{out['postproc']['requests']} postproc kernels, "
                f"{out['postproc']['hist_tokens']} tokens binned)")
    print(msg)


if __name__ == "__main__":
    main()
