"""Serving driver: batched greedy decoding with a continuous slot pool.

Requests enter a fixed-size batch of decode slots; finished sequences
free their slot for the next queued request (continuous batching).  The
serve step is the same jitted function the dry-run lowers.

Per-request kernel work rides **cox streams** (`--postproc`): each
decode slot owns a stream, and a finished request's postprocessing
kernel (a token histogram here — the stand-in for dedup/stats/safety
passes) is *enqueued* on its slot's stream and left in flight while the
server keeps decoding.  Independent requests' kernels overlap with each
other and with the decode steps; everything is synchronized once at the
end (``RequestKernelPool.collect``).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m-smoke \
        --batch 4 --ctx 128 --requests 8 --tokens 16 --postproc
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..configs.base import ShapeConfig
from ..core import cox
from ..models.params import init_params
from ..parallel import steps as steps_mod
from .mesh import make_host_mesh
from . import specs as S


@cox.kernel
def _token_hist(c, hist: cox.Array(cox.i32), toks: cox.Array(cox.i32),
                n: cox.i32, nbins: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        c.atomic_add(hist, toks[i] % nbins, 1)


# the per-token pipeline kernels (--graph captures this 3-launch DAG
# once and replays it per decode step): masked histogram accumulate →
# running total → per-bin stats over the settled counts
@cox.kernel
def _tok_hist_add(c, hist: cox.Array(cox.i32), toks: cox.Array(cox.i32),
                  n: cox.i32, nbins: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < n:
        if toks[i] >= 0:                  # -1 marks an idle decode slot
            c.atomic_add(hist, toks[i] % nbins, 1)


@cox.kernel
def _tok_hist_total(c, tot: cox.Array(cox.i32), hist: cox.Array(cox.i32),
                    nbins: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < nbins:
        c.atomic_add(tot, 0, hist[i])


@cox.kernel
def _tok_hist_stats(c, sq: cox.Array(cox.i32), hist: cox.Array(cox.i32),
                    tot: cox.Array(cox.i32), nbins: cox.i32):
    i = c.block_idx() * c.block_dim() + c.thread_idx()
    if i < nbins:
        sq[i] = hist[i] * hist[i] + tot[0]


class TokenPipeline:
    """Per-decode-step token statistics as a 3-kernel DAG on one cox
    stream: histogram-accumulate (carried across steps) → total →
    per-bin stats.  ``graph=True`` captures the DAG once and replays it
    per step — one staged-executable call per token instead of three
    binds/launches — with the step's tokens and the carried histogram
    rebound each replay; ``graph=False`` issues the three launches
    eagerly.  Both modes are bitwise-identical by the graph-replay
    equivalence contract."""

    def __init__(self, batch: int, nbins: int = 64, *, graph: bool = False):
        self.batch = batch
        self.nbins = nbins
        self.use_graph = graph
        # priority -1: the per-token stats pipeline is latency-sensitive
        # (it gates the decode loop's step cadence) — the Kahn ready-set
        # dispatches it before the bulk postprocess pool's launches
        self.stream = cox.Stream(name="tok-pipeline", priority=-1)
        self.hist = np.zeros(nbins, np.int32)
        self.last: Dict[str, np.ndarray] = {}
        self._graph: Optional[cox.Graph] = None
        self.steps = 0

    def _launch_dag(self, toks: np.ndarray):
        """Issue the 3-kernel DAG on the stream (capturing or eager)."""
        block = 64
        s, nb = self.stream, self.nbins
        h0 = s.launch(_tok_hist_add, grid=-(-self.batch // block),
                      block=block,
                      args=(self.hist, toks, self.batch, nb))
        h1 = s.launch(_tok_hist_total, grid=-(-nb // block), block=block,
                      args=(np.zeros(1, np.int32), h0.outputs["hist"], nb))
        h2 = s.launch(_tok_hist_stats, grid=-(-nb // block), block=block,
                      args=(np.zeros(nb, np.int32), h1.outputs["hist"],
                            h1.outputs["tot"], nb))
        return h2

    def step(self, tokens: np.ndarray, active: np.ndarray) -> None:
        """Fold one decode step's emitted tokens (idle slots masked to
        -1) into the running statistics."""
        toks = np.where(active, tokens, -1).astype(np.int32)
        self.steps += 1
        if self.use_graph:
            if self._graph is None:       # capture once, replay per token
                self._graph = cox.Graph(name="tok-pipeline")
                with self._graph.capture(self.stream):
                    self._launch_dag(toks)
                res = self._graph.replay()
            else:
                res = self._graph.replay(toks=toks, hist=self.hist)
            self.hist = res["hist"]       # carried via node 2's pass-through
            self.last = {"tot": res["tot"], "sq": res["sq"]}
            return
        h2 = self._launch_dag(toks)
        out = h2.arrays()                 # async: futures, no host block
        self.hist = out["hist"]
        self.last = {"tot": out["tot"], "sq": out["sq"]}

    def collect(self) -> Dict[str, np.ndarray]:
        """Materialize the final statistics (one sync)."""
        return {"hist": np.asarray(self.hist),
                **{k: np.asarray(v) for k, v in self.last.items()}}


class RequestKernelPool:
    """Per-request kernel postprocessing on per-slot cox streams.

    ``submit`` enqueues the request's kernel on its slot's stream and
    returns immediately (the handle is a future — XLA async dispatch);
    the serving loop never blocks on postprocessing.  ``collect``
    synchronizes every stream once, at the end.

    A faulting slot is **isolated**, not fatal: its typed
    :class:`~repro.core.errors.CoxError` surfaces at that handle's own
    sync, the failed request is retired, the slot's stream is reset
    (un-poisoned) so it stays usable, and the remaining slots complete
    normally.  ``health`` carries the pool counters.

    On a multi-device pool the slot streams spread across devices:
    each stream is a distinct placement unit, so the dispatcher's
    round-robin policy deals slots over the healthy devices and
    independent requests' kernels run truly concurrently (priority 1:
    postprocessing is bulk work, dispatched after the latency-sensitive
    token pipeline)."""

    def __init__(self, n_slots: int, nbins: int = 64):
        self.nbins = nbins
        self.streams = [cox.Stream(name=f"req-slot{i}", priority=1)
                        for i in range(n_slots)]
        self.handles: List[cox.LaunchHandle] = []
        self._meta: List[tuple] = []      # (slot, n_tokens) per handle
        self.ok_tokens = 0                # tokens binned by completed slots
        self.health: Dict[str, Any] = {
            "submitted": 0, "completed": 0, "failed": 0,
            "failed_slots": [], "errors": []}

    def submit(self, slot: int, tokens: List[int]) -> None:
        toks = np.asarray(tokens, np.int32)
        n = int(toks.size)
        if n == 0:
            return
        block = 64
        h = self.streams[slot].launch(
            _token_hist, grid=-(-n // block), block=block,
            args=(np.zeros(self.nbins, np.int32), toks, n, self.nbins))
        self.handles.append(h)
        self._meta.append((slot, n))
        self.health["submitted"] += 1

    def collect(self) -> List[np.ndarray]:
        """Synchronize all streams and return each completed request's
        histogram (in completion order), isolating faulting slots."""
        hists: List[np.ndarray] = []
        for (slot, n), h in zip(self._meta, self.handles):
            try:
                hists.append(np.asarray(h.result()["hist"]))
                self.health["completed"] += 1
                self.ok_tokens += n
            except cox.CoxError as e:
                # the failed request is already retired by its surfaced
                # sync; reset clears any residual stream poisoning so
                # the slot can serve the next request
                self.health["failed"] += 1
                self.health["failed_slots"].append(slot)
                self.health["errors"].append(repr(e))
                self.streams[slot].reset()
        return hists


class BatchedServer:
    def __init__(self, arch: str, *, batch: int = 4, ctx: int = 128,
                 mesh=None, seed: int = 0, params=None):
        self.cfg = registry.get(arch)
        self.shape = ShapeConfig(f"serve_{ctx}", ctx, batch, "decode")
        self.mesh = mesh or make_host_mesh(data=1, model=1)
        self.step_fn, self.bundle, _ = steps_mod.jit_serve_step(
            self.cfg, self.mesh, self.shape)
        if params is None:
            params = init_params(self.bundle["specs"],
                                 jax.random.PRNGKey(seed))
        self.params = jax.device_put(params, self.bundle["param_sh"])
        self.batch = batch
        self.ctx = ctx
        # one batched-prefill executable per prompt length (shapes differ)
        self._prefill_cache: Dict[int, Any] = {}
        self.reset()

    def reset(self):
        cache_tree = S.cache_spec_tree(self.cfg, self.shape)
        from ..models.params import init_params as ip
        self.cache = jax.device_put(
            ip(cache_tree, jax.random.PRNGKey(1)),
            self.bundle["rules"].tree_shardings(cache_tree))
        self.pos = np.zeros((self.batch,), np.int32)
        self.tokens = np.zeros((self.batch,), np.int32)
        self.active = np.zeros((self.batch,), bool)
        self.outputs: List[List[int]] = [[] for _ in range(self.batch)]

    def _build_prefill(self, T: int):
        """One jitted program for a whole T-token prompt: ``lax.scan``
        of the raw (un-jitted) serve step over the token matrix, cache
        donated across the scan.  Replaces T host round-trips (one
        jitted dispatch per prompt token) with a single call; the math
        is identical to stepping token-by-token."""
        raw = self.bundle["raw_step"]

        def prefill(params, cache, tok_mat, pos0, mask):
            def body(carry, toks):
                cache, pos = carry
                _, cache = raw(params, cache, toks, pos)
                return (cache, pos + mask), None

            (cache, pos), _ = jax.lax.scan(body, (cache, pos0), tok_mat)
            return cache, pos

        return jax.jit(prefill, donate_argnums=(1,))

    def prefill_prompt(self, slot: int, prompt: List[int]):
        """Feed a prompt through the decode path in ONE step per slot: a
        single scanned+jitted call consumes the whole prompt (same
        per-token math as the decode loop, batched on device)."""
        self.pos[slot] = 0
        self.outputs[slot] = []
        self.active[slot] = True
        T = len(prompt)
        if T == 0:
            return self
        fn = self._prefill_cache.get(T)
        if fn is None:
            fn = self._prefill_cache[T] = self._build_prefill(T)
        # other slots keep stepping with their current (stale) token,
        # exactly as the old token-by-token loop did
        tok_mat = np.tile(self.tokens.astype(np.int32), (T, 1))
        tok_mat[:, slot] = np.asarray(prompt, np.int32)
        mask = self.active.astype(np.int32)
        self.cache, pos = fn(self.params, self.cache, jnp.asarray(tok_mat),
                             jnp.asarray(self.pos), jnp.asarray(mask))
        self.pos = np.array(pos)        # writable host copy
        self.tokens[slot] = prompt[-1]
        return self

    def _step_all(self):
        toks = jnp.asarray(self.tokens)
        pos = jnp.asarray(self.pos)
        nxt, self.cache = self.step_fn(self.params, self.cache, toks, pos)
        nxt = np.asarray(nxt)
        for i in range(self.batch):
            if self.active[i]:
                self.pos[i] += 1
        return nxt

    def decode(self, max_tokens: int, eos: Optional[int] = None,
               pipelines: Optional[List["TokenPipeline"]] = None):
        for _ in range(max_tokens):
            nxt = self._step_all()
            was_active = self.active.copy()
            for i in range(self.batch):
                if not self.active[i]:
                    continue
                t = int(nxt[i])
                self.outputs[i].append(t)
                self.tokens[i] = t
                if eos is not None and t == eos:
                    self.active[i] = False
                if self.pos[i] >= self.ctx - 1:
                    self.active[i] = False
            for p in pipelines or ():
                p.step(nxt, was_active)
            if not self.active.any():
                break
        return self.outputs


def serve_requests(arch: str, *, batch: int, ctx: int, n_requests: int,
                   max_tokens: int, seed: int = 0, postproc: bool = False,
                   graph: bool = False, chaos: bool = False) -> Dict[str, Any]:
    """Continuous batching over a queue of synthetic prompt requests.

    With ``postproc=True`` every finished request's token histogram is
    issued on that slot's cox stream and left in flight — per-request
    kernel work overlaps across requests and with subsequent decode
    steps; one synchronize at the end collects everything.

    With ``graph=True`` the per-token stats pipeline (3 dependent
    kernels per decode step) is stream-captured once into a
    ``cox.Graph`` and *replayed* every token — one fused XLA call
    instead of three launches' worth of host-side dispatch.  A shadow
    eager pipeline runs the same steps and the final statistics are
    asserted bitwise-equal.

    With ``chaos=True`` (requires ``postproc``) the first postprocess
    launch is forced to fail via ``cox.faults`` — the fault-injection
    drill: the faulting slot is isolated and every other slot must
    complete with its histogram totals intact."""
    if chaos and not postproc:
        raise ValueError("chaos=True requires postproc=True "
                         "(it faults the postprocess pool)")
    rng = np.random.default_rng(seed)
    server = BatchedServer(arch, batch=batch, ctx=ctx, seed=seed)
    pool = RequestKernelPool(batch) if postproc else None
    pipelines: List[TokenPipeline] = []
    if graph:
        pipelines = [TokenPipeline(batch, graph=True),
                     TokenPipeline(batch, graph=False)]
    queue = [list(rng.integers(1, server.cfg.vocab, size=8))
             for _ in range(n_requests)]
    done: List[List[int]] = []
    t0 = time.time()
    with contextlib.ExitStack() as stack:
        if chaos:
            # deterministically fail the first postprocess dispatch
            stack.enter_context(cox.faults.inject(
                "_token_hist", site="dispatch", index=0, times=1))
        while queue or server.active.any():
            for slot in range(batch):
                if not server.active[slot] and queue:
                    server.prefill_prompt(slot, queue.pop(0))
            server.decode(max_tokens, pipelines=pipelines)
            for slot in range(batch):
                if not server.active[slot] and server.outputs[slot]:
                    done.append(server.outputs[slot])
                    if pool is not None:
                        pool.submit(slot, server.outputs[slot])
                    server.outputs[slot] = []
        out: Dict[str, Any] = {}
        if pool is not None:
            hists = pool.collect()      # one sync for all streams
            out["postproc"] = {
                "requests": len(hists),
                "hist_tokens": int(sum(int(h.sum()) for h in hists)),
                "failed": pool.health["failed"],
                "health": dict(pool.health),
            }
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in done)
    out.update({"completed": len(done), "tokens": total_tokens,
                "wall_s": dt, "tok_per_s": total_tokens / max(dt, 1e-9)})
    out["dispatch_health"] = cox.get_dispatcher().health()
    if pool is not None:
        # the completed histograms were binned from exactly the tokens
        # their requests emitted — a faulted slot subtracts only its own
        assert out["postproc"]["hist_tokens"] == pool.ok_tokens
        if not chaos:
            assert pool.health["failed"] == 0
            assert out["postproc"]["hist_tokens"] == total_tokens
            # a clean run must never lean on the fault-tolerance
            # machinery: a ladder rung here would mask a real regression
            dh = out["dispatch_health"]
            assert dh["degradations"] == 0 and dh["sticky"] is None, dh
        else:
            # one injected fault; the blast radius is CUDA-faithful —
            # the faulting slot's stream is poisoned, so every request
            # it had in flight fails as a CoxDependencyError descendant,
            # and every *other* slot completes untouched
            h = pool.health
            assert h["failed"] >= 1 and set(h["failed_slots"]) == {0}, h
            assert h["completed"] == h["submitted"] - h["failed"], h
            roots = [e for e in h["errors"]
                     if not e.startswith("CoxDependencyError")]
            assert len(roots) == 1 and "injected" in roots[0], h
            # ...and the per-device counters confirm the fault stayed
            # confined to ONE device (slot 0's placement) — the other
            # devices' failure counters are untouched
            dev_fail = [d for d, c in
                        out["dispatch_health"]["devices"].items()
                        if c.get("failures", 0)]
            assert len(dev_fail) == 1, out["dispatch_health"]
    if graph:
        g_stats, e_stats = (p.collect() for p in pipelines)
        for k in g_stats:               # replay ≡ eager, bitwise
            assert np.array_equal(g_stats[k], e_stats[k]), k
        assert int(g_stats["hist"].sum()) == total_tokens
        out["graph"] = {"steps": pipelines[0].steps,
                        "hist_tokens": int(g_stats["hist"].sum()),
                        "replayed": pipelines[0]._graph is not None}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--postproc", action="store_true",
                    help="per-request postprocess kernels on per-slot "
                         "cox streams (overlapped, one final sync)")
    ap.add_argument("--graph", action="store_true",
                    help="capture the per-token stats pipeline once as a "
                         "cox.Graph and replay it every decode step "
                         "(verified bitwise against eager launches)")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-injection drill: force the first "
                         "postprocess launch to fail and assert the "
                         "remaining slots complete with correct totals "
                         "(requires --postproc)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure knob candidates for every all-auto "
                         "launch (winners persisted in the on-disk "
                         "autotune cache; a warm cache issues zero "
                         "measurement launches)")
    args = ap.parse_args()
    if args.autotune:
        import os
        os.environ.setdefault("COX_AUTOTUNE", "1")
    out = serve_requests(args.arch, batch=args.batch, ctx=args.ctx,
                         n_requests=args.requests, max_tokens=args.tokens,
                         postproc=args.postproc, graph=args.graph,
                         chaos=args.chaos)
    msg = (f"served {out['completed']} requests, {out['tokens']} tokens, "
           f"{out['tok_per_s']:.1f} tok/s")
    if args.postproc:
        msg += (f" (+{out['postproc']['requests']} postproc kernels, "
                f"{out['postproc']['hist_tokens']} tokens binned, "
                f"{out['postproc']['failed']} faulted)")
    if args.graph:
        msg += (f" (graph replay: {out['graph']['steps']} steps, "
                f"{out['graph']['hist_tokens']} tokens binned, "
                f"bitwise == eager)")
    # per-device placement health: one cell per device the dispatcher
    # placed work on (multi-device pools spread the slot streams)
    devs = out["dispatch_health"].get("devices", {})
    if devs:
        cells = ", ".join(
            f"{name}: {c['dispatches']}d/{c['failures']}f/"
            f"{c['degradations']}g" for name, c in sorted(devs.items()))
        msg += f" [devices: {cells}]"
    # autotune cache effectiveness: memory/disk hits vs measured misses
    # plus the measurement-launch count (zero on a warm fleet) — the
    # production signal that knob warmup amortized
    at = out["dispatch_health"].get("autotune", {})
    if at:
        msg += (f" [autotune: {at.get('hits', 0)}h/"
                f"{at.get('disk_hits', 0)}dh/{at.get('misses', 0)}m, "
                f"{at.get('measurements', 0)} measured]")
    print(msg)


if __name__ == "__main__":
    main()
