"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the program fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""
import argparse
import json
import os
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks device count at first init.

from ..configs import registry  # noqa: E402
from ..configs.base import LONG_CONTEXT_OK, SHAPES  # noqa: E402
from ..parallel import steps as steps_mod  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# ---------------------------------------------------------------------------
# HLO collective-byte accounting
# ---------------------------------------------------------------------------

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\(?([^)]*?)\)?\s*"
                       r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                       r"collective-permute)")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _tensor_bytes(ty: str) -> int:
    """bytes of one tensor type like 'bf16[256,1024]{1,0}'."""
    m = re.match(r"(\w+)\[([\d,]*)\]", ty.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                        r"collective-permute)(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        if "-done(" in rhs:
            continue  # avoid double counting async pairs
        op = opm.group(1)
        tys = re.findall(r"\w+\[[\d,]*\](?:\{[^}]*\})?", rhs[:opm.start()])
        b = sum(_tensor_bytes(t) for t in tys)
        out[op] += b
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, mesh, backend: str = "xla",
               smoke: bool = False, strategy: str = "tp",
               overrides: Optional[Dict[str, Any]] = None):
    import dataclasses
    cfg = registry.get(arch, smoke=smoke)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if shape.kind == "train" or shape.kind == "prefill":
        if shape.kind == "prefill":
            # prefill lowers the training forward without the optimizer —
            # use the train step graph with loss only (representative of a
            # batched prefill); decode shapes exercise serve_step.
            pass
        jitted, bundle, abstract = steps_mod.jit_train_step(
            cfg, mesh, shape, backend=backend, strategy=strategy)
        lowered = jitted.lower(*abstract)
    else:
        jitted, bundle, abstract = steps_mod.jit_serve_step(
            cfg, mesh, shape, backend=backend, strategy=strategy)
        lowered = jitted.lower(*abstract)
    return cfg, lowered, bundle


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             backend: str = "xla", smoke: bool = False,
             keep_hlo: bool = False, strategy: str = "tp",
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "strategy": strategy,
                           "overrides": dict(overrides or {}),
                           "mesh": "2x16x16" if multi_pod else "16x16"}
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        rec["status"] = "skipped"
        rec["reason"] = ("full quadratic attention at 524288 ctx — "
                         "sub-quadratic variant not specified by source "
                         "config (DESIGN.md §Arch-applicability)")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg, lowered, bundle = build_cell(arch, shape_name, mesh,
                                          backend=backend, smoke=smoke,
                                          strategy=strategy,
                                          overrides=overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # while-aware attribution: scan bodies × trip count (cost_analysis
        # counts them once — see repro.launch.hlo_analysis)
        from .hlo_analysis import analyze as hlo_analyze
        corrected = hlo_analyze(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "argument_size": int(mem.argument_size_in_bytes),
            "output_size": int(mem.output_size_in_bytes),
            "temp_size": int(mem.temp_size_in_bytes),
            "alias_size": int(mem.alias_size_in_bytes),
            "generated_code_size": int(mem.generated_code_size_in_bytes),
            "collectives": coll,
            "flops_corrected": corrected["flops"],
            "coll_bytes_corrected": corrected["coll_bytes"],
            "out_bytes_corrected": corrected["out_bytes"],
            "coll_per_op_corrected": {
                k.split(".", 1)[1]: v for k, v in corrected.items()
                if k.startswith("coll.")},
            "replication_notes": list(bundle["rules"].notes)[:20],
            "param_count": registry.get(arch, smoke=smoke).param_count(),
            "active_param_count":
                registry.get(arch, smoke=smoke).active_param_count(),
        })
        if keep_hlo:
            rec["hlo"] = hlo
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"],
                    default="off")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = registry.names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]
    results = []
    for mp in pods:
        for arch in archs:
            for sh in shapes:
                rec = run_cell(arch, sh, multi_pod=mp, smoke=args.smoke,
                               strategy=args.strategy)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    per_dev = (rec["argument_size"] + rec["output_size"]
                               + rec["temp_size"])
                    extra = (f"flops={rec['flops']:.3e} "
                             f"bytes={rec['bytes_accessed']:.3e} "
                             f"mem/dev={per_dev / 2 ** 30:.2f}GiB "
                             f"coll={sum(rec['collectives'][k] for k in _COLLECTIVE_OPS) / 2 ** 20:.1f}MiB "
                             f"compile={rec['compile_s']:.0f}s")
                elif status == "error":
                    extra = rec["error"][:200]
                else:
                    extra = rec["reason"][:80]
                print(f"[{rec['mesh']}] {arch} × {sh}: {status} {extra}",
                      flush=True)
                results.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    bad = [r for r in results if r["status"] == "error"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
