"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation: this is what the dry-run
lowers against.  For training that's {tokens, labels(, frontend)}; for
decode it's {tokens, pos} plus the cache (built from cache_specs).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import encdec, lm
from ..models.params import tree_abstract

ENC_LEN_DECODE = 3072  # encoder memory length for enc-dec decode shapes


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "frontend": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                 jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.n_frontend_tokens:
            # frontend embeds replace the first n tokens of the sequence
            St = S - cfg.n_frontend_tokens
            out["tokens"] = jax.ShapeDtypeStruct((B, St), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((B, St), jnp.int32)
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return out
    # decode: one new token against a seq_len cache
    return {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def cache_abstract(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return tree_abstract(encdec.cache_specs(cfg, B, S, ENC_LEN_DECODE))
    return tree_abstract(lm.cache_specs(cfg, B, S))


def cache_spec_tree(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return encdec.cache_specs(cfg, B, S, ENC_LEN_DECODE)
    return lm.cache_specs(cfg, B, S)


def batch_pspec_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    """Logical axes for each batch input (resolved via AxisRules)."""
    if shape.kind in ("train", "prefill"):
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
        if cfg.family == "encdec" or cfg.n_frontend_tokens:
            axes["frontend"] = ("batch", None, None)
        return axes
    return {"tokens": ("batch",), "pos": ("batch",)}
