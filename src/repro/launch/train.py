"""Training driver: sharded pjit train loop with checkpoint/restart,
straggler watchdog, deterministic resume, and failure drills.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m-smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..checkpoint.ckpt import CheckpointManager
from ..configs import registry
from ..configs.base import ShapeConfig
from ..data.pipeline import DataConfig, TokenSource
from ..ft.watchdog import FailureInjector, StepWatchdog, retry_loop
from ..models.params import init_params
from ..optim import adamw
from ..parallel import steps as steps_mod
from .mesh import make_host_mesh


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 25,
          data_cfg: Optional[DataConfig] = None,
          mesh=None, seed: int = 0, log_every: int = 10,
          injector: Optional[FailureInjector] = None,
          deadline_s: float = 300.0,
          opt_cfg: Optional[adamw.AdamWConfig] = None) -> Dict[str, Any]:
    cfg = registry.get(arch)
    shape = ShapeConfig(f"train_{seq}", seq, batch, "train")
    mesh = mesh or make_host_mesh(data=len(jax.devices()), model=1)
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=steps)

    jitted, bundle, abstract = steps_mod.jit_train_step(
        cfg, mesh, shape, opt_cfg=opt_cfg)
    source = TokenSource(cfg, shape, data_cfg or DataConfig(seed=seed))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    losses: list = []
    state: Dict[str, Any] = {}

    def init_state():
        params = init_params(bundle["specs"], jax.random.PRNGKey(seed))
        params = jax.device_put(params, bundle["param_sh"])
        opt = adamw.init_state(params, opt_cfg)
        opt = jax.device_put(opt, bundle["opt_sh"])
        return params, opt

    def run_from(start_step: int) -> int:
        params = opt = None
        if start_step > 0 and mgr is not None and mgr.latest_step() is not None:
            ck = mgr.latest_step()
            blob = mgr.restore(ck, {"params": abstract[0],
                                    "opt": abstract[1]},
                               {"params": bundle["param_sh"],
                                "opt": bundle["opt_sh"]})
            params, opt = blob["params"], blob["opt"]
            start_step = ck + 1
        if params is None:
            params, opt = init_state()
            start_step = 0

        wd = StepWatchdog(deadline_s)
        for step in range(start_step, steps):
            if injector is not None:
                injector.maybe_fail(step)
            batch_np = source.batch_at(step)
            batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
            wd.start(step)
            t0 = time.time()
            params, opt, metrics = jitted(params, opt, batch_dev)
            loss = float(metrics["loss"])
            wd.stop()
            wd.check()
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train {arch}] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"dt {time.time() - t0:.2f}s", flush=True)
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt})
        if mgr is not None:
            mgr.save(steps - 1, {"params": params, "opt": opt},
                     blocking=True)
        state["params"] = params
        return steps - 1

    if mgr is not None:
        final = retry_loop(run_from, ckpt_mgr=mgr)
    else:
        final = run_from(0)
    return {"final_step": final, "losses": losses,
            "params": state.get("params")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                seed=args.seed)
    print(f"done: final_step={out['final_step']} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
