"""While-aware HLO cost attribution.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so a model
that scans L layers under-reports FLOPs/collectives by ~L×.  This module
parses the optimized HLO, builds the computation call graph, extracts
each while-loop's trip count from its condition, and accumulates

  * dot FLOPs          (2 · prod(out dims) · contracted size, resolved
                        through a per-computation symbol table)
  * collective bytes   (result-shape bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute)
  * touched bytes      (Σ instruction output bytes × 2 — a read+write
                        traffic proxy)

with multipliers along the call chain (while trip counts; call/cond/
fusion = 1).  Validated against an unrolled-scan compile in tests.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_INSTR = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLEE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _dims(ty: str) -> Tuple[str, List[int]]:
    m = _SHAPE.search(ty)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _bytes_of(ty: str) -> int:
    dt, dims = _dims(ty)
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
        self.out_bytes = 0.0
        self.calls: List[Tuple[str, str]] = []   # (kind, callee)
        self.whiles: List[Tuple[str, str]] = []
        self.cmp_consts: List[int] = []
        self.types: Dict[str, str] = {}   # instr/param name -> type str


def _parse_header_params(line: str, comp: Computation):
    inside = line[line.find("(") + 1: line.rfind(")")]
    for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]"
                          r"(?:\{[^}]*\})?))", inside):
        comp.types[pm.group(1)] = pm.group(2)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if line and line[0] in "%E" and line.endswith("{") and "->" in line:
            name = line.split()[1] if line.startswith("ENTRY") else \
                line.split()[0]
            name = name.lstrip("%").split("(")[0]
            cur = Computation(name)
            comps[cur.name] = cur
            _parse_header_params(line, cur)
            continue
        if cur is None:
            continue
        s = line.strip()
        m = _INSTR.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        opm = re.search(r"\b([a-z][\w\-]*)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        ty = rhs[: opm.start()].strip()
        cur.types[name] = ty
        out_b = _bytes_of_all(ty)
        # HBM-traffic proxy: only instructions at computation top level
        # write buffers; fusion bodies are register/VMEM-resident, so the
        # accumulator descends into fusions for flops/collectives but NOT
        # for bytes (the fusion's own output row is counted here).
        # Zero-copy ops and CPU-backend bf16-legalization artifacts
        # (convert/copy) are excluded — a TPU build would not emit them.
        if op not in ("bitcast", "bitcast-convert", "reshape", "tuple",
                      "get-tuple-element", "parameter", "constant",
                      "convert", "copy", "iota"):
            cur.out_bytes += out_b
        if op == "dot":
            cur.flops += _dot_flops(rhs, cur)
        elif op in _COLLECTIVES and not op_ends_done(rhs):
            cur.coll[op] += out_b
        if op == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", rhs)
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            if cm and bm:
                cur.whiles.append((cm.group(1), bm.group(1)))
        else:
            kind = "fusion" if op in ("fusion", "reduce", "map", "scatter",
                                      "sort", "reduce-window",
                                      "select-and-scatter") else "call"
            for cm in _CALLEE.finditer(rhs):
                cur.calls.append((kind, cm.group(1)))
            bm = _BRANCHES.search(rhs)
            if bm:
                for b in bm.group(1).split(","):
                    cur.calls.append(("call", b.strip().lstrip("%")))
        if op == "constant" and ty.startswith("s32[]"):
            km = re.search(r"constant\((\d+)\)", rhs)
            if km:
                cur.cmp_consts.append(int(km.group(1)))
    return comps


def op_ends_done(rhs: str) -> bool:
    return bool(re.search(r"\b(?:all-gather|all-reduce|reduce-scatter|"
                          r"all-to-all|collective-permute)-done\(", rhs))


def _bytes_of_all(ty: str) -> int:
    """ty may be a tuple '(f32[..], f32[..])' or a single type."""
    return sum(_bytes_of(t) for t in
               re.findall(r"\w+\[[\d,]*\](?:\{[^}]*\})?", ty)) or 0


def _split_top_level(seg: str) -> List[str]:
    """Split an HLO operand list on commas at bracket depth 0 (shape
    dims ``[256,64]`` and layouts ``{1,0}`` carry internal commas)."""
    parts: List[str] = []
    cur: List[str] = []
    depth = 0
    for ch in seg:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def _dot_flops(rhs: str, comp: Computation) -> float:
    tys = re.findall(r"\w+\[[\d,]*\]", rhs[: rhs.find("dot(")])
    if not tys:
        return 0.0
    _, out_dims = _dims(tys[0])
    inner = rhs[rhs.find("dot(") + 4:]
    depth = 1
    end = 0
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    seg = inner[:end]
    km = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if not seg or not km:
        return 0.0
    # newer HLO prints operand types inline — 'dot(f32[256,64]{1,0} %x, …)'
    # — older HLO prints bare operand names resolved via the symbol
    # table, and mixed-format output can do either per operand.  Split
    # the operand list on TOP-LEVEL commas first (commas also appear
    # inside shape/layout brackets), then look for an inline shape only
    # within the lhs operand so an rhs inline type is never mistaken
    # for the lhs shape.
    operands = _split_top_level(seg)
    lhs = operands[0] if operands else ""
    tm = _SHAPE.search(lhs)
    if tm:
        lhs_dims = ([int(d) for d in tm.group(2).split(",")]
                    if tm.group(2) else [])
    else:
        name = lhs.strip().split()[-1].lstrip("%") if lhs.strip() else ""
        _, lhs_dims = _dims(comp.types.get(name, ""))
    contracted = 1
    for ix in km.group(1).split(","):
        if ix != "" and int(ix) < len(lhs_dims):
            contracted *= lhs_dims[int(ix)]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contracted


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None or not cond.cmp_consts:
        return 1
    return max(1, max(cond.cmp_consts))


def accumulate(comps: Dict[str, Computation],
               entry: Optional[str] = None) -> Dict[str, float]:
    if entry is None:
        called = set()
        for c in comps.values():
            called.update(n for _, n in c.calls)
            called.update(n for pair in c.whiles for n in pair)
        roots = [n for n in comps if n not in called]
        entry = roots[-1] if roots else next(iter(comps))

    totals = {"flops": 0.0, "coll_bytes": 0.0, "out_bytes": 0.0}
    per_op = {k: 0.0 for k in _COLLECTIVES}
    stack = set()

    def visit(name: str, mult: float, count_bytes: bool):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.add(name)
        totals["flops"] += mult * comp.flops
        if count_bytes:
            totals["out_bytes"] += mult * comp.out_bytes
        for k, v in comp.coll.items():
            per_op[k] += mult * v
            totals["coll_bytes"] += mult * v
        for kind, callee in comp.calls:
            visit(callee, mult, count_bytes and kind != "fusion")
        for cond, body in comp.whiles:
            t = trip_count(comps, cond)
            visit(cond, mult * t, count_bytes)
            visit(body, mult * t, count_bytes)
        stack.discard(name)

    visit(entry, 1.0, True)
    totals.update({f"coll.{k}": v for k, v in per_op.items()})
    return totals


def analyze(hlo_text: str) -> Dict[str, float]:
    return accumulate(parse_hlo(hlo_text))


def xla_cost(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()``.

    Depending on the JAX version this returns a dict or a one-entry
    list of per-partition dicts; older multi-partition builds return
    one dict per partition, which are summed here.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, dict):
        return dict(ca)
    out: Dict[str, float] = {}
    for part in ca:
        for k, v in part.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
            else:
                out.setdefault(k, v)
    return out
