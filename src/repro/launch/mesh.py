"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16×16 = 256 chips (v5e pod);
multi-pod: 2×16×16 = 512 chips with a leading "pod" axis for cross-pod
data parallelism (hierarchical DP: fast in-pod ICI, slow DCN across).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))
