"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16×16 = 256 chips (v5e pod);
multi-pod: 2×16×16 = 512 chips with a leading "pod" axis for cross-pod
data parallelism (hierarchical DP: fast in-pod ICI, slow DCN across).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


def device_pool(n: int | None = None, *, mesh=None):
    """The device tuple a :class:`~repro.core.streams.Dispatcher`
    places streams over: the first ``n`` host devices (all of them when
    ``n`` is None), or — given a ``mesh`` — that mesh's devices in
    flat order, so stream placement and sharded launches draw from the
    same pool.  Run under ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` to get N CPU devices."""
    if mesh is not None:
        devs = tuple(mesh.devices.flat)
    else:
        devs = tuple(jax.devices())
    return devs if n is None else devs[:n]
