"""Measured knob autotuning with a persistent on-disk winner cache.

The ``flat.choose_*`` heuristics are good defaults, but CuPBoP and
Polygeist both find CPU-side parity hinges on *per-kernel* scheduling
configuration.  This module measures a small candidate set — chunk ∈
``CHUNK_CANDIDATES`` × backend × warp_exec × schedule, pruned by the
cost model (chunked cells whose table + wave footprint blows the
``costmodel`` budget are replaced by grid-stride cells sized by
``costmodel.resident_slots``; the old chunk clamp survives only as a
last resort for explicitly pinned ``schedule='chunked'``) — and
persists winners in ``~/.cache/cox/autotune.json`` so a production
fleet warms once, not once per boot.

Contract with the resolver (``runtime.ResolvedLaunch``):

* only knobs the caller left on ``'auto'`` are tuned — an explicit
  ``backend=``/``warp_exec=``/``chunk=<int>`` is never overridden
  (``chunk_source == 'explicit'`` is the regression-tested guarantee);
* the heuristic pick is always in the candidate set, so a tuned launch
  is never slower than the untuned one beyond measurement noise;
* every measured winner is bitwise-equivalent by the backend-
  equivalence contract (all candidates compute scan/serial semantics).

Cache keying and robustness: entries are keyed like the launch cache
(compile token + geometry + knob tunability + arg-shape signature)
plus a CPU fingerprint, the file is version-stamped
(``AUTOTUNE_VERSION`` — stale stamps invalidate wholesale), writes are
atomic (temp file + ``os.replace``, with a read-merge so concurrent
writers union instead of clobber), and a corrupt/truncated file is
treated as empty — heuristics keep working, nothing crashes.
``COX_AUTOTUNE_CACHE`` overrides the path (``off`` disables disk);
``COX_AUTOTUNE=1`` turns tuning on for every all-auto launch.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from . import costmodel as _costmodel
from .types import GraphRef

AUTOTUNE_VERSION = 2   # v2: records/keys carry the launch schedule
ENV_CACHE = "COX_AUTOTUNE_CACHE"    # cache file path, or 'off' to disable
ENV_ENABLE = "COX_AUTOTUNE"         # '1' tunes every all-auto launch
CHUNK_CANDIDATES = (4, 8, 16, 32)
MEASURE_WARMUP = 1                  # un-timed compile/warm launches per cell
MEASURE_REPS = 2                    # timed launches per cell (min taken)

_lock = threading.RLock()
_memory: Dict[str, dict] = {}       # key -> winner record
_disk_seeded_from: Optional[str] = None   # path _memory was seeded from
_stats = {
    "hits": 0,          # resolved from the in-memory cache
    "disk_hits": 0,     # resolved from the on-disk cache (fresh process)
    "misses": 0,        # had to measure
    "measurements": 0,  # measurement launches issued (warmup + timed)
    "tuned": 0,         # launches whose knobs came from a measured winner
    "disk_writes": 0,
    "load_errors": 0,   # corrupt/stale cache files tolerated
}


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

def enabled() -> bool:
    """True when ``COX_AUTOTUNE`` asks every all-auto launch to tune."""
    return os.environ.get(ENV_ENABLE, "").strip().lower() in ("1", "true",
                                                              "on", "yes")


def cache_path() -> Optional[str]:
    """The on-disk winner-cache path, or ``None`` when disk persistence
    is off (``COX_AUTOTUNE_CACHE=off``)."""
    p = os.environ.get(ENV_CACHE)
    if p is not None:
        p = p.strip()
        if p.lower() in ("off", "0", "none", ""):
            return None
        return os.path.expanduser(p)
    return os.path.expanduser("~/.cache/cox/autotune.json")


def cpu_fingerprint() -> str:
    """Keys winners to the host class: knobs tuned on one machine shape
    transfer within a homogeneous fleet and re-measure elsewhere."""
    try:
        import jax
        backend = jax.default_backend()
        ndev = jax.local_device_count()
    except Exception:           # pragma: no cover - jax always importable
        backend, ndev = "cpu", 1
    return "%s-%s-%dcpu-%s-x%d" % (platform.machine(), platform.system(),
                                   os.cpu_count() or 1, backend, ndev)


def stats() -> Dict[str, int]:
    with _lock:
        return dict(_stats)


def entries() -> Dict[str, dict]:
    """Copy of the in-memory winner cache (bench/test introspection)."""
    with _lock:
        return {k: dict(v) for k, v in _memory.items()}


def reset(memory_only: bool = False) -> None:
    """Clear counters and the in-memory cache (tests; ``memory_only``
    simulates a fresh process that still sees the disk cache)."""
    global _disk_seeded_from
    with _lock:
        _memory.clear()
        _disk_seeded_from = None
        if not memory_only:
            for k in _stats:
                _stats[k] = 0


# ---------------------------------------------------------------------------
# the persistent cache (atomic, versioned, corruption-tolerant)
# ---------------------------------------------------------------------------

def _load_disk(path: str) -> Dict[str, dict]:
    """Read the winner file; any defect (missing, truncated, not JSON,
    wrong shape, stale version stamp) yields ``{}`` — the heuristics
    remain the fallback, a bad cache can never crash a launch."""
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
        if not isinstance(doc, dict) or doc.get("version") != AUTOTUNE_VERSION:
            raise ValueError("stale or malformed autotune cache")
        entries = doc.get("entries")
        if not isinstance(entries, dict):
            raise ValueError("malformed autotune cache entries")
        return {k: v for k, v in entries.items() if isinstance(v, dict)}
    except FileNotFoundError:
        return {}
    except Exception:
        with _lock:
            _stats["load_errors"] += 1
        return {}


def _save_disk(path: str, records: Dict[str, dict]) -> None:
    """Merge ``records`` into the file atomically: re-read, union, write
    a temp file in the same directory, ``os.replace``.  Concurrent
    writers may lose a race but readers always see a complete file."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    merged = _load_disk(path)
    merged.update(records)
    doc = {"version": AUTOTUNE_VERSION, "entries": merged}
    fd, tmp = tempfile.mkstemp(prefix=".autotune-", suffix=".json",
                               dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except Exception:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    with _lock:
        _stats["disk_writes"] += 1


def _seed_from_disk() -> None:
    """Populate the in-memory cache from disk once per (process, path).
    Caller holds ``_lock``."""
    global _disk_seeded_from
    path = cache_path()
    if path is None or _disk_seeded_from == path:
        return
    for k, v in _load_disk(path).items():
        _memory.setdefault(k, v)
    _disk_seeded_from = path


def cache_key(token: tuple, ck, rl, shapes: Dict[str, tuple], *,
              simd: bool,
              tunable: Tuple[bool, bool, bool, bool]) -> str:
    """Launch-cache-style key + CPU fingerprint.  The *tunable* mask
    (backend, warp_exec, chunk, schedule) is part of the key: a launch
    with an explicit backend tunes a smaller space and must not collide
    with the all-auto winner."""
    shape_sig = ",".join("%s:%s" % (k, "x".join(map(str, v)))
                         for k, v in sorted(shapes.items()))
    return "|".join([
        ck.kernel.name, repr(token), str(ck.n_phases),
        "g%s" % (rl.grid.astuple(),), "b%s" % (rl.block.astuple(),),
        "simd%d" % int(simd),
        "t%d%d%d%d" % tuple(int(t) for t in tunable),
        shape_sig, cpu_fingerprint(),
    ])


# ---------------------------------------------------------------------------
# candidate enumeration + measurement
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Candidate:
    backend: str
    warp_exec: str
    chunk: int
    schedule: str = "chunked"
    n_resident: Optional[int] = None

    @property
    def label(self) -> str:
        if self.schedule == "grid_stride":
            return "%s/%s/gs%d" % (self.backend, self.warp_exec,
                                   self.n_resident or 1)
        return "%s/%s/c%d" % (self.backend, self.warp_exec, self.chunk)

    @property
    def key(self) -> tuple:
        return (self.backend, self.warp_exec, self.chunk, self.schedule,
                self.n_resident)


def _chunk_candidates(ck, rl, shapes, *, warp_exec: str,
                      tunable_chunk: bool,
                      allow_empty: bool = False) -> List[int]:
    """Chunked-schedule chunk values worth measuring for a vmap-family
    backend, pruned by the footprint model (wave copies **plus** the
    materialized O(grid) bid table).  ``allow_empty=True`` lets an
    all-over-budget set come back empty — the caller swaps in
    grid-stride cells instead.  When the schedule is pinned 'chunked'
    (``allow_empty=False``) the old clamp survives as a last resort:
    shrink the wave until its copies fit (the table term cannot shrink,
    so this only bounds wave memory)."""
    grid = rl.grid.total
    if not tunable_chunk:
        return [rl.chunk]
    cands = sorted({c for c in CHUNK_CANDIDATES if c <= grid} | {rl.chunk})
    budget = _costmodel.footprint_budget()
    fitting = [c for c in cands
               if _costmodel.chunk_footprint(
                   ck, shapes, chunk=c, n_warps=rl.n_warps,
                   warp_exec=warp_exec, grid=grid) <= budget]
    if not fitting and not allow_empty:
        c = min(cands)
        while c > 1 and _costmodel.chunk_footprint(
                ck, shapes, chunk=c, n_warps=rl.n_warps,
                warp_exec=warp_exec) > budget:
            c //= 2
        fitting = [max(1, c)]
    return fitting


def _stride_candidates(ck, rl, shapes, *, warp_exec: str) -> List[int]:
    """Grid-stride wave widths worth measuring: the cost-model-sized
    width (``costmodel.resident_slots``) plus the resolver's pick when
    it already strided — a two-cell-max set, since stride footprint is
    grid-independent and the sizer already found the widest fit."""
    grid = rl.grid.total
    widths = {_costmodel.resident_slots(ck, shapes, grid=grid,
                                        n_warps=rl.n_warps,
                                        warp_exec=warp_exec)}
    if rl.schedule == "grid_stride" and rl.n_resident:
        widths.add(min(int(rl.n_resident), grid))
    return sorted(widths)


def _candidates(ck, rl, shapes, *, tunable: Tuple[bool, bool, bool, bool]
                ) -> List[Candidate]:
    tune_backend, tune_warp, tune_chunk, tune_sched = tunable
    grid = rl.grid.total
    from . import flat as _flat
    atomic_old = _flat.captures_atomic_old(ck.kernel)
    backends = [rl.backend]
    if tune_backend and grid > 1 and not atomic_old and \
            rl.backend in ("scan", "vmap"):
        backends = sorted({rl.backend, "scan", "vmap"})
    warps = [rl.warp_exec]
    if tune_warp and rl.n_warps > 1 and not atomic_old:
        warps = sorted({rl.warp_exec, "serial", "batched"})
    out: List[Candidate] = []
    for b in backends:
        for w in warps:
            if b == "scan":
                # chunk only changes the vmap wave width; scan ignores
                # it, so scan cells collapse to the resolved schedule
                out.append(Candidate(b, w, rl.chunk, rl.schedule,
                                     rl.n_resident))
                continue
            if not tune_sched and rl.schedule == "grid_stride":
                # schedule pinned strided (explicit/cooperative): vary
                # backend/warp only, keep the wave width
                out.append(Candidate(b, w, rl.chunk, "grid_stride",
                                     rl.n_resident))
                continue
            chunks = _chunk_candidates(ck, rl, shapes, warp_exec=w,
                                       tunable_chunk=tune_chunk,
                                       allow_empty=tune_sched)
            for c in chunks:
                out.append(Candidate(b, w, c, "chunked", None))
            if tune_sched and (not chunks
                               or rl.schedule == "grid_stride"):
                # the chunk table blows the budget (or the resolver
                # already strided): grid-stride cells replace the old
                # blind chunk clamp
                for r in _stride_candidates(ck, rl, shapes, warp_exec=w):
                    out.append(Candidate(b, w, r, "grid_stride", r))
    # de-dup preserving order (heuristic cell may coincide with a grid one)
    seen = set()
    uniq = []
    for cand in out:
        if cand.key not in seen:
            seen.add(cand.key)
            uniq.append(cand)
    return uniq


def _zero_globals(ck, shapes: Dict[str, tuple]):
    import jax.numpy as jnp
    from .types import ArraySpec
    g: Dict[str, Any] = {}
    for spec in ck.kernel.params:
        if not isinstance(spec, ArraySpec):
            continue
        shape = shapes.get(spec.name, (1,))
        n = 1
        for d in shape:
            n *= int(d)
        g[spec.name] = jnp.zeros((n,), spec.dtype.jnp)
    return g


def _measure(ck, rl, cand: Candidate, *, simd: bool, shapes,
             scalars) -> Optional[float]:
    """Median-of-min wall seconds for one candidate cell (warmup
    launches compile; timed launches block until ready).  Returns
    ``None`` for cells the backends reject (``CoxUnsupported``) or
    that fail to build — an unmeasurable candidate simply drops out."""
    import jax
    from . import runtime as _runtime
    rl_c = dataclasses.replace(rl, backend=cand.backend,
                               warp_exec=cand.warp_exec, chunk=cand.chunk,
                               schedule=cand.schedule,
                               n_resident=cand.n_resident)
    try:
        _, exe = _runtime.build_resolved(ck, rl_c, simd=simd)
        g = _zero_globals(ck, shapes)
        s = dict(scalars or {})
        for _i in range(MEASURE_WARMUP):
            jax.block_until_ready(exe(g, s))
        with _lock:
            _stats["measurements"] += MEASURE_WARMUP
        best = float("inf")
        for _i in range(MEASURE_REPS):
            t0 = time.perf_counter()
            jax.block_until_ready(exe(g, s))
            best = min(best, time.perf_counter() - t0)
        with _lock:
            _stats["measurements"] += MEASURE_REPS
        return best
    except Exception:
        return None


def _apply_record(rl, rec: dict, *, tunable: Tuple[bool, bool, bool, bool]):
    """Rebuild a ResolvedLaunch from a cached winner, honoring the
    tunable mask — a record can never move a knob the caller pinned."""
    tune_backend, tune_warp, tune_chunk, tune_sched = tunable
    kw: Dict[str, Any] = {}
    if tune_backend and rec.get("backend") in ("scan", "vmap"):
        kw["backend"] = rec["backend"]
    if tune_warp and rec.get("warp_exec") in ("serial", "batched"):
        kw["warp_exec"] = rec["warp_exec"]
    if tune_chunk and isinstance(rec.get("chunk"), int) \
            and rec["chunk"] >= 1:
        kw["chunk"] = min(rec["chunk"], rl.grid.total)
        kw["chunk_source"] = "autotuned"
    if tune_sched and rec.get("schedule") in ("chunked", "grid_stride"):
        nr = rec.get("n_resident")
        if rec["schedule"] == "grid_stride" \
                and isinstance(nr, int) and nr >= 1:
            kw["schedule"] = "grid_stride"
            kw["n_resident"] = min(nr, rl.grid.total)
            kw["schedule_source"] = "autotuned"
        elif rec["schedule"] == "chunked":
            kw["schedule"] = "chunked"
            kw["n_resident"] = None
            kw["schedule_source"] = "autotuned"
    if not kw:
        return rl
    with _lock:
        _stats["tuned"] += 1
    return dataclasses.replace(rl, **kw)


def tune(ck, token: tuple, rl, *, shapes: Dict[str, tuple],
         scalars: Optional[Dict[str, Any]] = None,
         globals_: Optional[Dict[str, Any]] = None,
         simd: bool = True, mesh=None,
         req_backend: str = "auto", req_warp_exec: str = "auto"):
    """Resolve ``rl``'s tunable knobs by cache lookup or measurement.

    Tunes only what the caller left on auto (``req_backend``/
    ``req_warp_exec == 'auto'``, ``rl.chunk_source == 'heuristic'``);
    skips sharded launches (the mesh shape is its own knob space) and
    graph-capture requests (``GraphRef`` placeholders have no data to
    measure).  Returns a possibly-updated ``ResolvedLaunch`` — always
    legal, never slower than the heuristic cell beyond noise because
    the heuristic cell is itself a candidate."""
    if mesh is not None:
        return rl
    if globals_ is not None and any(isinstance(v, GraphRef)
                                    for v in globals_.values()):
        return rl
    tunable = (req_backend == "auto", req_warp_exec == "auto",
               rl.chunk_source == "heuristic",
               rl.schedule_source == "heuristic")
    if not any(tunable):
        return rl
    key = cache_key(token, ck, rl, shapes, simd=simd, tunable=tunable)
    with _lock:
        rec = _memory.get(key)
        if rec is not None:
            _stats["hits"] += 1
            return _apply_record(rl, rec, tunable=tunable)
        _seed_from_disk()
        rec = _memory.get(key)
        if rec is not None:
            _stats["disk_hits"] += 1
            return _apply_record(rl, rec, tunable=tunable)
        _stats["misses"] += 1
    cands = _candidates(ck, rl, shapes, tunable=tunable)
    if len(cands) <= 1:
        return rl
    times: Dict[str, float] = {}
    best_cand: Optional[Candidate] = None
    best_t = float("inf")
    for cand in cands:
        t = _measure(ck, rl, cand, simd=simd, shapes=shapes,
                     scalars=scalars)
        if t is None:
            continue
        times[cand.label] = t
        if t < best_t:
            best_t, best_cand = t, cand
    if best_cand is None:           # nothing measurable: keep heuristics
        return rl
    est = _costmodel.estimate(ck, dataclasses.replace(
        rl, backend=best_cand.backend, warp_exec=best_cand.warp_exec,
        chunk=best_cand.chunk, schedule=best_cand.schedule,
        n_resident=best_cand.n_resident), shapes, simd=simd, mode="xla")
    rec = {
        "backend": best_cand.backend,
        "warp_exec": best_cand.warp_exec,
        "chunk": best_cand.chunk,
        "schedule": best_cand.schedule,
        "n_resident": best_cand.n_resident,
        "best_us": best_t * 1e6,
        "times_us": {k: v * 1e6 for k, v in sorted(times.items())},
        "op_estimate": est.op_estimate,
        "mem_estimate": est.mem_estimate,
        "gflops": est.gflops(best_t),
        "fingerprint": cpu_fingerprint(),
    }
    with _lock:
        _memory[key] = rec
    path = cache_path()
    if path is not None:
        try:
            with _lock:
                _save_disk(path, {key: rec})
        except OSError:
            pass                    # read-only FS: stay in-memory
    return _apply_record(rl, rec, tunable=tunable)
