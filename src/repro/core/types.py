"""Shared types for the COX core compiler.

The paper (COX, Han et al. 2021) transforms NVVM IR; we transform a
structured kernel IR produced by a Python-AST frontend.  Dtypes are the
small set CUDA kernels in the paper's benchmarks use.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Sequence

import jax.numpy as jnp
import numpy as np

WARP_SIZE = 32  # CUDA warpSize; configurable per-compile (TPU-native = 128 lanes)

# CUDA launch-geometry limits (compute capability >= 2.x, the paper's
# benchmark hardware): per-axis block caps, 1024 threads per block, and
# the 65535 cap on grid y/z.
CUDA_MAX_BLOCK = (1024, 1024, 64)
CUDA_MAX_BLOCK_THREADS = 1024
CUDA_MAX_GRID = (2**31 - 1, 65535, 65535)

# Cooperative-launch residency cap: CUDA's cudaLaunchCooperativeKernel
# requires every block of the grid to be simultaneously resident (SMs ×
# maxBlocksPerSM); a grid that does not fit cannot reach a grid barrier.
# Our analogue: every block's persistent state (locals + shared memory)
# is carried live between phase executables, so the whole grid must fit
# one resident wave of the chunk schedule.  The cap mirrors a large
# device (e.g. 108 SMs × 32 blocks ≈ 3456); launches above it raise
# CoxUnsupported exactly like cudaLaunchCooperativeKernel errors out.
COOP_MAX_RESIDENT_BLOCKS = 4096


class CoxUnsupported(Exception):
    """Raised when a kernel uses a feature outside the supported set.

    Mirrors the paper's coverage gaps: dynamic cooperative groups,
    grid/multi-grid sync, non-aligned barriers (Table 1 "X" rows).
    """


class CoxTypeError(Exception):
    pass


class DType(enum.Enum):
    f32 = "f32"
    f16 = "f16"
    bf16 = "bf16"
    i32 = "i32"
    i64 = "i64"
    u32 = "u32"
    b1 = "b1"  # predicate / bool

    @property
    def jnp(self):
        return {
            DType.f32: jnp.float32,
            DType.f16: jnp.float16,
            DType.bf16: jnp.bfloat16,
            DType.i32: jnp.int32,
            DType.i64: jnp.int64,
            DType.u32: jnp.uint32,
            DType.b1: jnp.bool_,
        }[self]

    @property
    def is_float(self) -> bool:
        return self in (DType.f32, DType.f16, DType.bf16)

    @property
    def is_int(self) -> bool:
        return self in (DType.i32, DType.i64, DType.u32)


def from_jnp(dt) -> DType:
    dt = jnp.dtype(dt)
    table = {
        jnp.dtype(jnp.float32): DType.f32,
        jnp.dtype(jnp.float16): DType.f16,
        jnp.dtype(jnp.bfloat16): DType.bf16,
        jnp.dtype(jnp.int32): DType.i32,
        jnp.dtype(jnp.int64): DType.i64,
        jnp.dtype(jnp.uint32): DType.u32,
        jnp.dtype(jnp.bool_): DType.b1,
    }
    if dt not in table:
        raise CoxTypeError(f"unsupported dtype {dt}")
    return table[dt]


def promote(a: DType, b: DType) -> DType:
    """C-style arithmetic promotion over our small lattice."""
    if a == b:
        return a
    order = [DType.b1, DType.i32, DType.u32, DType.i64, DType.bf16, DType.f16, DType.f32]
    # float beats int; f32 is the top float.
    if a.is_float or b.is_float:
        floats = [d for d in (a, b) if d.is_float]
        if len(floats) == 2 and floats[0] != floats[1]:
            return DType.f32
        return floats[0] if len(floats) == 1 else floats[0]
    return order[max(order.index(a), order.index(b))]


@dataclasses.dataclass(frozen=True)
class Dim3:
    """CUDA ``dim3`` launch geometry.  The internal schedule stays
    *linear* (CUDA's own model): threads linearize x-fastest into warps
    (``lin = x + dim.x * (y + dim.y * z)``) and blocks linearize the
    same way into the grid walk; the per-axis intrinsics are cheap
    decompositions of the linear id against these static extents."""
    x: int
    y: int = 1
    z: int = 1

    @property
    def total(self) -> int:
        return self.x * self.y * self.z

    def astuple(self) -> tuple:
        return (self.x, self.y, self.z)

    def __repr__(self):
        return f"dim3({self.x}, {self.y}, {self.z})"


def as_dim3(v, what: str = "launch dimension") -> Dim3:
    """Normalize ``int | (x,) | (x, y) | (x, y, z) | Dim3`` to one
    canonical :class:`Dim3` (missing axes are 1, CUDA's default)."""
    if isinstance(v, Dim3):
        d = v
    elif isinstance(v, (int, np.integer)) and not isinstance(v, bool):
        d = Dim3(int(v))
    elif isinstance(v, (tuple, list)):
        if not 1 <= len(v) <= 3:
            raise ValueError(f"{what} must have 1-3 components, got {v!r}")
        if not all(isinstance(c, (int, np.integer)) and not isinstance(c, bool)
                   for c in v):
            raise TypeError(f"{what} components must be ints, got {v!r}")
        d = Dim3(*(int(c) for c in v))
    else:
        raise TypeError(f"{what} must be an int or a 1-3 tuple of ints, "
                        f"got {type(v).__name__}")
    if d.x <= 0 or d.y <= 0 or d.z <= 0:
        raise ValueError(f"{what} components must be positive, got {d}")
    return d


def dim3_tuple(v) -> Optional[tuple]:
    """Normalize a Dim3 / tuple / None to a static (x, y, z) int tuple
    (None passes through: 'no geometry — treat as 1-D linear')."""
    if v is None:
        return None
    if isinstance(v, Dim3):
        return v.astuple()
    t = tuple(int(c) for c in v)
    return t + (1,) * (3 - len(t))


def check_launch_geometry(grid: Dim3, block: Dim3):
    """Enforce CUDA's launch limits on a normalized dim3 pair."""
    for ax, extent, cap in zip("xyz", block.astuple(), CUDA_MAX_BLOCK):
        if extent > cap:
            raise CoxUnsupported(
                f"CUDA blocks are limited to {cap} threads along "
                f"{ax} (got block.{ax}={extent})")
    if block.total > CUDA_MAX_BLOCK_THREADS:
        raise CoxUnsupported(
            f"CUDA blocks are limited to {CUDA_MAX_BLOCK_THREADS} threads "
            f"(got {block} = {block.total})")
    for ax, extent, cap in zip("xyz", grid.astuple(), CUDA_MAX_GRID):
        if extent > cap:
            raise CoxUnsupported(
                f"CUDA grids are limited to {cap} blocks along "
                f"{ax} (got grid.{ax}={extent})")


class BarrierLevel(enum.Enum):
    """Hierarchy of barrier scopes — the paper's central distinction,
    extended one level up: WARP < BLOCK < GRID."""
    WARP = "warp"    # __syncwarp() / implicit from warp collectives (RAW/WAR)
    BLOCK = "block"  # __syncthreads()
    GRID = "grid"    # this_grid().sync() — cooperative-groups grid barrier

    @property
    def rank(self) -> int:
        return {"warp": 0, "block": 1, "grid": 2}[self.value]

    def __ge__(self, other: "BarrierLevel") -> bool:  # wider scope subsumes
        return self.rank >= other.rank


class GraphRef:
    """Symbolic handle to a captured launch's output — the currency of
    stream capture (``repro.core.graphs``).

    While a stream is capturing, launch handles hand back ``GraphRef``
    placeholders instead of arrays; passing one as an argument to a
    later captured launch records a *data edge* in the captured DAG
    (the graph tracer threads the producer's output straight into the
    consumer, eliding the intermediate buffer).  A ``GraphRef`` never
    holds data: consuming it outside its capture raises
    :class:`CoxUnsupported` at enqueue."""

    __slots__ = ("node", "name", "shape", "dtype")

    def __init__(self, node, name: str, shape: tuple, dtype: DType):
        self.node = node          # owning GraphNode (repro.core.graphs)
        self.name = name          # output (global param) name
        self.shape = shape        # shape the consumer observes
        self.dtype = dtype

    def __repr__(self):
        return (f"GraphRef({self.node!r}.{self.name}, "
                f"shape={self.shape}, {self.dtype.value})")


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """A kernel parameter backed by global memory."""
    name: str
    dtype: DType


@dataclasses.dataclass(frozen=True)
class ScalarSpec:
    """A kernel parameter passed by value (block-uniform)."""
    name: str
    dtype: DType


@dataclasses.dataclass(frozen=True)
class SharedSpec:
    """A __shared__ array declaration (per-block)."""
    name: str
    shape: tuple
    dtype: DType


ParamSpec = Any  # ArraySpec | ScalarSpec


def _fmt_args(args: Sequence[Any]) -> str:
    return ", ".join(str(a) for a in args)
