"""Hierarchical Parallel Regions (paper §3.5, Fig. 7) and the two-level
machine the executor runs.

Partitioning (constructive form of Algorithm 2):

* **block level** — cut every out-edge of a block ending with a *block*
  barrier; isolate pure-branch blocks whose branch level is BLOCK (they
  become block-level peel nodes).  Connected components of what remains
  are the block-level PRs.  A block-level PR may contain warp-level
  control flow inside it — that is exactly the hierarchy of Fig. 7.
* **warp level, within each block-level PR** — cut every out-edge of a
  block ending with *any* barrier; isolate every remaining pure-branch
  block (warp-level peel).  Components are the warp-level PRs; by
  construction each is a straight Jmp-chain (all barrier-free divergence
  was predicated by the frontend).

The executor wraps each block-level PR in one inter-warp loop and runs
its warp-level machine per warp — the generated-code shape of Code 3 —
or, under warp-batched execution, runs all warps of the PR at once as a
(n_warps, W) lane plane (``execute.py``).  Warp-peel nodes resolve
their branch direction from lane 0 of the condition; in the batched
plane that decision becomes **per-warp** — each warp's lane 0 steers
that warp's own PC through the warp graph, so warps may sit at
different peel targets simultaneously (vmap's masked while/switch
batching keeps finished warps frozen).

Invariant (paper: "a warp-level PR is always a subset of a block-level
PR"): holds by construction and is property-tested.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from . import kernel_ir as K
from .cfg import CFG, Block, Br, Jmp, Ret, WarpBufCompute, WarpBufStore
from .types import BarrierLevel, CoxUnsupported

EXIT = -1  # sentinel node id


# ------------------------------ warp level ---------------------------------

WTarget = Tuple[str, int]  # ("node", id) | ("exit", exit_ix)


@dataclasses.dataclass
class WarpPR:
    id: int
    blocks: List[str]           # chain order
    succ: WTarget = ("exit", 0)


@dataclasses.dataclass
class WarpPeel:
    id: int
    cond: str
    on_true: WTarget = ("exit", 0)
    on_false: WTarget = ("exit", 0)


@dataclasses.dataclass
class WarpGraph:
    nodes: List[object]
    entry: int
    exit_targets: List[str]     # CFG block names outside the block-level PR
    # exit_targets[i] is where exit_ix == i continues at block level


# ------------------------------ block level --------------------------------


@dataclasses.dataclass
class BlockPR:
    id: int
    blocks: Set[str]
    entry_block: str
    warp: WarpGraph = None  # type: ignore
    succ_ids: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class BlockPeel:
    id: int
    cond: str
    t_id: int = EXIT
    f_id: int = EXIT


@dataclasses.dataclass
class Machine:
    nodes: List[object]
    entry: int
    cfg: CFG


def warp_peel_count(machine: Machine) -> int:
    """Number of warp-level peel nodes across all block-level PRs — the
    lane-0-resolved branches whose directions become per-warp under
    warp-batched execution.  0 means every warp graph is a straight
    chain and the batched plane never diverges at the PC level."""
    n = 0
    for node in machine.nodes:
        if isinstance(node, BlockPR) and node.warp is not None:
            n += sum(isinstance(w, WarpPeel) for w in node.warp.nodes)
    return n


# ----------------------------------------------------------------------------


class _UF:
    def __init__(self, items):
        self.p = {i: i for i in items}

    def find(self, x):
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a, b):
        self.p[self.find(a)] = self.find(b)


def _ends_with_barrier(blk: Block, level: BarrierLevel) -> bool:
    if not blk.instrs or not isinstance(blk.instrs[-1], K.Barrier):
        return False
    if level == BarrierLevel.WARP:
        return True  # any barrier ends a warp-level PR
    # block-level cut: block barriers and anything wider (a grid barrier
    # may never be collapsed across — defensive; compile_kernel splits
    # phases before a GRID barrier can reach the region machine)
    return blk.instrs[-1].level >= BarrierLevel.BLOCK


def _components(cfg: CFG, members: Set[str], cut_level: BarrierLevel,
                peels: Set[str]) -> Dict[str, int]:
    """Union-find components of `members` under the cut rules."""
    uf = _UF(members)
    for u in members:
        if u in peels:
            continue
        blk = cfg.blocks[u]
        if _ends_with_barrier(blk, cut_level):
            continue
        for v in blk.term.targets():
            if v in members and v not in peels:
                uf.union(u, v)
    comp: Dict[str, int] = {}
    remap: Dict[str, int] = {}
    for b in members:
        if b in peels:
            continue
        r = uf.find(b)
        if r not in remap:
            remap[r] = len(remap)
        comp[b] = remap[r]
    return comp


def build_machine(cfg: CFG) -> Machine:
    all_blocks = set(cfg.blocks.keys())
    block_peels = {n for n, b in cfg.blocks.items()
                   if b.is_pure_branch() and b.term.level == BarrierLevel.BLOCK}
    comp = _components(cfg, all_blocks, BarrierLevel.BLOCK, block_peels)

    nodes: List[object] = []
    comp_node: Dict[int, BlockPR] = {}
    peel_node: Dict[str, BlockPeel] = {}

    comp_blocks: Dict[int, Set[str]] = {}
    for b, c in comp.items():
        comp_blocks.setdefault(c, set()).add(b)

    # allocate node ids deterministically: components in order of their
    # first block in CFG insertion order, then peels
    order = []
    seen_c = set()
    for name in cfg.blocks:
        if name in block_peels:
            order.append(("peel", name))
        else:
            c = comp[name]
            if c not in seen_c:
                seen_c.add(c)
                order.append(("comp", c))

    def node_id_of_block(name: str) -> int:
        if name in block_peels:
            return peel_node[name].id
        return comp_node[comp[name]].id

    for kind, key in order:
        nid = len(nodes)
        if kind == "comp":
            blocks = comp_blocks[key]
            entry = _component_entry(cfg, blocks)
            node = BlockPR(nid, blocks, entry)
            comp_node[key] = node
        else:
            br: Br = cfg.blocks[key].term  # type: ignore
            node = BlockPeel(nid, br.cond)
            peel_node[key] = node
        nodes.append(node)

    # resolve edges
    for kind, key in order:
        if kind == "peel":
            name = key
            br: Br = cfg.blocks[name].term  # type: ignore
            pn = peel_node[name]
            pn.t_id = node_id_of_block(br.true)
            pn.f_id = node_id_of_block(br.false)
        else:
            node = comp_node[key]
            node.warp = _build_warp_graph(cfg, node)
            succ_ids = []
            for tgt in node.warp.exit_targets:
                succ_ids.append(EXIT if tgt == "@ret" else node_id_of_block(tgt))
            node.succ_ids = succ_ids

    entry_id = node_id_of_block(cfg.entry)
    return Machine(nodes, entry_id, cfg)


def _component_entry(cfg: CFG, blocks: Set[str]) -> str:
    if cfg.entry in blocks:
        return cfg.entry
    entries = set()
    for name in blocks:
        for p in cfg.preds(name):
            if p not in blocks:
                entries.add(name)
    if len(entries) != 1:
        raise CoxUnsupported(
            f"parallel region with {len(entries)} entries ({sorted(entries)}) — "
            f"irreducible control flow is outside the supported set")
    return entries.pop()


# ----------------------------------------------------------------------------


def _build_warp_graph(cfg: CFG, bpr: BlockPR) -> WarpGraph:
    members = bpr.blocks
    peels = {n for n in members if cfg.blocks[n].is_pure_branch()}
    comp = _components(cfg, members, BarrierLevel.WARP, peels)

    comp_blocks: Dict[int, List[str]] = {}
    for b, c in comp.items():
        comp_blocks.setdefault(c, []).append(b)

    nodes: List[object] = []
    comp_node: Dict[int, WarpPR] = {}
    peel_node: Dict[str, WarpPeel] = {}
    exit_targets: List[str] = []

    order = []
    seen_c = set()
    for name in cfg.blocks:
        if name not in members:
            continue
        if name in peels:
            order.append(("peel", name))
        else:
            c = comp[name]
            if c not in seen_c:
                seen_c.add(c)
                order.append(("comp", c))

    for kind, key in order:
        nid = len(nodes)
        if kind == "comp":
            chain = _chain_order(cfg, set(comp_blocks[key]), members)
            node = WarpPR(nid, chain)
            comp_node[key] = node
        else:
            br: Br = cfg.blocks[key].term  # type: ignore
            node = WarpPeel(nid, br.cond)
            peel_node[key] = node
        nodes.append(node)

    def target_of(name: str) -> WTarget:
        if name in members:
            if name in peels:
                return ("node", peel_node[name].id)
            return ("node", comp_node[comp[name]].id)
        if name not in exit_targets:
            exit_targets.append(name)
        return ("exit", exit_targets.index(name))

    for kind, key in order:
        if kind == "peel":
            br = cfg.blocks[key].term
            pn = peel_node[key]
            pn.on_true = target_of(br.true)
            pn.on_false = target_of(br.false)
        else:
            node = comp_node[key]
            last = cfg.blocks[node.blocks[-1]]
            if isinstance(last.term, Ret):
                if "@ret" not in exit_targets:
                    exit_targets.append("@ret")
                node.succ = ("exit", exit_targets.index("@ret"))
            elif isinstance(last.term, Jmp):
                node.succ = target_of(last.term.target)
            else:
                raise CoxUnsupported(
                    f"warp PR {node.blocks} ends in a branch with instructions — "
                    f"violates the pure-branch invariant")

    entry = target_of(bpr.entry_block)
    assert entry[0] == "node"
    return WarpGraph(nodes, entry[1], exit_targets)


def _chain_order(cfg: CFG, blocks: Set[str], region: Set[str]) -> List[str]:
    """Warp-level PRs are Jmp-chains; order them by walking."""
    entries = [b for b in blocks
               if not any(p in blocks for p in cfg.preds(b))]
    # a single-block self-contained component has itself as entry
    if not entries:
        raise CoxUnsupported(f"warp PR {sorted(blocks)} has no entry (cycle "
                             f"without a barrier?)")
    if len(entries) != 1:
        raise CoxUnsupported(f"warp PR {sorted(blocks)} has multiple entries")
    chain = []
    cur: Optional[str] = entries[0]
    visited = set()
    while cur is not None and cur in blocks and cur not in visited:
        chain.append(cur)
        visited.add(cur)
        t = cfg.blocks[cur].term
        nxt = None
        if isinstance(t, Jmp) and t.target in blocks:
            nxt = t.target
        cur = nxt
    if len(chain) != len(blocks):
        raise CoxUnsupported(
            f"warp PR {sorted(blocks)} is not a chain (got {chain})")
    return chain


# ----------------------------------------------------------------------------
# Variable replication analysis (paper §3.6)
# ----------------------------------------------------------------------------


def _expr_reads(e: Optional[K.Expr], out: Set[str]):
    if e is None:
        return
    stack = [e]
    while stack:
        cur = stack.pop()
        if isinstance(cur, K.Var):
            out.add(cur.name)
        stack.extend(K.expr_children(cur))


def _instr_vars(ins, out: Set[str]):
    if isinstance(ins, K.Assign):
        out.add(ins.name)
        _expr_reads(ins.value, out)
    elif isinstance(ins, (K.StoreGlobal, K.StoreShared)):
        _expr_reads(ins.index, out)
        _expr_reads(ins.value, out)
    elif isinstance(ins, K.AtomicRMW):
        _expr_reads(ins.index, out)
        _expr_reads(ins.value, out)
        if ins.dst:
            out.add(ins.dst)
    elif isinstance(ins, WarpBufStore):
        out.add(ins.buf)
        _expr_reads(ins.value, out)
    elif isinstance(ins, WarpBufCompute):
        out.add(ins.dst)
        out.add(ins.buf)
        for a in ins.args:
            _expr_reads(a, out)
    elif isinstance(ins, K.If):
        _expr_reads(ins.cond, out)
        for s in ins.then_body + ins.else_body:
            _instr_vars(s, out)
    elif isinstance(ins, K.While):
        _expr_reads(ins.cond, out)
        for s in ins.body:
            _instr_vars(s, out)
    elif isinstance(ins, K.Barrier):
        pass


def replication_classes(machine: Machine, uniforms: Set[str]) -> Dict[str, str]:
    """Classify every local: 'block' → replicated (n_warps, W) — lives
    across block-level PRs (the paper's length-block_size arrays);
    'warp' → (W,) — confined to one block-level PR (the paper's
    length-32 arrays).  Kernel scalar params are uniform and excluded."""
    usage: Dict[str, Set[int]] = {}
    block_marked: Set[str] = set()
    for node in machine.nodes:
        if isinstance(node, BlockPeel):
            block_marked.add(node.cond)
            continue
        refs: Set[str] = set()
        for bname in node.blocks:
            for ins in machine.cfg.blocks[bname].instrs:
                _instr_vars(ins, refs)
            t = machine.cfg.blocks[bname].term
            if isinstance(t, Br):
                refs.add(t.cond)
        for v in refs:
            usage.setdefault(v, set()).add(node.id)
    classes: Dict[str, str] = {}
    for v, nodes in usage.items():
        if v in uniforms:
            continue
        if v in block_marked or len(nodes) > 1:
            classes[v] = "block"
        else:
            classes[v] = "warp"
    for v in block_marked:
        classes[v] = "block"
    # warp buffers never cross a barrier (RAW/WAR bracketing) — force warp
    for v in list(classes):
        if v.startswith(".warpbuf_"):
            classes[v] = "warp"
    return classes
