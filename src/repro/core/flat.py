"""Flat collapsing — the prior-art baseline (MCUDA / POCL / DPC semantics,
paper §2.1) and the hybrid dispatcher (paper §5.2.1).

Flat collapsing wraps each (block-level) Parallel Region in a single loop
of length block_size.  It is realized here by running the hierarchical
pipeline with ``warp_size == block_size`` (one "warp" covering the whole
block): the inter-warp loop degenerates to one iteration and every PR is
a single vectorized loop — exactly the flat output shape, Fig. 1(b).

Faithful to the coverage story (Table 1), flat collapsing REJECTS kernels
that use warp-level features: a single block-wide loop cannot represent
warp-scoped barriers (the paper's Code 2 shows why patching them in is
intractable).  ``supports_flat`` is the feature detector; hybrid mode
uses flat when possible (it is ~13% faster on warp-free kernels, Fig. 12)
and hierarchical collapsing otherwise.
"""
from __future__ import annotations

from typing import Optional

from . import kernel_ir as K
from .types import BarrierLevel, CoxUnsupported


class FlatUnsupported(CoxUnsupported):
    """The kernel needs hierarchical collapsing (warp-level features)."""


def flat_rejection_reason(kernel: K.Kernel) -> Optional[str]:
    """Why flat collapsing cannot compile this kernel (None = it can).
    Mirrors the ✗ rows of the paper's Table 1 for POCL-class frameworks."""
    for s in kernel.walk():
        if isinstance(s, K.WarpCall):
            if s.width and s.width != 32:
                return (f"static cooperative-group tile<{s.width}> "
                        f"({s.func}) — sub-warp collective")
            return f"warp-level collective {s.func} (implicit warp barriers)"
        if isinstance(s, K.Barrier) and s.level == BarrierLevel.WARP:
            return "explicit __syncwarp() — warp-scoped barrier"
    return None


def supports_flat(kernel: K.Kernel) -> bool:
    return flat_rejection_reason(kernel) is None


def check_flat(kernel: K.Kernel):
    reason = flat_rejection_reason(kernel)
    if reason is not None:
        raise FlatUnsupported(
            f"flat collapsing cannot express kernel '{kernel.name}': {reason}")


def choose_collapse(kernel: K.Kernel, requested: str = "hybrid") -> str:
    """'hybrid' (default, paper §5.2.1): flat when the kernel has no
    warp-level features, hierarchical otherwise."""
    if requested == "flat":
        check_flat(kernel)
        return "flat"
    if requested == "hier":
        return "hier"
    if requested != "hybrid":
        raise ValueError(f"unknown collapse mode {requested}")
    return "flat" if supports_flat(kernel) else "hier"
