"""Flat collapsing — the prior-art baseline (MCUDA / POCL / DPC semantics,
paper §2.1) and the hybrid dispatcher (paper §5.2.1).

Flat collapsing wraps each (block-level) Parallel Region in a single loop
of length block_size.  It is realized here by running the hierarchical
pipeline with ``warp_size == block_size`` (one "warp" covering the whole
block): the inter-warp loop degenerates to one iteration and every PR is
a single vectorized loop — exactly the flat output shape, Fig. 1(b).

Faithful to the coverage story (Table 1), flat collapsing REJECTS kernels
that use warp-level features: a single block-wide loop cannot represent
warp-scoped barriers (the paper's Code 2 shows why patching them in is
intractable).  ``supports_flat`` is the feature detector; hybrid mode
uses flat when possible (it is ~13% faster on warp-free kernels, Fig. 12)
and hierarchical collapsing otherwise.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import kernel_ir as K
from .types import BarrierLevel, CoxUnsupported


class FlatUnsupported(CoxUnsupported):
    """The kernel needs hierarchical collapsing (warp-level features)."""


def flat_rejection_reason(kernel: K.Kernel) -> Optional[str]:
    """Why flat collapsing cannot compile this kernel (None = it can).
    Mirrors the ✗ rows of the paper's Table 1 for POCL-class frameworks."""
    for s in kernel.walk():
        if isinstance(s, K.WarpCall):
            if s.width and s.width != 32:
                return (f"static cooperative-group tile<{s.width}> "
                        f"({s.func}) — sub-warp collective")
            return f"warp-level collective {s.func} (implicit warp barriers)"
        if isinstance(s, K.Barrier) and s.level == BarrierLevel.WARP:
            return "explicit __syncwarp() — warp-scoped barrier"
    return None


def supports_flat(kernel: K.Kernel) -> bool:
    return flat_rejection_reason(kernel) is None


def check_flat(kernel: K.Kernel):
    reason = flat_rejection_reason(kernel)
    if reason is not None:
        raise FlatUnsupported(
            f"flat collapsing cannot express kernel '{kernel.name}': {reason}")


def choose_collapse(kernel: K.Kernel, requested: str = "hybrid") -> str:
    """'hybrid' (default, paper §5.2.1): flat when the kernel has no
    warp-level features, hierarchical otherwise."""
    if requested == "flat":
        check_flat(kernel)
        return "flat"
    if requested == "hier":
        return "hier"
    if requested != "hybrid":
        raise ValueError(f"unknown collapse mode {requested}")
    return "flat" if supports_flat(kernel) else "hier"


# ---------------------------------------------------------------------------
# Launch-level dispatch: grid-execution backend + execution mode.
# Same shape as choose_collapse: an explicit request is validated and
# honored; 'auto' applies the heuristic.
# ---------------------------------------------------------------------------

_BACKENDS = ("scan", "vmap", "sharded")


def captures_atomic_old(kernel: K.Kernel) -> bool:
    """True when any AtomicRMW captures the pre-op value (the atomicAdd
    ticket pattern).  Such kernels observe atomic *intermediate* state —
    old values are unique only under serial execution, so the
    delta-merge backends (vmap/sharded) cannot reproduce them."""
    return any(isinstance(s, K.AtomicRMW) and s.dst for s in kernel.walk())


def choose_backend(kernel: K.Kernel, *, grid: int, mesh=None,
                   requested: str = "auto") -> str:
    """Pick a grid-execution backend (paper §4's one-pthread-per-block,
    reinterpreted for XLA).

    Heuristic (kernel features + grid size): a mesh forces ``sharded``
    (blocks dealt over devices, psum merge); a multi-block grid takes
    ``vmap`` (chunks of blocks run simultaneously) when the kernel has
    enough per-block internal work for batching to pay — shared-memory
    tiles or atomics (measured on the coverage suite: ~2.9x on tiled
    matmul, ~1x on tree reductions/histograms) — while pure streaming
    SPMD kernels stay on ``scan``, whose loop-carried trace fuses into
    one pass over global memory that block-batching cannot beat; a
    single-block grid always degenerates to ``scan`` (nothing to
    parallelize, and the loop-carried path skips mask tracking).

    Kernels that capture atomic old values (:func:`captures_atomic_old`)
    stay on ``scan``: captured old values are only unique under serial
    execution, and the delta-merge backends would silently hand every
    block the same ticket.  An *explicit* vmap/sharded request for such
    a kernel is rejected at backend build time — as is any launch with
    a mesh (a mesh forces ``sharded``, whose merge cannot reproduce
    ticket semantics; drop the mesh to run these kernels).
    """
    if requested != "auto":
        if requested not in _BACKENDS:
            raise ValueError(f"unknown launch backend {requested!r}; "
                             f"available: {_BACKENDS + ('auto',)}")
        if requested == "sharded" and mesh is None:
            raise ValueError("backend='sharded' needs a mesh")
        if requested != "sharded" and mesh is not None:
            raise ValueError(f"a mesh was given but backend={requested!r}; "
                             "use backend='sharded' (or 'auto')")
        return requested
    if mesh is not None:
        return "sharded"
    if grid <= 1 or captures_atomic_old(kernel):
        return "scan"
    if K.uses_grid_sync(kernel):
        # cooperative launches pin the chunk schedule to one all-resident
        # wave and merge global memory at every phase boundary, so the
        # vmap wave pays grid× copies of globals per phase; measured
        # ~10x against the loop-carried scan on the sweep's gridReduce.
        # Explicit backend='vmap'/mesh requests are still honored.
        return "scan"
    blockwise_work = bool(kernel.shared) or \
        any(isinstance(s, K.AtomicRMW) for s in kernel.walk())
    return "vmap" if blockwise_work else "scan"


def choose_mode(kernel: K.Kernel, *, n_warps: int,
                requested: str = "auto") -> str:
    """Resolve the execution mode ('auto' is the default, end to end
    from ``api.launch``).  'auto' burns the block size in (jit mode:
    inter-warp loop unrolled) only when the block is a single warp —
    there the unrolled form has no loop at all and no bloat; for wider
    blocks the fori-loop 'normal' mode traces smaller programs and the
    paper's Fig-13 JIT advantage does not transfer to XLA."""
    if requested in ("normal", "jit"):
        return requested
    if requested != "auto":
        raise ValueError(f"unknown mode {requested!r}")
    return "jit" if n_warps == 1 else "normal"


# ---------------------------------------------------------------------------
# Warp-execution dispatch: serial inter-warp loop vs batched (n_warps, W)
# lane plane.  Same shape again: explicit requests validated and honored,
# 'auto' applies the heuristic.
# ---------------------------------------------------------------------------

# per-block budget for the batched plane's per-warp shared-memory copies
# (shmem bytes × n_warps): CUDA shared memory tops out around 100 KiB
# per block and n_warps ≤ 32, so real kernels always fit — the budget
# guards synthetic giant-shmem kernels from exploding the vmap footprint
WARP_BATCH_SHMEM_BUDGET = 4 << 20


def shared_footprint(kernel: K.Kernel) -> int:
    """Static shared-memory bytes per block."""
    total = 0
    for s in kernel.shared:
        n = 1
        for d in s.shape:
            n *= int(d)
        total += n * np.dtype(s.dtype.jnp).itemsize
    return total


def choose_warp_exec(kernel: K.Kernel, *, n_warps: int,
                     requested: str = "auto", machine=None) -> str:
    """Resolve how warps run within each block-level PR.

    'batched' exposes the warp axis to XLA: all warps of a PR run as
    one (n_warps, W) lane plane (``jax.vmap`` over the warp-level
    machine walk), multiplying the parallelism the compiler sees —
    grid-chunk × warps × lanes.  'serial' is the paper's Code 3
    inter-warp loop.

    Heuristic ('auto', measured on the coverage suite — BENCH_PR2.json):
    batch when the block has more than one warp, the kernel keeps
    per-block state in **shared memory** (the blockwise-internal-work
    signal, same as ``choose_backend``'s vmap test), the per-warp
    shared-memory copies fit the size budget (``shared_footprint ×
    n_warps ≤ WARP_BATCH_SHMEM_BUDGET``), and — when the caller
    supplies the compiled ``machine`` — the warp graphs are peel-free:
    a batched PC machine must run *every* ``lax.switch`` branch and
    select per warp, which loses to the serial loop's one-branch
    dispatch (0.4x on peel-heavy warp reductions).  The payoff scales
    with how much non-fusable per-warp work a PR holds: ~1.5x on a
    collective-dense shared kernel at 8 warps (2x with the scalar
    collective backend, whose per-lane loop ops the plane divides by
    n_warps), roughly parity on gather-bound tiled matmul.  Pure
    streaming/vote kernels stay serial: their per-PR lane work is too
    small to amortize the per-warp copy + merge.

    Kernels that capture atomic old values (:func:`captures_atomic_old`)
    stay serial — captured old values are only unique under a serial
    warp order, exactly the scan-only argument one level up — and an
    explicit 'batched' request for such a kernel is rejected.
    """
    if requested == "batched":
        if captures_atomic_old(kernel):
            raise CoxUnsupported(
                f"kernel '{kernel.name}' captures atomic old values "
                f"(atomic_add_old): old values are only unique under a "
                f"serial warp order, which warp-batched execution "
                f"cannot reproduce — use warp_exec='serial'")
        return requested
    if requested == "serial":
        return requested
    if requested != "auto":
        raise ValueError(f"unknown warp_exec {requested!r}; "
                         f"expected 'serial', 'batched' or 'auto'")
    if n_warps <= 1 or captures_atomic_old(kernel):
        return "serial"
    if not kernel.shared:
        return "serial"
    if shared_footprint(kernel) * n_warps > WARP_BATCH_SHMEM_BUDGET:
        return "serial"
    if machine is not None:
        from .regions import warp_peel_count
        # a tuple/list means per-phase machines (cooperative grid-sync
        # kernels): any peel-heavy phase keeps the whole launch serial
        machines = (machine if isinstance(machine, (tuple, list))
                    else (machine,))
        if any(warp_peel_count(m) > 0 for m in machines):
            return "serial"
    return "batched"
