"""Warp-level collective implementations.

Two backends, mirroring the paper's Table 2 (warp vote w/ and w/o AVX):

* **vectorized** — lane-axis vector ops on the warp buffer.  On x86
  the paper uses AVX; on TPU these lower to VPU lane shifts/reductions;
  on the CPU validation platform XLA vectorizes them.
* **scalar** — per-lane `lax.fori_loop` emulation (the paper's "w/o AVX"
  baseline: one instruction + branch per lane).

Every collective operates on the **last** axis of the buffer and accepts
arbitrary leading batch axes, so a whole block's collectives can be
evaluated as one ``(n_warps, W)`` lane plane in a single direct call.
(The warp-batched executor itself reaches these functions through
``jax.vmap`` — its buffers are ``(W,)`` batched tracers at trace time,
not explicit 2-D planes — so the explicit leading-axis support exists
for direct/library callers and is what the parity suite in
``tests/test_collectives_property.py`` pins against the per-warp
semantics.)  Tile segmentation (cooperative-group
``thread_block_tile<N>``; the static ``width`` argument) stays per-warp:
segments never cross the lane axis, so the leading axes are untouched.
Width == 0 or W means the full warp.

The ``mask`` argument carries the active-lane mask (threads past
block_size in a partial last warp); it broadcasts against the buffer, so
a shared ``(W,)`` mask serves every warp of a batched plane.  Inactive
lanes contribute the operation's identity, matching CUDA's behaviour
where such lanes simply do not exist.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .types import CoxUnsupported


def _tile(width: int, W: int) -> int:
    w = width or W
    if w > W or (W % w) != 0 or w & (w - 1):
        raise CoxUnsupported(f"tile width {w} invalid for warp size {W}")
    return w


def _seg(buf: jnp.ndarray, w: int):
    """Split the lane axis into (n_segments, w) tiles, keeping any
    leading (warp-plane) axes intact."""
    return buf.reshape(buf.shape[:-1] + (-1, w))


def _unseg(seg: jnp.ndarray, w: int):
    """Broadcast one value per segment back over its w lanes
    (broadcast + reshape — never a gather)."""
    out_shape = seg.shape[:-1] + (seg.shape[-1] * w,)
    return jnp.broadcast_to(seg[..., None],
                            seg.shape + (w,)).reshape(out_shape)


def _gather(buf: jnp.ndarray, src: jnp.ndarray):
    """Per-lane gather along the lane axis.  ``src`` is (W,) or any
    shape broadcastable to ``buf`` (per-warp source lanes under a
    leading warp axis).  The 1-D case keeps the cheap shared-index
    ``take`` form — one index vector for every leading row — instead of
    materializing a fully-batched gather."""
    src = jnp.asarray(src).astype(jnp.int32)
    if src.ndim <= 1:
        return jnp.take(buf, src, axis=-1)
    return jnp.take_along_axis(buf, jnp.broadcast_to(src, buf.shape),
                               axis=-1)


# ---------------------------------------------------------------------------
# vectorized (SIMD) backend
# ---------------------------------------------------------------------------


def shfl_down(buf, off, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    lane = jnp.arange(W, dtype=jnp.int32)
    sub = lane % w
    src = jnp.clip(lane + off, 0, W - 1)
    shifted = _gather(buf, src)
    # CUDA: lanes whose source falls outside the tile keep their own value
    return jnp.where(sub + off < w, shifted, buf)


def shfl_up(buf, off, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    lane = jnp.arange(W, dtype=jnp.int32)
    sub = lane % w
    src = jnp.clip(lane - off, 0, W - 1)
    shifted = _gather(buf, src)
    return jnp.where(sub - off >= 0, shifted, buf)


def shfl_xor(buf, lanemask, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    lane = jnp.arange(W, dtype=jnp.int32)
    src = lane ^ lanemask
    ok = (src % w) == ((lane % w) ^ lanemask)  # stays inside the tile
    src = jnp.clip(src, 0, W - 1)
    return jnp.where(ok, _gather(buf, src), buf)


def shfl_idx(buf, srclane, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    lane = jnp.arange(W, dtype=jnp.int32)
    base = (lane // w) * w
    src = base + (srclane % w).astype(jnp.int32)
    return _gather(buf, jnp.clip(src, 0, W - 1))


def vote_all(buf, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    b = buf.astype(jnp.bool_)
    if mask is not None:
        b = b | ~mask  # inactive lanes vote True (identity of AND)
    seg = _seg(b, w).all(axis=-1)
    return _unseg(seg, w)


def vote_any(buf, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    b = buf.astype(jnp.bool_)
    if mask is not None:
        b = b & mask
    seg = _seg(b, w).any(axis=-1)
    return _unseg(seg, w)


def ballot(buf, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    b = buf.astype(jnp.bool_)
    if mask is not None:
        b = b & mask
    weights = (jnp.uint32(1) << jnp.arange(w, dtype=jnp.uint32))
    seg = (_seg(b, w).astype(jnp.uint32) * weights).sum(
        axis=-1, dtype=jnp.uint32)
    return _unseg(seg, w)


def red_add(buf, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    b = buf
    if mask is not None:
        b = jnp.where(mask, b, jnp.zeros_like(b))
    seg = _seg(b, w).sum(axis=-1)
    return _unseg(seg, w)


def red_max(buf, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    b = buf
    if mask is not None:
        lo = jnp.finfo(b.dtype).min if jnp.issubdtype(b.dtype, jnp.floating) \
            else jnp.iinfo(b.dtype).min
        b = jnp.where(mask, b, jnp.full_like(b, lo))
    seg = _seg(b, w).max(axis=-1)
    return _unseg(seg, w)


def red_min(buf, W: int, width: int = 0, mask=None):
    w = _tile(width, W)
    b = buf
    if mask is not None:
        hi = jnp.finfo(b.dtype).max if jnp.issubdtype(b.dtype, jnp.floating) \
            else jnp.iinfo(b.dtype).max
        b = jnp.where(mask, b, jnp.full_like(b, hi))
    seg = _seg(b, w).min(axis=-1)
    return _unseg(seg, w)


VECTORIZED = {
    "shfl_down": shfl_down, "shfl_up": shfl_up, "shfl_xor": shfl_xor,
    "shfl_idx": shfl_idx, "vote_all": vote_all, "vote_any": vote_any,
    "ballot": ballot, "red_add": red_add, "red_max": red_max,
    "red_min": red_min,
}


# ---------------------------------------------------------------------------
# scalar backend (per-lane loops — the paper's "w/o AVX" rows in Table 2)
# ---------------------------------------------------------------------------


def _lift_lane_axis(fn):
    """Give a 1-D (W,)-only scalar collective the same leading-axis
    contract as the vectorized backend: leading axes are flattened and
    ``jax.vmap``-ed over (the per-lane loop bodies stay scalar, so the
    Table-2 instruction-count story per warp is unchanged).  Extra
    operands that carry the same leading axes (per-warp offset vectors)
    are mapped along with the buffer; scalars and plain (W,) operands
    are shared across warps."""
    @functools.wraps(fn)
    def lifted(buf, *extra, W, width=0, mask=None):
        buf = jnp.asarray(buf)  # fori bodies index with traced lane ids
        if mask is not None:
            mask = jnp.asarray(mask)
        if buf.ndim <= 1:
            return fn(buf, *extra, W=W, width=width, mask=mask)
        lead = buf.shape[:-1]
        n_lead = len(lead)
        ops = [buf.reshape((-1, buf.shape[-1]))]
        axes = [0]
        for e in extra:
            ea = jnp.asarray(e)
            if ea.ndim > 1 and ea.shape[:n_lead] == lead:
                ops.append(ea.reshape((-1,) + ea.shape[n_lead:]))
                axes.append(0)
            else:
                ops.append(ea)
                axes.append(None)
        if mask is not None:
            ops.append(jnp.broadcast_to(mask, buf.shape)
                       .reshape(ops[0].shape))
            axes.append(0)

            def call(b, *rest):
                return fn(b, *rest[:-1], W=W, width=width, mask=rest[-1])
        else:
            def call(b, *rest):
                return fn(b, *rest, W=W, width=width, mask=None)
        out = jax.vmap(call, in_axes=tuple(axes))(*ops)
        return out.reshape(lead + out.shape[1:])
    return lifted


def _scalar_vote(buf, W, width, mask, op, identity):
    w = _tile(width, W)
    n_seg = W // w
    b = buf.astype(jnp.bool_)
    if mask is not None:
        b = (b | ~mask) if op == "all" else (b & mask)

    def per_segment(s, acc):
        def lane_step(i, a):
            v = b[s * w + i]
            return (a & v) if op == "all" else (a | v)
        return lax.fori_loop(0, w, lane_step, jnp.array(identity, jnp.bool_))

    def seg_step(s, out):
        r = per_segment(s, None)
        return lax.dynamic_update_slice(out, jnp.broadcast_to(r, (w,)), (s * w,))

    return lax.fori_loop(0, n_seg, seg_step, jnp.zeros((W,), jnp.bool_))


@_lift_lane_axis
def scalar_vote_all(buf, W, width=0, mask=None):
    return _scalar_vote(buf, W, width, mask, "all", True)


@_lift_lane_axis
def scalar_vote_any(buf, W, width=0, mask=None):
    return _scalar_vote(buf, W, width, mask, "any", False)


@_lift_lane_axis
def scalar_red_add(buf, W, width=0, mask=None):
    w = _tile(width, W)
    n_seg = W // w
    b = buf if mask is None else jnp.where(mask, buf, jnp.zeros_like(buf))

    def seg_step(s, out):
        def lane_step(i, a):
            return a + b[s * w + i]
        r = lax.fori_loop(0, w, lane_step, jnp.zeros((), b.dtype))
        return lax.dynamic_update_slice(out, jnp.broadcast_to(r, (w,)), (s * w,))

    return lax.fori_loop(0, n_seg, seg_step, jnp.zeros((W,), b.dtype))


@_lift_lane_axis
def scalar_shfl_down(buf, off, W, width=0, mask=None):
    w = _tile(width, W)
    off = jnp.asarray(off)

    def lane_step(i, out):
        sub = i % w
        o = off[i] if off.ndim else off  # per-lane or uniform offset
        src = jnp.where(sub + o < w, i + o, i)
        return out.at[i].set(buf[src])

    return lax.fori_loop(0, W, lane_step, jnp.zeros_like(buf))


SCALAR = dict(VECTORIZED)
SCALAR.update({
    "vote_all": scalar_vote_all,
    "vote_any": scalar_vote_any,
    "red_add": scalar_red_add,
    "shfl_down": scalar_shfl_down,
})


def dispatch(func: str, simd: bool):
    table = VECTORIZED if simd else SCALAR
    if func not in table:
        raise CoxUnsupported(f"unknown warp collective {func}")
    return table[func]
