"""COX runtime: grid launch (the paper §4 host side).

The paper forks one pthread per CUDA block.  Here the grid is functional
and the schedule is a pluggable *backend* (``repro.core.backends``):

* ``scan``    — single device, ``lax.scan`` over block indices carrying
  global memory (a legal schedule: CUDA guarantees nothing about
  cross-block ordering between grid-wide syncs);
* ``vmap``    — single device, chunks of blocks run simultaneously via
  ``jax.vmap`` over the block function; per-block copies of global
  memory are reconciled with single-writer write-masks + summed atomic
  deltas (``backends/merge.py``);
* ``sharded`` — blocks dealt round-robin-contiguously over a mesh axis
  with ``shard_map``; within each device the same vmap executor runs,
  and device copies merge with masked ``psum`` stores + ``psum`` of
  atomic deltas (a *stronger* story than the paper, which has none).

``backend="auto"`` (default) applies ``flat.choose_backend``'s
heuristic.  Straggler note for the 1000-node posture: blocks are pure
functions of (bid, inputs), so any chunk can be re-executed anywhere;
``chunk`` slices the grid into re-dispatchable work units.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax.numpy as jnp
from jax.sharding import Mesh

from . import backends as _backends
from . import flat as _flat
from .backends.plan import DEFAULT_CHUNK, LaunchPlan
from .execute import CompiledKernel
from .types import (COOP_MAX_RESIDENT_BLOCKS, CoxUnsupported, Dim3, as_dim3,
                    check_launch_geometry)


@dataclasses.dataclass(frozen=True)
class ResolvedLaunch:
    """Launch knobs after dim3 normalization and 'auto' resolution —
    the single canonical form every caller (``KernelFn.launch``'s cache
    key, :func:`build_launcher`, tests) derives from.  The heuristics
    key on the normalized *totals*, so ``grid=4`` and ``grid=(4,1,1)``
    resolve identically.

    ``chunk``/``chunk_source`` carry the resolved vmap-wave width and
    *where it came from*: ``'explicit'`` (caller passed ``chunk=``, the
    autotuner must never override it), ``'heuristic'`` (defaulted to
    ``min(grid, DEFAULT_CHUNK)``, fair game for measurement),
    ``'cooperative'`` (pinned to ``grid`` by the all-resident grid-sync
    rule), or ``'autotuned'`` (a measured winner).  Before this field
    existed an explicit ``chunk=`` and the default were
    indistinguishable downstream — the autotuner could have silently
    overridden a user knob.

    ``schedule``/``n_resident``/``schedule_source`` mirror that design
    for the *launch schedule*: ``'chunked'`` walks a materialized
    ``(n_chunks, chunk)`` block-id table; ``'grid_stride'`` runs a
    fixed wave of ``n_resident`` block slots that loop over the grid
    with in-graph block ids (``bid = wave × n_resident + slot``), so no
    O(grid) table ever exists — CUDA's grid-stride-loop idiom.  The
    provenance values follow ``chunk_source``: ``'explicit'`` (caller
    passed ``schedule=``), ``'heuristic'`` (the footprint verdict,
    applied once argument shapes are bound), ``'cooperative'`` (a
    multi-phase grid beyond the resident capacity, grid-strided instead
    of rejected), or ``'autotuned'`` (a measured winner)."""
    grid: Dim3
    block: Dim3
    backend: str    # 'scan' | 'vmap' | 'sharded'
    mode: str       # 'normal' | 'jit'
    warp_exec: str  # 'serial' | 'batched'
    n_warps: int
    chunk: Optional[int] = None  # resolved blocks-per-wave (None: plan default)
    chunk_source: str = "heuristic"  # 'explicit'|'heuristic'|'cooperative'|'autotuned'
    schedule: str = "chunked"    # 'chunked' | 'grid_stride'
    n_resident: Optional[int] = None  # grid-stride wave width (None: chunked)
    schedule_source: str = "heuristic"  # same provenance set as chunk_source


def resolve_chunk(ck: CompiledKernel, grid: int, chunk) -> tuple:
    """Resolve the ``chunk`` knob to ``(value, source)`` — the one place
    the explicit-vs-defaulted distinction is decided.  ``chunk`` accepts
    an int (explicit — clamped to the grid but otherwise honored
    verbatim, and never overridden by autotune), ``None``/'auto' (the
    ``min(grid, DEFAULT_CHUNK)`` heuristic, tunable), with cooperative
    launches pinning ``chunk == grid`` exactly as ``LaunchPlan.build``
    enforces."""
    auto = chunk is None or chunk == "auto"
    if ck.n_phases > 1:
        if not auto and int(chunk) < grid:
            raise CoxUnsupported(
                f"cooperative launch of '{ck.kernel.name}': chunk={chunk} "
                f"would split the grid into waves, but a grid barrier "
                f"needs every block resident per phase — drop chunk= "
                f"(the plan schedules all {grid} blocks as one wave)")
        return grid, "cooperative"
    if auto:
        return min(grid, DEFAULT_CHUNK), "heuristic"
    c = int(chunk)
    if c < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk!r}")
    return min(c, grid), "explicit"


def resolve_launch(ck: CompiledKernel, *, grid, block,
                   mode: str = "auto", backend: str = "auto",
                   warp_exec: str = "auto", chunk=None,
                   schedule: str = "auto",
                   n_resident: Optional[int] = None,
                   mesh: Optional[Mesh] = None) -> ResolvedLaunch:
    """Normalize ``grid``/``block`` (``int | (x, y[, z])``) to canonical
    dim3, enforce CUDA's launch limits, and resolve the 'auto' knobs via
    the ``repro.core.flat`` heuristics.  This is the one place launch
    knobs are resolved — dim3 normalization happens exactly once.

    ``schedule`` accepts ``'auto'`` (the footprint verdict picks, once
    argument shapes are known — :func:`resolve_schedule`),
    ``'chunked'``, or ``'grid_stride'``; ``n_resident`` sizes the
    grid-stride wave (``None``: cost-model default) and implies
    ``schedule='grid_stride'``.  Cooperative grids beyond the resident
    capacity lower to a grid-strided phase wave instead of raising —
    the CUDA analogue of occupancy-sizing a cooperative launch — unless
    the caller explicitly pins ``schedule='chunked'``."""
    grid3 = as_dim3(grid, "grid")
    block3 = as_dim3(block, "block")
    check_launch_geometry(grid3, block3)
    if schedule not in ("auto", "chunked", "grid_stride"):
        raise ValueError(
            f"schedule must be 'auto', 'chunked' or 'grid_stride', "
            f"got {schedule!r}")
    if n_resident is not None:
        n_resident = int(n_resident)
        if n_resident < 1:
            raise ValueError(f"n_resident must be >= 1, got {n_resident}")
        if schedule == "chunked":
            raise ValueError(
                "n_resident= only applies to schedule='grid_stride' "
                "(the chunked schedule sizes waves with chunk=)")
        schedule = "grid_stride"  # n_resident implies the strided schedule
    sched = "chunked" if schedule == "auto" else schedule
    sched_src = "heuristic" if schedule == "auto" else "explicit"
    n_res = n_resident
    bname = _flat.choose_backend(ck.kernel, grid=grid3.total, mesh=mesh,
                                 requested=backend)
    n_warps = -(-block3.total // ck.warp_size)
    mode = _flat.choose_mode(ck.kernel, n_warps=n_warps, requested=mode)
    machines = (ck.machine if not ck.phases
                else tuple(p.machine for p in ck.phases))
    warp_exec = _flat.choose_warp_exec(ck.kernel, n_warps=n_warps,
                                       requested=warp_exec,
                                       machine=machines)
    ch, ch_src = resolve_chunk(ck, grid3.total, chunk)
    if ck.n_phases > 1:
        # CUDA's cooperative-launch constraint (cudaLaunchCooperativeKernel
        # rejects grids beyond SMs × maxBlocksPerSM): a grid barrier needs
        # every block resident per phase.  Beyond the capacity we lower to
        # a grid-strided phase wave — COOP_MAX_RESIDENT_BLOCKS slots loop
        # over the grid within each phase, every wave of phase p completing
        # before phase p+1 starts, so the barrier guarantee holds with
        # per-block carried state paged through the resident wave.
        if grid3.total > COOP_MAX_RESIDENT_BLOCKS:
            if schedule == "chunked":
                raise CoxUnsupported(
                    f"cooperative launch of '{ck.kernel.name}': grid="
                    f"{grid3.total} blocks exceeds the resident capacity "
                    f"({COOP_MAX_RESIDENT_BLOCKS}) and schedule='chunked' "
                    f"pins the all-resident wave — drop schedule= to let "
                    f"the grid-stride lowering page blocks through "
                    f"{COOP_MAX_RESIDENT_BLOCKS} resident slots")
            sched = "grid_stride"
            if sched_src != "explicit":
                sched_src = "cooperative"
            n_res = min(n_res or COOP_MAX_RESIDENT_BLOCKS,
                        COOP_MAX_RESIDENT_BLOCKS)
            ch, ch_src = n_res, "cooperative"
        elif sched == "grid_stride":
            n_res = min(n_res or grid3.total, grid3.total,
                        COOP_MAX_RESIDENT_BLOCKS)
            ch, ch_src = n_res, "cooperative"
    elif sched == "grid_stride" and n_res is not None:
        n_res = min(n_res, grid3.total)
    return ResolvedLaunch(grid3, block3, bname, mode, warp_exec, n_warps,
                          ch, ch_src, sched, n_res, sched_src)


def resolve_schedule(ck: CompiledKernel, rl: ResolvedLaunch,
                     shapes: Dict[str, tuple], *,
                     budget: Optional[int] = None) -> ResolvedLaunch:
    """Apply the footprint verdict to an otherwise-resolved launch.
    Needs the *bound* argument shapes (the footprint model keys on
    global-memory bytes), so it runs after ``plan.bind_args`` /
    ``bind_kernel_args`` rather than inside :func:`resolve_launch`.

    Explicit schedules are honored verbatim (an explicit
    ``'grid_stride'`` without ``n_resident=`` gets the cost-model wave
    width filled in), and so is an explicit ``chunk=`` — the caller
    asked for that exact wave geometry, so the verdict never swaps the
    schedule underneath it; cooperative lowering decided in
    :func:`resolve_launch` is kept; everything else asks
    ``costmodel.schedule_verdict`` whether the chunk-table schedule
    fits ``FOOTPRINT_BUDGET`` and routes to grid-stride when it does
    not."""
    from . import costmodel as _costmodel
    if rl.schedule == "grid_stride":
        if rl.n_resident is None:
            n_res = _costmodel.resident_slots(
                ck, shapes, grid=rl.grid.total, n_warps=rl.n_warps,
                warp_exec=rl.warp_exec, budget=budget)
            return dataclasses.replace(
                rl, n_resident=min(n_res, rl.grid.total))
        return rl
    if (rl.schedule_source == "explicit"
            or rl.chunk_source == "explicit" or ck.n_phases > 1):
        return rl
    sched, n_res = _costmodel.schedule_verdict(
        ck, shapes, grid=rl.grid.total,
        chunk=rl.chunk if rl.chunk else DEFAULT_CHUNK,
        n_warps=rl.n_warps, warp_exec=rl.warp_exec,
        backend=rl.backend, budget=budget)
    if sched == "grid_stride":
        return dataclasses.replace(rl, schedule="grid_stride",
                                   n_resident=n_res,
                                   schedule_source="heuristic")
    return rl


def build_traceable(ck: CompiledKernel, rl: ResolvedLaunch, *,
                    simd: bool = True, mesh: Optional[Mesh] = None,
                    axis: str = "data", chunk: Optional[int] = None):
    """Build the plan and the *raw* (un-jitted) launcher for an
    already-resolved launch.  Returns ``(plan, fn)`` with
    ``fn(globals_, scalars) -> {name: flat array}`` traceable inside a
    larger jitted program — the form ``repro.core.graphs`` inlines when
    staging a captured launch DAG as one fused executable.

    ``chunk=`` overrides the resolved ``rl.chunk`` when given (legacy
    call shape; the resolved field is the canonical source)."""
    plan = LaunchPlan.build(ck, grid=rl.grid, block=rl.block, mode=rl.mode,
                            simd=simd,
                            chunk=chunk if chunk is not None else rl.chunk,
                            warp_exec=rl.warp_exec, schedule=rl.schedule,
                            n_resident=rl.n_resident)
    fn = _backends.get_backend(rl.backend).build_fn(plan, mesh=mesh,
                                                    axis=axis)
    return plan, fn


def build_resolved(ck: CompiledKernel, rl: ResolvedLaunch, *,
                   simd: bool = True, mesh: Optional[Mesh] = None,
                   axis: str = "data", chunk: Optional[int] = None,
                   donate: bool = False):
    """Build the plan and stage the jitted executable for an
    already-resolved launch.  Returns ``(plan, exe)`` with
    ``exe(globals_, scalars) -> {name: flat array}``.

    ``donate=True`` stages the executable with its global-memory inputs
    donated (``jax.jit(..., donate_argnums=...)``): XLA reuses the input
    buffers for the outputs instead of copying, so an in-order stream
    re-launching over the same globals stops paying the copy.  The
    caller must treat the passed arrays as *consumed* — JAX deletes
    donated buffers, and re-using one raises."""
    plan = LaunchPlan.build(ck, grid=rl.grid, block=rl.block, mode=rl.mode,
                            simd=simd,
                            chunk=chunk if chunk is not None else rl.chunk,
                            warp_exec=rl.warp_exec, schedule=rl.schedule,
                            n_resident=rl.n_resident)
    exe = _backends.get_backend(rl.backend).build(plan, mesh=mesh, axis=axis,
                                                  donate=donate)
    return plan, exe


def build_launcher(ck: CompiledKernel, *, grid, block,
                   mode: str = "auto", simd: bool = True,
                   mesh: Optional[Mesh] = None, axis: str = "data",
                   backend: str = "auto", chunk: Optional[int] = None,
                   warp_exec: str = "auto", schedule: str = "auto",
                   n_resident: Optional[int] = None, donate: bool = False):
    """:func:`resolve_launch` + :func:`build_resolved` in one call.

    No argument shapes here, so ``schedule='auto'`` stays chunked (the
    footprint verdict can't run); :func:`launch` and the stream layer
    bind args first and get the full :func:`resolve_schedule` pass."""
    rl = resolve_launch(ck, grid=grid, block=block, mode=mode,
                        backend=backend, warp_exec=warp_exec, chunk=chunk,
                        schedule=schedule, n_resident=n_resident, mesh=mesh)
    if rl.schedule == "grid_stride" and rl.n_resident is None:
        rl = resolve_schedule(ck, rl, {})  # cost-model default wave width
    return build_resolved(ck, rl, simd=simd, mesh=mesh, axis=axis,
                          donate=donate)


def launch(ck: CompiledKernel, *, grid, block, args: Sequence[Any],
           mode: str = "auto", simd: bool = True,
           mesh: Optional[Mesh] = None, axis: str = "data",
           backend: str = "auto", chunk: Optional[int] = None,
           warp_exec: str = "auto", schedule: str = "auto",
           n_resident: Optional[int] = None,
           donate: bool = False) -> Dict[str, jnp.ndarray]:
    """Run ``kernel<<<grid, block>>>(*args)``; returns {array name: value}.
    ``grid`` and ``block`` accept ``int | (x, y[, z])`` dim3 geometry.

    mode='auto' (default) resolves to loop-carried 'normal' execution
    for multi-warp blocks — on XLA the trace is already
    shape-specialized, so the paper's JIT mode (grid/block burned in,
    loops unrolled) only bloats the program; the Fig-13 advantage does
    NOT transfer (EXPERIMENTS.md §Benchmarks) — and to 'jit' for
    single-warp blocks, where unrolling is free.  mode='jit'/'normal'
    remain available for the comparison.

    warp_exec='auto' (default) batches the inter-warp loop into one
    (n_warps, W) lane plane whenever the block has more than one warp
    and the per-warp shared-memory copies fit the budget
    (``flat.choose_warp_exec``); 'serial'/'batched' force either path.

    ``donate=True`` donates the flat global-memory buffers to the
    executable (buffer reuse instead of copy-on-write); the bound
    arrays are consumed — note that for already-1-D inputs the flat
    binding aliases the caller's array, which JAX then deletes.
    Donation is unsupported on the ``sharded`` backend
    (``CoxUnsupported``): its replicated cross-device buffers cannot
    alias a single donated input.

    This is the uncached entry point; ``KernelFn.launch`` adds a
    launch-level compile cache (now owned by the stream dispatcher,
    ``repro.core.streams``) so repeat launches skip retracing.
    """
    from .backends.plan import bind_kernel_args
    rl = resolve_launch(ck, grid=grid, block=block, mode=mode,
                        backend=backend, warp_exec=warp_exec, chunk=chunk,
                        schedule=schedule, n_resident=n_resident, mesh=mesh)
    globals_, shapes, scalars = bind_kernel_args(ck, args)
    rl = resolve_schedule(ck, rl, shapes)
    _, exe = build_resolved(ck, rl, simd=simd, mesh=mesh, axis=axis,
                            donate=donate)
    out = exe(globals_, scalars)
    return {k: v.reshape(shapes[k]) for k, v in out.items()}
