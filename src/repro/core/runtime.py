"""COX runtime: grid launch (the paper §4 host side).

The paper forks one pthread per CUDA block.  Here the grid is functional:

* single device — ``lax.scan`` over block indices, carrying global
  memory (a legal schedule: CUDA guarantees nothing about cross-block
  ordering between grid-wide syncs);
* multi device — blocks are sharded round-robin-contiguously over a mesh
  axis with ``shard_map``; each device runs its blocks on its own copy of
  global memory and the copies are merged with write-masks (plain
  stores; disjoint by the CUDA race-freedom contract) and ``psum`` of
  deltas (atomics — a *stronger* story than the paper, which has none).

Straggler note for the 1000-node posture: blocks are pure functions of
(bid, inputs), so any chunk can be re-executed anywhere; the launcher
exposes ``chunk`` to slice the grid into re-dispatchable work units.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from . import kernel_ir as K
from .execute import CompiledKernel, make_block_fn
from .types import ArraySpec, CoxUnsupported, ScalarSpec


def _bind_args(ck: CompiledKernel, args: Sequence[Any]):
    """Split positional args into (globals dict, scalar uniforms dict);
    arrays are flattened (CUDA pointer semantics) and shapes remembered."""
    if len(args) != len(ck.kernel.params):
        raise TypeError(f"kernel {ck.kernel.name} takes "
                        f"{len(ck.kernel.params)} args, got {len(args)}")
    globals_: Dict[str, Any] = {}
    shapes: Dict[str, tuple] = {}
    scalars: Dict[str, Any] = {}
    for spec, val in zip(ck.kernel.params, args):
        if isinstance(spec, ArraySpec):
            arr = jnp.asarray(val, spec.dtype.jnp)
            shapes[spec.name] = arr.shape
            globals_[spec.name] = arr.reshape(-1)
        else:
            scalars[spec.name] = jnp.asarray(val, spec.dtype.jnp)
    return globals_, shapes, scalars


def launch(ck: CompiledKernel, *, grid: int, block: int, args: Sequence[Any],
           mode: str = "normal", simd: bool = True,
           mesh: Optional[Mesh] = None, axis: str = "data",
           donate: bool = False) -> Dict[str, jnp.ndarray]:
    """Run ``kernel<<<grid, block>>>(*args)``; returns {array name: value}.

    mode='normal' (default) uses loop-carried execution — on XLA the
    trace is already shape-specialized, so the paper's JIT mode (grid/
    block burned in, loops unrolled) only bloats the program; the Fig-13
    advantage does NOT transfer (EXPERIMENTS.md §Benchmarks).  mode='jit'
    remains available for the comparison."""
    if block <= 0 or grid <= 0:
        raise ValueError("grid and block must be positive")
    if block > 1024:
        raise CoxUnsupported("CUDA blocks are limited to 1024 threads")
    W = ck.warp_size
    n_warps = -(-block // W)
    globals_, shapes, scalars = _bind_args(ck, args)

    if mesh is None:
        out = _launch_single(ck, grid, block, n_warps, scalars, globals_,
                             mode, simd)
    else:
        out = _launch_sharded(ck, grid, block, n_warps, scalars, globals_,
                              mode, simd, mesh, axis)
    return {k: v.reshape(shapes[k]) for k, v in out.items()}


# ---------------------------------------------------------------------------


def _launch_single(ck, grid, block, n_warps, scalars, globals_, mode, simd):
    block_fn = make_block_fn(ck, n_warps=n_warps, mode=mode, simd=simd)

    def uniforms_for(bid):
        u = {"bid": bid, "bdim": jnp.int32(block), "gdim": jnp.int32(grid)}
        u.update(scalars)
        return u

    def step(g, bid):
        g2, _, _ = block_fn(uniforms_for(bid), g)
        return g2, None

    def run(g):
        g, _ = lax.scan(step, g, jnp.arange(grid, dtype=jnp.int32))
        return g

    return jax.jit(run)(globals_)


def _launch_sharded(ck, grid, block, n_warps, scalars, globals_, mode, simd,
                    mesh, axis):
    ndev = mesh.shape[axis]
    per = -(-grid // ndev)  # blocks per device (last device may idle-pad)
    block_fn = make_block_fn(ck, n_warps=n_warps, mode=mode, simd=simd,
                             multi_device=True)
    has_atomics = any(isinstance(s, K.AtomicRMW) for s in _walk_instrs(ck))

    def device_fn(dev_bids, g0):
        # local view of the sharded (ndev, per) id table is (1, per):
        # flatten to this device's (per,) block ids (−1 = padding)
        dev_bids = dev_bids.reshape(-1)
        masks = {k: jnp.zeros(v.shape, jnp.bool_) for k, v in g0.items()}
        deltas = ({k: jnp.zeros_like(v) for k, v in g0.items()}
                  if has_atomics else {})

        def step(carry, bid):
            g, m, d = carry
            u = {"bid": bid, "bdim": jnp.int32(block),
                 "gdim": jnp.int32(grid)}
            u.update(scalars)
            g2, m2, d2 = block_fn(u, g, m, d)
            skip = bid < 0
            g = jax.tree_util.tree_map(
                lambda a, b: jnp.where(skip, a, b), g, g2)
            m = jax.tree_util.tree_map(
                lambda a, b: jnp.where(skip, a, b), m, m2)
            d = jax.tree_util.tree_map(
                lambda a, b: jnp.where(skip, a, b), d, d2)
            return (g, m, d), None

        (g, m, d), _ = lax.scan(step, (g0, masks, deltas), dev_bids)

        # merge across devices: single-writer stores + summed atomics
        merged = {}
        for k in g0:
            stored = lax.psum(jnp.where(m[k], _num(g[k]), 0), axis)
            cnt = lax.psum(m[k].astype(jnp.int32), axis)
            val = jnp.where(cnt > 0, stored.astype(_num(g[k]).dtype), _num(g0[k]))
            if has_atomics and k in d:
                val = val + lax.psum(_num(d[k]), axis)
            merged[k] = _denum(val, g0[k].dtype)
        return merged

    bids = np.full((ndev * per,), -1, np.int32)
    bids[:grid] = np.arange(grid, dtype=np.int32)
    bids = jnp.asarray(bids.reshape(ndev, per))

    in_specs = (P(axis), P())     # bids sharded; globals replicated
    out_specs = P()

    fn = jax.shard_map(device_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)(bids, globals_)


def _num(x):
    return x.astype(jnp.int32) if x.dtype == jnp.bool_ else x


def _denum(x, dt):
    return (x != 0) if dt == jnp.bool_ else x.astype(dt)


def _walk_instrs(ck: CompiledKernel):
    for blk in ck.cfg.blocks.values():
        stack = list(blk.instrs)
        while stack:
            s = stack.pop()
            yield s
            if isinstance(s, K.If):
                stack.extend(s.then_body)
                stack.extend(s.else_body)
            elif isinstance(s, K.While):
                stack.extend(s.body)
