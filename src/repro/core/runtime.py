"""COX runtime: grid launch (the paper §4 host side).

The paper forks one pthread per CUDA block.  Here the grid is functional
and the schedule is a pluggable *backend* (``repro.core.backends``):

* ``scan``    — single device, ``lax.scan`` over block indices carrying
  global memory (a legal schedule: CUDA guarantees nothing about
  cross-block ordering between grid-wide syncs);
* ``vmap``    — single device, chunks of blocks run simultaneously via
  ``jax.vmap`` over the block function; per-block copies of global
  memory are reconciled with single-writer write-masks + summed atomic
  deltas (``backends/merge.py``);
* ``sharded`` — blocks dealt round-robin-contiguously over a mesh axis
  with ``shard_map``; within each device the same vmap executor runs,
  and device copies merge with masked ``psum`` stores + ``psum`` of
  atomic deltas (a *stronger* story than the paper, which has none).

``backend="auto"`` (default) applies ``flat.choose_backend``'s
heuristic.  Straggler note for the 1000-node posture: blocks are pure
functions of (bid, inputs), so any chunk can be re-executed anywhere;
``chunk`` slices the grid into re-dispatchable work units.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax.numpy as jnp
from jax.sharding import Mesh

from . import backends as _backends
from . import flat as _flat
from .backends.plan import LaunchPlan
from .execute import CompiledKernel


def build_launcher(ck: CompiledKernel, *, grid: int, block: int,
                   mode: str = "auto", simd: bool = True,
                   mesh: Optional[Mesh] = None, axis: str = "data",
                   backend: str = "auto", chunk: Optional[int] = None,
                   warp_exec: str = "auto"):
    """Resolve (backend, mode, warp_exec), build the plan, and stage the
    jitted executable.  Returns ``(plan, exe)`` with
    ``exe(globals_, scalars) -> {name: flat array}``."""
    bname = _flat.choose_backend(ck.kernel, grid=grid, mesh=mesh,
                                 requested=backend)
    n_warps = -(-block // ck.warp_size)
    mode = _flat.choose_mode(ck.kernel, n_warps=n_warps, requested=mode)
    warp_exec = _flat.choose_warp_exec(ck.kernel, n_warps=n_warps,
                                       requested=warp_exec,
                                       machine=ck.machine)
    plan = LaunchPlan.build(ck, grid=grid, block=block, mode=mode,
                            simd=simd, chunk=chunk, warp_exec=warp_exec)
    exe = _backends.get_backend(bname).build(plan, mesh=mesh, axis=axis)
    return plan, exe


def launch(ck: CompiledKernel, *, grid: int, block: int, args: Sequence[Any],
           mode: str = "auto", simd: bool = True,
           mesh: Optional[Mesh] = None, axis: str = "data",
           backend: str = "auto", chunk: Optional[int] = None,
           warp_exec: str = "auto",
           donate: bool = False) -> Dict[str, jnp.ndarray]:
    """Run ``kernel<<<grid, block>>>(*args)``; returns {array name: value}.

    mode='auto' (default) resolves to loop-carried 'normal' execution
    for multi-warp blocks — on XLA the trace is already
    shape-specialized, so the paper's JIT mode (grid/block burned in,
    loops unrolled) only bloats the program; the Fig-13 advantage does
    NOT transfer (EXPERIMENTS.md §Benchmarks) — and to 'jit' for
    single-warp blocks, where unrolling is free.  mode='jit'/'normal'
    remain available for the comparison.

    warp_exec='auto' (default) batches the inter-warp loop into one
    (n_warps, W) lane plane whenever the block has more than one warp
    and the per-warp shared-memory copies fit the budget
    (``flat.choose_warp_exec``); 'serial'/'batched' force either path.

    This is the uncached entry point; ``KernelFn.launch`` adds a
    launch-level compile cache so repeat launches skip retracing.
    """
    plan, exe = build_launcher(ck, grid=grid, block=block, mode=mode,
                               simd=simd, mesh=mesh, axis=axis,
                               backend=backend, chunk=chunk,
                               warp_exec=warp_exec)
    globals_, shapes, scalars = plan.bind_args(args)
    out = exe(globals_, scalars)
    return {k: v.reshape(shapes[k]) for k, v in out.items()}
