"""COX runtime: grid launch (the paper §4 host side).

The paper forks one pthread per CUDA block.  Here the grid is functional
and the schedule is a pluggable *backend* (``repro.core.backends``):

* ``scan``    — single device, ``lax.scan`` over block indices carrying
  global memory (a legal schedule: CUDA guarantees nothing about
  cross-block ordering between grid-wide syncs);
* ``vmap``    — single device, chunks of blocks run simultaneously via
  ``jax.vmap`` over the block function; per-block copies of global
  memory are reconciled with single-writer write-masks + summed atomic
  deltas (``backends/merge.py``);
* ``sharded`` — blocks dealt round-robin-contiguously over a mesh axis
  with ``shard_map``; within each device the same vmap executor runs,
  and device copies merge with masked ``psum`` stores + ``psum`` of
  atomic deltas (a *stronger* story than the paper, which has none).

``backend="auto"`` (default) applies ``flat.choose_backend``'s
heuristic.  Straggler note for the 1000-node posture: blocks are pure
functions of (bid, inputs), so any chunk can be re-executed anywhere;
``chunk`` slices the grid into re-dispatchable work units.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax.numpy as jnp
from jax.sharding import Mesh

from . import backends as _backends
from . import flat as _flat
from .backends.plan import DEFAULT_CHUNK, LaunchPlan
from .execute import CompiledKernel
from .types import (COOP_MAX_RESIDENT_BLOCKS, CoxUnsupported, Dim3, as_dim3,
                    check_launch_geometry)


@dataclasses.dataclass(frozen=True)
class ResolvedLaunch:
    """Launch knobs after dim3 normalization and 'auto' resolution —
    the single canonical form every caller (``KernelFn.launch``'s cache
    key, :func:`build_launcher`, tests) derives from.  The heuristics
    key on the normalized *totals*, so ``grid=4`` and ``grid=(4,1,1)``
    resolve identically.

    ``chunk``/``chunk_source`` carry the resolved vmap-wave width and
    *where it came from*: ``'explicit'`` (caller passed ``chunk=``, the
    autotuner must never override it), ``'heuristic'`` (defaulted to
    ``min(grid, DEFAULT_CHUNK)``, fair game for measurement),
    ``'cooperative'`` (pinned to ``grid`` by the all-resident grid-sync
    rule), or ``'autotuned'`` (a measured winner).  Before this field
    existed an explicit ``chunk=`` and the default were
    indistinguishable downstream — the autotuner could have silently
    overridden a user knob."""
    grid: Dim3
    block: Dim3
    backend: str    # 'scan' | 'vmap' | 'sharded'
    mode: str       # 'normal' | 'jit'
    warp_exec: str  # 'serial' | 'batched'
    n_warps: int
    chunk: Optional[int] = None  # resolved blocks-per-wave (None: plan default)
    chunk_source: str = "heuristic"  # 'explicit'|'heuristic'|'cooperative'|'autotuned'


def resolve_chunk(ck: CompiledKernel, grid: int, chunk) -> tuple:
    """Resolve the ``chunk`` knob to ``(value, source)`` — the one place
    the explicit-vs-defaulted distinction is decided.  ``chunk`` accepts
    an int (explicit — clamped to the grid but otherwise honored
    verbatim, and never overridden by autotune), ``None``/'auto' (the
    ``min(grid, DEFAULT_CHUNK)`` heuristic, tunable), with cooperative
    launches pinning ``chunk == grid`` exactly as ``LaunchPlan.build``
    enforces."""
    auto = chunk is None or chunk == "auto"
    if ck.n_phases > 1:
        if not auto and int(chunk) < grid:
            raise CoxUnsupported(
                f"cooperative launch of '{ck.kernel.name}': chunk={chunk} "
                f"would split the grid into waves, but a grid barrier "
                f"needs every block resident per phase — drop chunk= "
                f"(the plan schedules all {grid} blocks as one wave)")
        return grid, "cooperative"
    if auto:
        return min(grid, DEFAULT_CHUNK), "heuristic"
    c = int(chunk)
    if c < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk!r}")
    return min(c, grid), "explicit"


def resolve_launch(ck: CompiledKernel, *, grid, block,
                   mode: str = "auto", backend: str = "auto",
                   warp_exec: str = "auto", chunk=None,
                   mesh: Optional[Mesh] = None) -> ResolvedLaunch:
    """Normalize ``grid``/``block`` (``int | (x, y[, z])``) to canonical
    dim3, enforce CUDA's launch limits, and resolve the 'auto' knobs via
    the ``repro.core.flat`` heuristics.  This is the one place launch
    knobs are resolved — dim3 normalization happens exactly once."""
    grid3 = as_dim3(grid, "grid")
    block3 = as_dim3(block, "block")
    check_launch_geometry(grid3, block3)
    if ck.n_phases > 1 and grid3.total > COOP_MAX_RESIDENT_BLOCKS:
        # CUDA's cooperative-launch constraint (cudaLaunchCooperativeKernel
        # rejects grids beyond SMs × maxBlocksPerSM): a grid barrier needs
        # every block resident per phase — here, every block's carried
        # state (locals + shared memory) live across the phase sequence.
        raise CoxUnsupported(
            f"cooperative launch of '{ck.kernel.name}': grid="
            f"{grid3.total} blocks exceeds the resident capacity "
            f"({COOP_MAX_RESIDENT_BLOCKS}) — grid_sync requires every "
            f"block resident per phase; shrink the grid (grid-stride "
            f"the work) as on CUDA")
    bname = _flat.choose_backend(ck.kernel, grid=grid3.total, mesh=mesh,
                                 requested=backend)
    n_warps = -(-block3.total // ck.warp_size)
    mode = _flat.choose_mode(ck.kernel, n_warps=n_warps, requested=mode)
    machines = (ck.machine if not ck.phases
                else tuple(p.machine for p in ck.phases))
    warp_exec = _flat.choose_warp_exec(ck.kernel, n_warps=n_warps,
                                       requested=warp_exec,
                                       machine=machines)
    ch, ch_src = resolve_chunk(ck, grid3.total, chunk)
    return ResolvedLaunch(grid3, block3, bname, mode, warp_exec, n_warps,
                          ch, ch_src)


def build_traceable(ck: CompiledKernel, rl: ResolvedLaunch, *,
                    simd: bool = True, mesh: Optional[Mesh] = None,
                    axis: str = "data", chunk: Optional[int] = None):
    """Build the plan and the *raw* (un-jitted) launcher for an
    already-resolved launch.  Returns ``(plan, fn)`` with
    ``fn(globals_, scalars) -> {name: flat array}`` traceable inside a
    larger jitted program — the form ``repro.core.graphs`` inlines when
    staging a captured launch DAG as one fused executable.

    ``chunk=`` overrides the resolved ``rl.chunk`` when given (legacy
    call shape; the resolved field is the canonical source)."""
    plan = LaunchPlan.build(ck, grid=rl.grid, block=rl.block, mode=rl.mode,
                            simd=simd,
                            chunk=chunk if chunk is not None else rl.chunk,
                            warp_exec=rl.warp_exec)
    fn = _backends.get_backend(rl.backend).build_fn(plan, mesh=mesh,
                                                    axis=axis)
    return plan, fn


def build_resolved(ck: CompiledKernel, rl: ResolvedLaunch, *,
                   simd: bool = True, mesh: Optional[Mesh] = None,
                   axis: str = "data", chunk: Optional[int] = None,
                   donate: bool = False):
    """Build the plan and stage the jitted executable for an
    already-resolved launch.  Returns ``(plan, exe)`` with
    ``exe(globals_, scalars) -> {name: flat array}``.

    ``donate=True`` stages the executable with its global-memory inputs
    donated (``jax.jit(..., donate_argnums=...)``): XLA reuses the input
    buffers for the outputs instead of copying, so an in-order stream
    re-launching over the same globals stops paying the copy.  The
    caller must treat the passed arrays as *consumed* — JAX deletes
    donated buffers, and re-using one raises."""
    plan = LaunchPlan.build(ck, grid=rl.grid, block=rl.block, mode=rl.mode,
                            simd=simd,
                            chunk=chunk if chunk is not None else rl.chunk,
                            warp_exec=rl.warp_exec)
    exe = _backends.get_backend(rl.backend).build(plan, mesh=mesh, axis=axis,
                                                  donate=donate)
    return plan, exe


def build_launcher(ck: CompiledKernel, *, grid, block,
                   mode: str = "auto", simd: bool = True,
                   mesh: Optional[Mesh] = None, axis: str = "data",
                   backend: str = "auto", chunk: Optional[int] = None,
                   warp_exec: str = "auto", donate: bool = False):
    """:func:`resolve_launch` + :func:`build_resolved` in one call."""
    rl = resolve_launch(ck, grid=grid, block=block, mode=mode,
                        backend=backend, warp_exec=warp_exec, chunk=chunk,
                        mesh=mesh)
    return build_resolved(ck, rl, simd=simd, mesh=mesh, axis=axis,
                          donate=donate)


def launch(ck: CompiledKernel, *, grid, block, args: Sequence[Any],
           mode: str = "auto", simd: bool = True,
           mesh: Optional[Mesh] = None, axis: str = "data",
           backend: str = "auto", chunk: Optional[int] = None,
           warp_exec: str = "auto",
           donate: bool = False) -> Dict[str, jnp.ndarray]:
    """Run ``kernel<<<grid, block>>>(*args)``; returns {array name: value}.
    ``grid`` and ``block`` accept ``int | (x, y[, z])`` dim3 geometry.

    mode='auto' (default) resolves to loop-carried 'normal' execution
    for multi-warp blocks — on XLA the trace is already
    shape-specialized, so the paper's JIT mode (grid/block burned in,
    loops unrolled) only bloats the program; the Fig-13 advantage does
    NOT transfer (EXPERIMENTS.md §Benchmarks) — and to 'jit' for
    single-warp blocks, where unrolling is free.  mode='jit'/'normal'
    remain available for the comparison.

    warp_exec='auto' (default) batches the inter-warp loop into one
    (n_warps, W) lane plane whenever the block has more than one warp
    and the per-warp shared-memory copies fit the budget
    (``flat.choose_warp_exec``); 'serial'/'batched' force either path.

    ``donate=True`` donates the flat global-memory buffers to the
    executable (buffer reuse instead of copy-on-write); the bound
    arrays are consumed — note that for already-1-D inputs the flat
    binding aliases the caller's array, which JAX then deletes.
    Donation is unsupported on the ``sharded`` backend
    (``CoxUnsupported``): its replicated cross-device buffers cannot
    alias a single donated input.

    This is the uncached entry point; ``KernelFn.launch`` adds a
    launch-level compile cache (now owned by the stream dispatcher,
    ``repro.core.streams``) so repeat launches skip retracing.
    """
    plan, exe = build_launcher(ck, grid=grid, block=block, mode=mode,
                               simd=simd, mesh=mesh, axis=axis,
                               backend=backend, chunk=chunk,
                               warp_exec=warp_exec, donate=donate)
    globals_, shapes, scalars = plan.bind_args(args)
    out = exe(globals_, scalars)
    return {k: v.reshape(shapes[k]) for k, v in out.items()}
