"""JAX version compatibility shims.

``shard_map`` graduated from ``jax.experimental.shard_map`` to
``jax.shard_map`` (and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``) across JAX releases.  Every call site in
this repo goes through :func:`shard_map` below so a single import works
on both sides of the move.
"""
from __future__ import annotations

import inspect

import jax

try:
    _shard_map_impl = jax.shard_map          # jax >= 0.6
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """Version-portable ``shard_map``.

    ``check_vma`` maps onto whichever replication-check kwarg the
    installed JAX understands (``check_vma`` new, ``check_rep`` old);
    ``None`` leaves the library default in place.
    """
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)
