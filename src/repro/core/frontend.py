"""Python-AST frontend: restricted-Python CUDA-style kernels → kernel IR.

Plays the role of Clang/NVVM in the paper's pipeline (Fig. 3).  Kernels
are written as Python functions whose first parameter is the COX context
(thread intrinsics); remaining parameters are annotated global arrays or
scalars:

    @cox.kernel
    def reduce(c, out: cox.Array(cox.f32), val: cox.Array(cox.f32)):
        tid = c.thread_idx()
        v = val[tid]
        if tid < 32:
            offset = 16
            while offset > 0:
                v += c.shfl_down(v, offset)
                offset //= 2
        if tid == 0:
            out[0] = v

Canonicalization guarantees (the paper leans on LLVM loop-simplify /
lowerswitch — §3.3.3): every loop this frontend emits has a single latch
and a loop-header condition; every branch is two-way.
"""
from __future__ import annotations

import ast
import dataclasses
import inspect
import textwrap
from typing import Any, Dict, List, Optional

from . import kernel_ir as K
from .types import (ArraySpec, BarrierLevel, CoxUnsupported, DType,
                    ScalarSpec, SharedSpec)


# ----------------------------------------------------------------------------
# Parameter annotations (public, re-exported from api)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Array:
    """Annotation for a global-memory pointer parameter."""
    dtype: DType = DType.f32


# Scalar annotations are DType members themselves (cox.i32, cox.f32, ...).


_WARP_FUNCS = {
    "shfl_down": "shfl_down",
    "shfl_up": "shfl_up",
    "shfl_xor": "shfl_xor",
    "shfl": "shfl_idx",
    "vote_all": "vote_all",
    "all_sync": "vote_all",
    "vote_any": "vote_any",
    "any_sync": "vote_any",
    "ballot": "ballot",
    "red_add": "red_add",
    "red_max": "red_max",
    "red_min": "red_min",
}

_SPECIALS = {
    "thread_idx": "tid",
    "lane_id": "lane",
    "warp_id": "wid",
    "block_idx": "bid",
    "block_dim": "bdim",
    "grid_dim": "gdim",
    "warp_size": "wsize",
}

# dim3 intrinsics accept an axis; bare calls mean 'x' (CUDA's .x), so
# every 1-D kernel is untouched.  lane/wid/wsize are axis-less: warps
# are a property of the x-fastest linearized thread order.
_DIM3_KINDS = ("tid", "bid", "bdim", "gdim")

# CUDA-style per-axis shorthands: c.tid_y() == c.thread_idx('y')
_AXIS_ALIASES = {
    f"{kind}_{ax}": (kind, ax)
    for kind in _DIM3_KINDS for ax in ("x", "y", "z")
}

_UNARY_MATH = {"exp", "log", "sqrt", "rsqrt", "tanh", "sigmoid", "floor", "abs", "neg"}
_CASTS = {"f32": DType.f32, "i32": DType.i32, "f16": DType.f16,
          "bf16": DType.bf16, "u32": DType.u32}

_DTYPE_BY_NAME = {d.value: d for d in DType}


class _Parser(ast.NodeVisitor):
    def __init__(self, ctx_name: str, arrays: Dict[str, ArraySpec],
                 scalars: Dict[str, ScalarSpec], closure: Dict[str, Any]):
        self.ctx = ctx_name
        self.arrays = arrays
        self.scalars = scalars
        self.closure = closure          # captured Python constants
        self.shared: Dict[str, SharedSpec] = {}
        self._tmp = 0

    # ---------------- helpers ----------------

    def fresh(self, hint="t") -> str:
        self._tmp += 1
        return f".{hint}{self._tmp}"

    def err(self, node, msg) -> CoxUnsupported:
        return CoxUnsupported(f"line {getattr(node, 'lineno', '?')}: {msg}")

    def _is_ctx_call(self, node) -> Optional[str]:
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == self.ctx):
            return node.func.attr
        return None

    # ---------------- expressions ----------------

    def expr(self, node) -> K.Expr:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool):
                return K.Const(bool(node.value), DType.b1)
            if isinstance(node.value, int):
                return K.Const(int(node.value), DType.i32)
            if isinstance(node.value, float):
                return K.Const(float(node.value), DType.f32)
            raise self.err(node, f"unsupported constant {node.value!r}")
        if isinstance(node, ast.Name):
            if node.id in self.scalars:
                return K.Var(node.id, self.scalars[node.id].dtype)
            if node.id in self.closure:
                v = self.closure[node.id]
                if isinstance(v, bool):
                    return K.Const(bool(v), DType.b1)
                if isinstance(v, int):
                    return K.Const(int(v), DType.i32)
                if isinstance(v, float):
                    return K.Const(float(v), DType.f32)
                raise self.err(node, f"closure var {node.id} has unsupported type {type(v)}")
            return K.Var(node.id)
        if isinstance(node, ast.BinOp):
            op = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
                  ast.FloorDiv: "//", ast.Mod: "%", ast.BitAnd: "&",
                  ast.BitOr: "|", ast.BitXor: "^", ast.LShift: "<<",
                  ast.RShift: ">>"}.get(type(node.op))
            if op is None:
                raise self.err(node, f"unsupported binop {type(node.op).__name__}")
            return K.BinOp(op, self.expr(node.left), self.expr(node.right))
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, ast.USub):
                return K.UnOp("neg", self.expr(node.operand))
            if isinstance(node.op, ast.Not):
                return K.UnOp("not", self.expr(node.operand))
            raise self.err(node, "unsupported unary op")
        if isinstance(node, ast.Compare):
            if len(node.ops) != 1:
                raise self.err(node, "chained comparisons unsupported")
            op = {ast.Lt: "<", ast.LtE: "<=", ast.Gt: ">", ast.GtE: ">=",
                  ast.Eq: "==", ast.NotEq: "!="}.get(type(node.ops[0]))
            if op is None:
                raise self.err(node, "unsupported comparison")
            return K.CmpOp(op, self.expr(node.left), self.expr(node.comparators[0]))
        if isinstance(node, ast.BoolOp):
            op = "and" if isinstance(node.op, ast.And) else "or"
            return K.BoolOp(op, [self.expr(v) for v in node.values])
        if isinstance(node, ast.IfExp):
            return K.Select(self.expr(node.test), self.expr(node.body),
                            self.expr(node.orelse))
        if isinstance(node, ast.Subscript):
            return self._load(node)
        if isinstance(node, ast.Call):
            return self._call_expr(node)
        raise self.err(node, f"unsupported expression {type(node).__name__}")

    def _linearize(self, arr_name: str, idxs: List[K.Expr], node) -> K.Expr:
        """Row-major linearization of per-axis indices against a shared
        array's static shape (the CUDA `tile[y][x]` address math)."""
        shape = self.shared[arr_name].shape
        if len(idxs) != len(shape):
            raise self.err(node, "index rank mismatch")
        flat: K.Expr = idxs[0]
        for dim, ix in zip(shape[1:], idxs[1:]):
            flat = K.BinOp("+", K.BinOp("*", flat, K.Const(int(dim), DType.i32)), ix)
        return flat

    def _index(self, arr_name: str, node) -> K.Expr:
        """Indices: 1-D for globals (CUDA pointer semantics); shared arrays
        with known shape accept tuple indices, linearized here."""
        if isinstance(node, ast.Tuple):
            if arr_name not in self.shared:
                raise self.err(node, "multi-dim index only on shared arrays")
            return self._linearize(arr_name,
                                   [self.expr(e) for e in node.elts], node)
        return self.expr(node)

    def _subscript_chain(self, node: ast.Subscript):
        """Peel a chained subscript — ``tile[ty][tx]`` (the CUDA 2-D
        shared-array spelling) — into ``(name, [axis index nodes])``.
        A plain ``name[idx]`` yields a single-element chain."""
        idx_nodes = []
        cur: ast.expr = node
        while isinstance(cur, ast.Subscript):
            idx_nodes.append(cur.slice)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            raise self.err(node, "only name[index] (optionally chained, "
                                 "e.g. tile[ty][tx]) supported")
        return cur.id, list(reversed(idx_nodes))

    def _subscript_index(self, node: ast.Subscript):
        """Resolve a load/store target subscript (plain, tuple, or
        chained) to ``(array name, linear index expr)``."""
        name, chain = self._subscript_chain(node)
        if len(chain) == 1:
            return name, self._index(name, chain[0])
        # chained subscripts: CUDA's `tile[ty][tx]` on a static 2-D/3-D
        # shared tile, lowered to the same row-major linearization as
        # the tuple form `tile[ty, tx]`
        if name not in self.shared:
            raise self.err(node, f"chained subscripts ({name}[i][j]) are "
                                 f"only supported on shared arrays with a "
                                 f"static shape (globals are 1-D CUDA "
                                 f"pointers — linearize the index)")
        if any(isinstance(c, ast.Tuple) for c in chain):
            raise self.err(node, "mixing tuple and chained subscripts "
                                 "is unsupported — write tile[ty][tx] or "
                                 "tile[ty, tx]")
        return name, self._linearize(name, [self.expr(c) for c in chain],
                                     node)

    def _load(self, node: ast.Subscript) -> K.Expr:
        name, idx = self._subscript_index(node)
        if name in self.shared:
            return K.LoadShared(name, idx, self.shared[name].dtype)
        if name in self.arrays:
            return K.LoadGlobal(name, idx, self.arrays[name].dtype)
        raise self.err(node, f"unknown array {name}")

    def _call_expr(self, node: ast.Call) -> K.Expr:
        attr = self._is_ctx_call(node)
        if attr is None:
            # builtins
            if isinstance(node.func, ast.Name) and node.func.id in ("min", "max"):
                if len(node.args) != 2:
                    raise self.err(node, "min/max take 2 args")
                return K.BinOp(node.func.id, self.expr(node.args[0]), self.expr(node.args[1]))
            if isinstance(node.func, ast.Name) and node.func.id == "abs":
                return K.UnOp("abs", self.expr(node.args[0]))
            if isinstance(node.func, ast.Name) and node.func.id == "float":
                return K.UnOp("f32", self.expr(node.args[0]))
            if isinstance(node.func, ast.Name) and node.func.id == "int":
                return K.UnOp("i32", self.expr(node.args[0]))
            raise self.err(node, "unsupported call")
        if attr in _AXIS_ALIASES:
            kind, axis = _AXIS_ALIASES[attr]
            if node.args or node.keywords:
                raise self.err(node, f"{attr}() takes no arguments "
                                     f"(the axis is in the name)")
            return K.Special(kind, DType.i32, axis)
        if attr in _SPECIALS:
            kind = _SPECIALS[attr]
            axis = "x"
            if node.args or node.keywords:
                if node.keywords or len(node.args) != 1:
                    raise self.err(node, f"{attr}() takes at most one "
                                         f"positional axis argument")
                if kind not in _DIM3_KINDS:
                    raise self.err(node, f"{attr}() takes no axis argument "
                                         f"(lane/warp ids are axis-less)")
                a0 = node.args[0]
                if not (isinstance(a0, ast.Constant)
                        and a0.value in ("x", "y", "z")):
                    raise self.err(node, f"{attr}() axis must be a literal "
                                         f"'x', 'y' or 'z'")
                axis = a0.value
            return K.Special(kind, DType.i32, axis)
        if attr in _CASTS:
            return K.UnOp(attr, self.expr(node.args[0]), _CASTS[attr])
        if attr in _UNARY_MATH:
            return K.UnOp(attr, self.expr(node.args[0]))
        if attr in ("min", "max"):
            return K.BinOp(attr, self.expr(node.args[0]), self.expr(node.args[1]))
        if attr == "select":
            return K.Select(self.expr(node.args[0]), self.expr(node.args[1]),
                            self.expr(node.args[2]))
        if attr in _WARP_FUNCS:
            # value-producing warp calls are handled in Assign; reaching here
            # means they are nested inside a larger expression — flattening
            # is done by stmt-level handling, so reject for clarity.
            raise self.err(node, f"warp collective {attr}() must be the sole "
                                 f"RHS of an assignment (e.g. v = c.{attr}(...))")
        if attr == "this_grid":
            raise self.err(
                node, "this_grid() is only supported as a grid barrier — "
                      "write c.this_grid().sync() (or c.grid_sync()) as a "
                      "standalone statement")
        if attr in ("coalesced_threads", "this_multi_grid"):
            raise CoxUnsupported(
                f"dynamic cooperative group '{attr}' requires runtime thread "
                f"scheduling (paper §2.2.3 — same gap as filter_arr)")
        raise self.err(node, f"unknown context intrinsic {attr}")

    # ---------------- statements ----------------

    def stmts(self, body: List[ast.stmt]) -> List[K.Stmt]:
        out: List[K.Stmt] = []
        for s in body:
            out.extend(self.stmt(s))
        return out

    def stmt(self, node: ast.stmt) -> List[K.Stmt]:
        if isinstance(node, ast.Expr):
            if isinstance(node.value, ast.Constant):  # docstring
                return []
            attr = self._is_ctx_call(node.value)
            if attr == "syncthreads":
                return [K.Barrier(BarrierLevel.BLOCK)]
            if attr == "syncwarp":
                return [K.Barrier(BarrierLevel.WARP)]
            if attr == "grid_sync":
                return [K.Barrier(BarrierLevel.GRID)]
            # cooperative-groups spelling: c.this_grid().sync()
            if (isinstance(node.value, ast.Call)
                    and isinstance(node.value.func, ast.Attribute)
                    and node.value.func.attr == "sync"
                    and self._is_ctx_call(node.value.func.value)
                    == "this_grid"):
                if node.value.args or node.value.keywords:
                    raise self.err(node, "this_grid().sync() takes no "
                                         "arguments")
                return [K.Barrier(BarrierLevel.GRID)]
            if attr == "atomic_add":
                a = node.value.args
                arr = a[0].id if isinstance(a[0], ast.Name) else None
                if arr not in self.arrays:
                    raise self.err(node, "atomic_add target must be a global array")
                return [K.AtomicRMW("add", arr, self.expr(a[1]), self.expr(a[2]))]
            raise self.err(node, "unsupported expression statement")
        if isinstance(node, ast.Assign):
            if len(node.targets) != 1:
                raise self.err(node, "multi-target assign unsupported")
            return self._assign(node.targets[0], node.value, node)
        if isinstance(node, ast.AugAssign):
            op = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
                  ast.FloorDiv: "//", ast.Mod: "%", ast.BitAnd: "&",
                  ast.BitOr: "|", ast.BitXor: "^", ast.LShift: "<<",
                  ast.RShift: ">>"}.get(type(node.op))
            if op is None:
                raise self.err(node, "unsupported augmented op")
            if isinstance(node.target, ast.Name):
                cur: ast.expr = ast.copy_location(
                    ast.Name(id=node.target.id, ctx=ast.Load()), node)
            elif isinstance(node.target, ast.Subscript):
                cur = ast.copy_location(
                    ast.Subscript(value=node.target.value, slice=node.target.slice,
                                  ctx=ast.Load()), node)
            else:
                raise self.err(node, "unsupported augmented target")
            value = K.BinOp(op, self.expr(cur), self.expr(node.value))
            return self._assign_value(node.target, value, node)
        if isinstance(node, ast.If):
            return [K.If(self.expr(node.test), self.stmts(node.body),
                         self.stmts(node.orelse))]
        if isinstance(node, ast.While):
            if node.orelse:
                raise self.err(node, "while-else unsupported")
            return [K.While(self.expr(node.test), self.stmts(node.body))]
        if isinstance(node, ast.For):
            return self._for_range(node)
        if isinstance(node, ast.Return):
            if node.value is not None:
                raise self.err(node, "kernels return nothing")
            return [K.Return()]
        if isinstance(node, (ast.Break, ast.Continue)):
            raise self.err(node, "break/continue unsupported (non-canonical loop)")
        if isinstance(node, ast.AnnAssign):
            if node.value is None:
                return []
            return self._assign(node.target, node.value, node)
        if isinstance(node, ast.Pass):
            return []
        raise self.err(node, f"unsupported statement {type(node).__name__}")

    def _assign(self, target, value_node, node) -> List[K.Stmt]:
        # shared-memory declaration:  tile = c.shared((64,), cox.f32)
        attr = self._is_ctx_call(value_node) if isinstance(value_node, ast.Call) else None
        if attr == "shared":
            if not isinstance(target, ast.Name):
                raise self.err(node, "shared decl target must be a name")
            shape_node = value_node.args[0]
            if isinstance(shape_node, ast.Tuple):
                dims = []
                for e in shape_node.elts:
                    ev = self.expr(e)
                    if not isinstance(ev, K.Const):
                        raise self.err(node, "shared shape must be static")
                    dims.append(int(ev.value))
                shape = tuple(dims)
            else:
                ev = self.expr(shape_node)
                if not isinstance(ev, K.Const):
                    raise self.err(node, "shared shape must be static")
                shape = (int(ev.value),)
            dt = DType.f32
            if len(value_node.args) > 1:
                dt = self._dtype_arg(value_node.args[1], node)
            self.shared[target.id] = SharedSpec(target.id, shape, dt)
            return []
        if attr in _WARP_FUNCS:
            if not isinstance(target, ast.Name):
                raise self.err(node, "warp collective result must go to a name")
            args = [self.expr(a) for a in value_node.args]
            width = 0
            for kw in value_node.keywords:
                if kw.arg == "width":
                    wv = self.expr(kw.value)
                    if not isinstance(wv, K.Const):
                        raise self.err(node, "tile width must be static "
                                             "(dynamic groups unsupported, paper §2.2.3)")
                    width = int(wv.value)
                else:
                    raise self.err(node, f"unknown kwarg {kw.arg}")
            return [K.WarpCall(_WARP_FUNCS[attr], target.id, args, width)]
        if attr == "atomic_add_old":
            a = value_node.args
            if not isinstance(target, ast.Name) or not isinstance(a[0], ast.Name):
                raise self.err(node, "bad atomic form")
            return [K.AtomicRMW("add", a[0].id, self.expr(a[1]), self.expr(a[2]),
                                dst=target.id)]
        return self._assign_value(target, self.expr(value_node), node)

    def _assign_value(self, target, value: K.Expr, node) -> List[K.Stmt]:
        if isinstance(target, ast.Name):
            if target.id in self.arrays or target.id in self.shared:
                raise self.err(node, f"cannot rebind array name {target.id}")
            if target.id in self.scalars:
                raise self.err(node, f"scalar parameter {target.id} is "
                                     f"read-only; copy it to a local first")
            return [K.Assign(target.id, value)]
        if isinstance(target, ast.Subscript):
            name, idx = self._subscript_index(target)
            if name in self.shared:
                return [K.StoreShared(name, idx, value)]
            if name in self.arrays:
                return [K.StoreGlobal(name, idx, value)]
            raise self.err(node, f"unknown array {name}")
        raise self.err(node, "unsupported assignment target")

    def _dtype_arg(self, node, ctx_node) -> DType:
        # cox.f32 etc. appear as Attribute or Name in closure
        if isinstance(node, ast.Attribute):
            name = node.attr
            if name in _DTYPE_BY_NAME:
                return _DTYPE_BY_NAME[name]
        if isinstance(node, ast.Name) and node.id in self.closure:
            v = self.closure[node.id]
            if isinstance(v, DType):
                return v
        raise self.err(ctx_node, "expected a cox dtype")

    def _for_range(self, node: ast.For) -> List[K.Stmt]:
        if node.orelse:
            raise self.err(node, "for-else unsupported")
        if not (isinstance(node.iter, ast.Call) and isinstance(node.iter.func, ast.Name)
                and node.iter.func.id == "range"):
            raise self.err(node, "only range() loops supported")
        if not isinstance(node.target, ast.Name):
            raise self.err(node, "loop target must be a name")
        a = node.iter.args
        if len(a) == 1:
            start, stop, step = K.Const(0, DType.i32), self.expr(a[0]), K.Const(1, DType.i32)
        elif len(a) == 2:
            start, stop, step = self.expr(a[0]), self.expr(a[1]), K.Const(1, DType.i32)
        elif len(a) == 3:
            start, stop, step = self.expr(a[0]), self.expr(a[1]), self.expr(a[2])
        else:
            raise self.err(node, "bad range()")
        var = node.target.id
        if isinstance(step, K.Const) and int(step.value) < 0:
            cond = K.CmpOp(">", K.Var(var), stop)
        elif isinstance(step, K.Const):
            cond = K.CmpOp("<", K.Var(var), stop)
        else:
            raise self.err(node, "range step must be a static constant")
        static_trip = None
        if all(isinstance(e, K.Const) for e in (start, stop, step)):
            s0, s1, st = int(start.value), int(stop.value), int(step.value)
            static_trip = max(0, -(-(s1 - s0) // st) if st > 0 else -(-(s0 - s1) // -st))
        body = self.stmts(node.body)
        # a user assignment to the induction variable invalidates the
        # static trip count (the executor would unroll the wrong length)
        def assigns_var(stmts) -> bool:
            for s in stmts:
                if isinstance(s, K.Assign) and s.name == var:
                    return True
                if isinstance(s, K.If) and (assigns_var(s.then_body)
                                            or assigns_var(s.else_body)):
                    return True
                if isinstance(s, K.While) and assigns_var(s.body):
                    return True
            return False
        if assigns_var(body):
            static_trip = None
        body.append(K.Assign(var, K.BinOp("+", K.Var(var), step)))
        return [K.Assign(var, start),
                K.While(cond, body, static_trip=static_trip,
                        induction=(var, start, step))]


def parse_kernel(fn, name: Optional[str] = None) -> K.Kernel:
    """Parse a Python function into a kernel IR."""
    src = textwrap.dedent(inspect.getsource(fn))
    tree = ast.parse(src)
    fdef = None
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fdef = n
            break
    if fdef is None:
        raise CoxUnsupported("no function definition found")
    args = fdef.args.args
    if not args:
        raise CoxUnsupported("kernel needs a context parameter")
    ctx_name = args[0].arg

    # closure constants (for captured Python ints/floats and dtypes)
    closure: Dict[str, Any] = {}
    if fn.__closure__ and fn.__code__.co_freevars:
        for nm, cell in zip(fn.__code__.co_freevars, fn.__closure__):
            try:
                closure[nm] = cell.cell_contents
            except ValueError:
                pass
    closure.update({k: v for k, v in fn.__globals__.items()
                    if isinstance(v, (int, float, DType)) and not k.startswith("__")})

    # parameter specs from annotations (evaluated objects via fn signature;
    # eval_str handles modules with `from __future__ import annotations`)
    try:
        sig = inspect.signature(fn, eval_str=True)
    except Exception:
        sig = inspect.signature(fn)
    arrays: Dict[str, ArraySpec] = {}
    scalars: Dict[str, ScalarSpec] = {}
    params: List[Any] = []
    for p in list(sig.parameters.values())[1:]:
        ann = p.annotation
        if isinstance(ann, Array):
            spec = ArraySpec(p.name, ann.dtype)
            arrays[p.name] = spec
        elif isinstance(ann, DType):
            spec = ScalarSpec(p.name, ann)
            scalars[p.name] = spec
        elif ann is inspect.Parameter.empty:
            spec = ArraySpec(p.name, DType.f32)  # CUDA default: float*
            arrays[p.name] = spec
        else:
            raise CoxUnsupported(
                f"parameter {p.name}: annotate with cox.Array(dtype) or a cox dtype")
        params.append(spec)

    parser = _Parser(ctx_name, arrays, scalars, closure)
    body = parser.stmts(fdef.body)
    return K.Kernel(name or fn.__name__, params, list(parser.shared.values()),
                    body, source=src)
