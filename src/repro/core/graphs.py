"""CUDA graphs: stream capture → instantiate → replay.

CUDA's answer to per-launch overhead is ``cudaGraph_t``: record a
stream's schedule once (``cudaStreamBeginCapture``), bake it into an
executable (``cudaGraphInstantiate``), then relaunch the whole DAG with
one host call (``cudaGraphLaunch``).  This module is the XLA rendition,
and it lands a bigger win than CUDA's: the captured DAG is staged as
**one jitted program** — every captured launch's raw (un-jitted)
backend launcher (``backends.*.build_fn``) is inlined into a single
trace, producer outputs thread *directly* into consumer bindings, so
consumed intermediates never materialize as device buffers and XLA
fuses across launch boundaries.  CUDA graphs amortize launch overhead;
a fused XLA graph also deletes the memory traffic between launches.

* :class:`~repro.core.types.GraphRef` — capture-time placeholder for a
  captured launch's output; passing one to a later captured launch
  records a *data edge*.
* :class:`GraphNode` / :class:`GraphNodeHandle` — one captured
  ``LaunchRequest`` and its handle (the capture-mode stand-in for
  :class:`~repro.core.streams.LaunchHandle`, so ``kern.launch(...)``
  composes unchanged under capture).
* :class:`Graph` — ``capture()`` context manager (or drive
  ``stream.begin_capture()`` / ``end_capture()`` directly),
  ``instantiate()``, ``replay(**bindings)``.
* :class:`GraphExec` — an instantiated graph: the staged fused
  executable plus this instantiation's current input bindings (CUDA
  ``cudaGraphExec_t``; rebinding at replay is
  ``cudaGraphExecKernelNodeSetParams``).

The fused executable joins the dispatcher's shared staging LRU, keyed
by the captured DAG's per-node stage keys — two structurally identical
captures (same kernels, geometry, knobs, and edge structure) trace and
compile once.  The per-launch raw traces themselves are shared with
eager staging through ``Dispatcher.stage_fn``, so a graph over a kernel
the streams already launched re-traces nothing.

Replay semantics follow CUDA: inputs not rebound keep their captured
values, rebindings persist across replays, and replay is pure — it
never mutates the bound arrays, it returns fresh outputs (the
functional analogue of relaunching over the same device buffers).
"""
from __future__ import annotations

import contextlib
import itertools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import errors as _errors
from . import faults as _faults
from .types import ArraySpec, CoxTypeError, CoxUnsupported, GraphRef

_names = itertools.count()


class GraphNode:
    """One captured launch: the request plus its schedule edges (stream
    program order + captured event edges + data edges), as node-index
    deps.  Capture order is a topological order by construction — every
    dep precedes its node — so instantiation never re-sorts."""

    __slots__ = ("graph", "idx", "req", "deps", "label")

    def __init__(self, graph: "Graph", idx: int, req, deps: Tuple[int, ...],
                 label: str):
        self.graph = graph
        self.idx = idx
        self.req = req
        self.deps = deps
        self.label = label

    def __repr__(self):
        return f"GraphNode({self.idx}:{self.label})"


class GraphNodeHandle:
    """Capture-mode stand-in for :class:`~repro.core.streams.
    LaunchHandle`: ``.outputs`` / ``.arrays()`` hand back
    :class:`~repro.core.types.GraphRef` placeholders (flat / reshaped,
    mirroring the eager handle's two endpoints) so dependent launches
    chain identically whether the stream is capturing or not.
    ``result()`` / ``done()`` raise — captured work has no results
    until the graph replays."""

    __slots__ = ("node",)

    def __init__(self, node: GraphNode):
        self.node = node

    @property
    def request(self):
        return self.node.req

    @property
    def graph(self) -> "Graph":
        return self.node.graph

    @property
    def stream(self):
        return self.node.req.stream

    def _refs(self, flat: bool) -> Dict[str, GraphRef]:
        req = self.node.req
        out = {}
        for s in req.ck.kernel.params:
            if not isinstance(s, ArraySpec):
                continue
            shape = tuple(req.shapes[s.name])
            if flat:
                shape = (int(np.prod(shape)),) if shape else (1,)
            out[s.name] = GraphRef(self.node, s.name, shape, s.dtype)
        return out

    @property
    def outputs(self) -> Dict[str, GraphRef]:
        """Flat placeholders — the async chaining endpoint."""
        return self._refs(flat=True)

    def arrays(self) -> Dict[str, GraphRef]:
        """Reshaped placeholders — what ``kern.launch`` returns."""
        return self._refs(flat=False)

    def done(self) -> bool:
        raise CoxUnsupported(
            f"{self.node!r} was captured, not launched — captured work "
            f"runs only at graph.replay(); there is no completion to "
            f"query")

    def result(self):
        raise CoxUnsupported(
            f"{self.node!r} was captured, not launched — captured work "
            f"runs only at graph.replay(); take outputs from the "
            f"replay's return value")


class Graph:
    """A captured launch DAG (CUDA ``cudaGraph_t``).

    Build one with :meth:`capture` (or ``stream.begin_capture(graph)``);
    :meth:`instantiate` stages the whole DAG as one fused executable;
    :meth:`replay` runs it with optionally rebound inputs.  A graph is
    immutable once instantiated — capture again into a fresh graph to
    change the schedule."""

    def __init__(self, name: Optional[str] = None):
        self.name = name or f"graph{next(_names)}"
        self.nodes: List[GraphNode] = []
        self._tails: Dict[Any, GraphNode] = {}   # stream -> captured tail
        self._streams: set = set()               # currently capturing
        self._disp = None
        self._exec: Optional["GraphExec"] = None
        self._frozen = False               # set by instantiate()

    def __repr__(self):
        return f"Graph({self.name!r}, nodes={len(self.nodes)})"

    def __len__(self):
        return len(self.nodes)

    # ------------- capture bookkeeping (driven by Stream) -------------

    def _attach_stream(self, stream) -> None:
        if self._frozen:
            raise CoxUnsupported(
                f"{self!r} is already instantiated — an instantiated "
                f"graph is immutable; capture into a fresh Graph")
        if self._disp is None:
            self._disp = stream.dispatcher
        elif stream.dispatcher is not self._disp:
            raise CoxUnsupported(
                f"{self!r}: all capturing streams must share one "
                f"dispatcher")
        self._streams.add(stream)

    def _detach_stream(self, stream) -> None:
        self._streams.discard(stream)

    def _tail_node(self, stream) -> Optional[GraphNode]:
        return self._tails.get(stream)

    @contextlib.contextmanager
    def capture(self, *streams):
        """Capture launches issued on ``streams`` (default: the default
        stream) into this graph for the duration of the ``with`` block —
        ``cudaStreamBeginCapture`` / ``cudaStreamEndCapture`` as a
        context manager."""
        from . import streams as _streams
        if not streams:
            streams = (_streams.get_dispatcher().default,)
        for s in streams:
            s.begin_capture(self)
        try:
            yield self
        finally:
            for s in streams:
                if s._capture is self:
                    s.end_capture()

    def add_request(self, req, *, stream) -> GraphNodeHandle:
        """Record one launch as a graph node (called by
        ``Stream.launch`` while capturing).  Schedule edges: the
        stream's captured tail plus any pending captured event edges;
        data edges: every :class:`GraphRef` argument."""
        if req.donate:
            raise CoxUnsupported(
                f"kernel '{req.ck.kernel.name}': donate=True is not "
                f"capturable — a replayed graph elides consumed "
                f"intermediates entirely (fusion already gives the "
                f"buffer reuse donation buys), and donating an external "
                f"input would consume the caller's buffer on every "
                f"replay")
        deps = []
        tail = self._tails.get(stream)
        if tail is not None:
            deps.append(tail.idx)
        deps.extend(stream._consume_capture_deps())
        for pname, val in (req.globals_ or {}).items():
            if isinstance(val, GraphRef):
                if val.node.graph is not self:
                    raise CoxUnsupported(
                        f"kernel '{req.ck.kernel.name}': argument "
                        f"'{pname}' references a launch captured in "
                        f"{val.node.graph!r}, not {self!r} — data edges "
                        f"cannot cross graphs")
                deps.append(val.node.idx)
        req.stream = stream
        node = GraphNode(self, len(self.nodes), req,
                         tuple(sorted(set(deps))), req.ck.kernel.name)
        self.nodes.append(node)
        self._tails[stream] = node
        return GraphNodeHandle(node)

    # ------------------------- instantiate -------------------------

    def instantiate(self, dispatcher=None, *, device=None) -> "GraphExec":
        """Stage the captured DAG as one fused executable and return a
        fresh :class:`GraphExec` bound to the captured input values.

        The executable joins the dispatcher's shared staging LRU keyed
        by the DAG's per-node stage keys, so instantiating twice — or
        instantiating a structurally identical second capture — traces
        and compiles exactly once (the second call is a stage hit);
        each :class:`GraphExec` still carries its *own* rebindable
        input state.

        ``device=`` places the instantiated graph: every replay commits
        its inputs to that device and runs there (CUDA: a graph
        launches into a stream on one device).  Left ``None``, the
        graph inherits the placed/pinned device of a capturing stream,
        if any — so a DAG captured on a placed stream replays where the
        stream's eager launches would have run."""
        if self._streams:
            raise CoxUnsupported(
                f"{self!r} is still capturing on "
                f"{sorted(s.name for s in self._streams)} — "
                f"end_capture() first")
        if not self.nodes:
            raise CoxUnsupported(
                f"{self!r} is empty — capture at least one launch "
                f"before instantiating")
        from . import streams as _streams
        disp = dispatcher or self._disp or _streams.get_dispatcher()
        spec = _binding_spec(self.nodes)
        key = ("graph",) + tuple(_node_sig(n, spec) for n in self.nodes)
        nodes = self.nodes

        def builder():
            return _trace_graph(disp, nodes, spec)

        exe, raw_fn = disp.stage_graph(key, builder)
        self._frozen = True                # the DAG is baked in; no edits
        if device is None:
            # inherit a capturing stream's placement (pin or policy
            # assignment) — replay runs where eager issue would have
            device = next((s._device for s in self._tails
                           if getattr(s, "_device", None) is not None),
                          None)
        return GraphExec(self, disp, exe, raw_fn, spec, device=device)

    def replay(self, **bindings) -> Dict[str, Any]:
        """Instantiate lazily (once), then replay — the one-call CUDA
        ``cudaGraphLaunch`` convenience.  Rebindings persist across
        replays on the underlying :class:`GraphExec`."""
        if self._exec is None:
            self._exec = self.instantiate()
        return self._exec.replay(**bindings)


def _binding_spec(nodes: List[GraphNode]) -> Dict[str, Any]:
    """Resolve the captured DAG's dataflow into a static spec:

    * ``node_bindings`` — per node, per param: ``('ref', producer_idx,
      out_name)`` (a data edge) or ``('ext'|'sext', canonical_name)``
      (an external array / scalar input);
    * ``inputs`` — canonical input name → (node idx, param name, kind);
    * ``dtypes`` — canonical input name → DType (the in-trace cast);
    * ``outputs`` — canonical output name → (node idx, out name) over
      the *terminal* outputs (never consumed by a later node —
      consumed intermediates are elided from the fused program);
    * ``aliases`` — bare param name → every canonical input it names.

    Canonical names are the bare param name when it is unique among
    external inputs, else ``{param}_n{node_idx}`` — derived purely from
    DAG structure, so structurally identical captures agree on names
    (a requirement for sharing the staged executable)."""
    ext_counts: Dict[str, int] = {}
    for n in nodes:
        req = n.req
        for s in req.ck.kernel.params:
            if isinstance(s, ArraySpec) and isinstance(
                    req.globals_[s.name], GraphRef):
                continue
            ext_counts[s.name] = ext_counts.get(s.name, 0) + 1

    def canon(pname: str, idx: int) -> str:
        return pname if ext_counts[pname] == 1 else f"{pname}_n{idx}"

    inputs: Dict[str, tuple] = {}
    dtypes: Dict[str, Any] = {}
    aliases: Dict[str, List[str]] = {}
    node_bindings: List[tuple] = []
    consumed = set()
    for n in nodes:
        req = n.req
        binds = []
        for s in req.ck.kernel.params:
            if isinstance(s, ArraySpec):
                v = req.globals_[s.name]
                if isinstance(v, GraphRef):
                    binds.append((s.name, ("ref", v.node.idx, v.name)))
                    consumed.add((v.node.idx, v.name))
                    continue
                c = canon(s.name, n.idx)
                binds.append((s.name, ("ext", c)))
                inputs[c] = (n.idx, s.name, "array")
            else:
                c = canon(s.name, n.idx)
                binds.append((s.name, ("sext", c)))
                inputs[c] = (n.idx, s.name, "scalar")
            dtypes[c] = s.dtype
            aliases.setdefault(s.name, []).append(c)
        node_bindings.append(tuple(binds))

    term = [(n.idx, s.name) for n in nodes for s in n.req.ck.kernel.params
            if isinstance(s, ArraySpec) and (n.idx, s.name) not in consumed]
    tcounts: Dict[str, int] = {}
    for _, nm in term:
        tcounts[nm] = tcounts.get(nm, 0) + 1
    outputs = {(nm if tcounts[nm] == 1 else f"{nm}_n{i}"): (i, nm)
               for i, nm in term}
    return {"node_bindings": tuple(node_bindings), "inputs": inputs,
            "dtypes": dtypes, "outputs": outputs, "aliases": aliases}


def _node_sig(node: GraphNode, spec: Dict[str, Any]) -> tuple:
    """One node's contribution to the graph stage key: kernel identity
    (``id(ck)`` — safe because the staged executable closes over the
    nodes, keeping every ck alive), the raw-launcher key (geometry +
    knobs sans donate), and the binding structure.  Schedule-only edges
    are deliberately absent: values flow exclusively through data
    edges, so captures differing only in event edges run the same
    program."""
    req = node.req
    return ((id(req.ck),) + req.fn_key()
            + spec["node_bindings"][node.idx])


def _trace_graph(disp, nodes: List[GraphNode], spec: Dict[str, Any]):
    """Build the fused executable: one ``jax.jit`` program that walks
    the nodes in capture (= topological) order, threading producer
    outputs straight into consumer bindings.  External inputs arrive as
    one dict pytree; the eager path's dtype-cast + flatten happens
    *inside* the trace (a no-op for the captured defaults, the
    conversion point for rebound values).  Returns only terminal
    outputs — consumed intermediates exist solely as values inside the
    trace, free for XLA to fuse away.  Returns ``(jitted, raw)`` — the
    fused executable plus the un-jitted trace function, the replay →
    eager fallback rung of the degradation ladder.

    A node that fails to stage fails the whole instantiation with *its
    own* typed error (:func:`~repro.core.errors.classify`, naming the
    node) — there is no partial graph."""
    staged = []                                      # [(plan, fn)] raw
    for n in nodes:
        fault = _faults.consume("stage", n.label)
        if fault is not None:
            raise fault
        try:
            staged.append(disp.stage_fn(n.req))
        except Exception as e:
            raise _errors.classify(
                e, site="stage",
                what=f"graph node {n.idx} (kernel '{n.label}')")
    node_bindings = spec["node_bindings"]
    outputs = spec["outputs"]
    dtypes = spec["dtypes"]

    def graph_fn(ext):
        vals: Dict[tuple, Any] = {}
        for (_, fn), n, binds in zip(staged, nodes, node_bindings):
            g, s = {}, {}
            for pname, b in binds:
                if b[0] == "ref":
                    g[pname] = vals[(b[1], b[2])]
                elif b[0] == "ext":
                    g[pname] = jnp.asarray(ext[b[1]],
                                           dtypes[b[1]].jnp).reshape(-1)
                else:
                    s[pname] = jnp.asarray(ext[b[1]], dtypes[b[1]].jnp)
            out = fn(g, s)
            for k, v in out.items():
                vals[(n.idx, k)] = v
        return {c: vals[t] for c, t in outputs.items()}

    return jax.jit(graph_fn), graph_fn


class GraphExec:
    """An instantiated graph (CUDA ``cudaGraphExec_t``): the shared
    fused executable plus *this* instantiation's input bindings.

    ``replay(**bindings)`` updates named inputs (bare param name when
    unambiguous, ``{param}_n{node}`` to address one node's binding —
    a bare name naming several bindings updates all of them) and runs
    the staged program: one dict update and one executable call, zero
    per-launch host work.  Un-rebound inputs keep their current values;
    rebindings persist across replays
    (``cudaGraphExecKernelNodeSetParams`` semantics)."""

    def __init__(self, graph: Graph, disp, exe, raw_fn,
                 spec: Dict[str, Any], *, device=None):
        self._graph = graph
        self._disp = disp
        self._exe = exe
        self._raw_fn = raw_fn        # un-jitted fallback (eager rung)
        self._device = device        # placed replay target (None: legacy)
        self._aliases = spec["aliases"]
        self._outputs = spec["outputs"]
        self._vals = {}
        for c, (nidx, pname, kind) in spec["inputs"].items():
            req = graph.nodes[nidx].req
            self._vals[c] = (req.globals_[pname] if kind == "array"
                             else req.scalars[pname])
        self._out_shapes = {c: tuple(graph.nodes[i].req.shapes[nm])
                            for c, (i, nm) in spec["outputs"].items()}

    @property
    def graph(self) -> Graph:
        return self._graph

    @property
    def device(self):
        """The device replays run on (``None``: unplaced legacy path)."""
        return self._device

    @property
    def input_names(self) -> Tuple[str, ...]:
        return tuple(self._vals)

    @property
    def output_names(self) -> Tuple[str, ...]:
        return tuple(self._outputs)

    def replay(self, **bindings) -> Dict[str, Any]:
        for name, val in bindings.items():
            if name in self._vals:
                self._vals[name] = val
            elif name in self._aliases:
                for c in self._aliases[name]:
                    if c in self._vals:
                        self._vals[c] = val
            else:
                raise KeyError(
                    f"graph {self._graph.name!r} has no input {name!r}; "
                    f"inputs: {sorted(self._vals)}")
        gname = self._graph.name
        dev = self._device
        if dev is not None:
            from .streams import _to_device
            with self._disp._lock:
                sticky = self._disp._sticky_for(dev)
            if sticky is not None:
                # a placed graph replays on *its* device — a poisoned
                # device fails the replay with its sticky error (route-
                # around is a placement-time decision, not a replay one)
                raise sticky
            # the transfer node: commit inputs to the placed device
            # (no-op for already-resident buffers) and keep the
            # committed arrays so later replays skip the put
            self._vals = {k: _to_device(v, dev)
                          for k, v in self._vals.items()}
        fault = _faults.consume("dispatch", gname)
        try:
            if fault is not None:
                raise fault
            flat = self._exe(self._vals)
        except Exception as e:
            err = _errors.classify(e, site="dispatch",
                                   what=f"graph '{gname}'")
            if (_errors.is_sticky(err)
                    or isinstance(err, (CoxUnsupported, CoxTypeError))):
                raise err            # user/device errors: no fallback
            # graph-replay → eager fallback: the last ladder rung — run
            # the same trace un-jitted (bitwise-identical by
            # construction), and log the degradation on the dispatcher
            disp = self._disp
            event = {"kernel": gname, "seq": -1,
                     "from": "graph-replay", "to": "eager",
                     "error": repr(err)}
            with disp._lock:
                disp.degradations += 1
                disp.degradation_log.append(event)
            flat = self._raw_fn(dict(self._vals))
        with self._disp._lock:
            self._disp._bump_dev(dev, "dispatches")
        return {c: v.reshape(self._out_shapes[c]) for c, v in flat.items()}

    __call__ = replay
