"""CUDA streams & events: the async launch-dispatch layer.

CUDA programs overlap independent kernels by issuing them on *streams* —
in-order launch queues whose cross-stream ordering is constrained only
by *events* (and by the legacy default stream, which synchronizes with
everything).  COX's runtime (paper §4) stops at synchronous single-queue
launches; this module refactors every launch into an explicit
request/dispatch architecture and builds streams on top:

* :class:`LaunchRequest` — resolved knobs (:class:`~repro.core.runtime.
  ResolvedLaunch`) plus bound args, the unit the dispatcher consumes.
  ``api.KernelFn.launch`` is now "build a request, enqueue it on the
  default stream, dispatch" — the returned arrays stay XLA futures
  exactly as before the refactor (no host block), one launch path.
* :class:`Stream` — an in-order launch queue.  ``stream.launch(...)``
  returns a :class:`LaunchHandle` future immediately; ``.result()``
  materializes the outputs.
* :class:`Event` — ``record()`` captures a point in a stream's program
  order; ``wait(stream)`` makes another stream's *subsequent* launches
  depend on it; ``synchronize()`` blocks the host; ``elapsed(end)``
  reports wall-clock milliseconds between two recorded events.
* :class:`Dispatcher` — the host-side scheduler.  Every flush
  **topologically orders** the pending requests by stream program order
  plus event edges and dispatches each staged executable through XLA's
  async dispatch — no ``block_until_ready`` inside the graph, so the
  host issues launch *B* while *A* is still executing (stream launches
  flush eagerly, like a CUDA launch; handles defer only the *wait*).
  The launch-level executable cache lives here (not on the kernel), so
  **all streams share staged executables**: identical geometry launched
  from two streams stages exactly once.

What maps to what (see README "Streams & events" for the full table):
in-stream order and event edges become host *dispatch order*; overlap
comes from XLA's async dispatch (a dispatched executable runs while the
host binds and dispatches the next request).  A single XLA device
executes one computation at a time, so two streams overlap host work
with device work — the CUDA H2D/compute-overlap story, not two
simultaneous device queues.  Buffer donation (``donate=True``) lets an
in-order stream re-launching over the same globals reuse their buffers
instead of copying (``jax.jit(..., donate_argnums=...)``).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
import weakref
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax

from . import runtime as _runtime
from .types import CoxUnsupported

# staged-executable LRU bound: far above any real working set (every
# distinct (kernel, geometry, knobs) combination is one entry); evicted
# entries are simply re-staged on next use
STAGE_CACHE_SIZE = 1024

# dispatch_log retention: the log is introspection/test surface, not an
# audit trail — a long-lived serving process must not grow per-launch
# state, so the log is trimmed to the most recent half once it doubles
DISPATCH_LOG_MAX = 8192


def _is_deleted(x) -> bool:
    """True for a jax.Array whose buffer was donated away (a later
    ``donate=True`` launch consumed it).  Deleted outputs are
    unwaitable — and vacuously complete: deletion happens when a
    downstream consumer was dispatched, and that consumer's own data
    dependency covers the producer."""
    try:
        return bool(x.is_deleted())
    except AttributeError:
        return False


def _outputs_ready(outputs: Dict[str, Any]) -> bool:
    """Non-blocking readiness over an output dict, donation-aware."""
    try:
        return all(_is_deleted(o) or o.is_ready() for o in outputs.values())
    except AttributeError:      # jax without Array.is_ready
        return True


def _block_outputs(outputs: Dict[str, Any]) -> None:
    """``block_until_ready`` over an output dict, skipping buffers that
    a donating relaunch already consumed."""
    for o in outputs.values():
        if not _is_deleted(o):
            jax.block_until_ready(o)


def _mesh_key(mesh) -> Any:
    """A hashable stand-in for the mesh in staging-cache keys, built
    from stable content (axis names/sizes + device ids).  Object
    identity is NOT a safe key: ``id()`` of a garbage-collected mesh can
    be recycled by a new mesh, which would then hit a stale executable
    closed over the old devices."""
    if mesh is None:
        return None
    try:
        return ("mesh", tuple(mesh.shape.items()),
                tuple(d.id for d in mesh.devices.flat))
    except (AttributeError, TypeError):
        pass
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return ("unhashable-mesh", id(mesh), repr(mesh))


@dataclasses.dataclass
class LaunchRequest:
    """One ``kernel<<<grid, block, stream>>>(*args)`` as data: the
    resolved launch knobs plus the bound arguments.  This is the unit
    the :class:`Dispatcher` consumes — ``KernelFn.make_request`` builds
    one, a :class:`Stream` enqueues it, the dispatcher stages and
    dispatches it."""
    ck: Any                      # CompiledKernel
    token: tuple                 # pass-pipeline cache key (stable per ck)
    rl: Any                      # runtime.ResolvedLaunch
    simd: bool
    chunk: Optional[int]
    mesh: Any
    axis: str
    donate: bool
    globals_: Optional[Dict[str, Any]]   # dropped after dispatch
    shapes: Dict[str, tuple]
    scalars: Optional[Dict[str, Any]]
    # dispatcher bookkeeping (set at enqueue / dispatch)
    seq: int = -1
    stream: Optional["Stream"] = None
    deps: Tuple[int, ...] = ()
    outputs: Optional[Dict[str, Any]] = None   # raw flat arrays (futures)
    dispatched: bool = False
    error: Optional[BaseException] = None

    def stage_key(self) -> tuple:
        """The staging-cache key *without* the kernel-identity element
        (the dispatcher prepends it).  Same layout as the old
        ``KernelFn._launch_cache`` key — the compile token first, the
        phase count second — with ``donate`` appended: a donating
        executable aliases its input buffers and must never be handed a
        launch that expects copies."""
        rl = self.rl
        return (self.token, self.ck.n_phases, rl.backend, rl.mode,
                rl.grid.astuple(), rl.block.astuple(), rl.n_warps,
                self.simd, self.chunk, rl.warp_exec, _mesh_key(self.mesh),
                self.axis, self.donate)


class LaunchHandle:
    """Future for an enqueued launch.  ``.result()`` flushes the
    dispatcher, blocks until this launch's outputs are ready, and
    returns them reshaped — the synchronous endpoint.  ``.outputs`` is
    the async endpoint: it only guarantees the launch has been
    *dispatched* and hands back the raw flat arrays (still XLA futures),
    the currency for chaining dependent launches without a host sync."""

    __slots__ = ("_req", "_disp")

    def __init__(self, req: LaunchRequest, disp: "Dispatcher"):
        self._req = req
        self._disp = disp

    @property
    def stream(self) -> "Stream":
        return self._req.stream

    @property
    def request(self) -> LaunchRequest:
        return self._req

    def done(self) -> bool:
        """True once the launch has been dispatched and its outputs are
        ready (never blocks)."""
        req = self._req
        if req.error is not None:
            return True
        if not req.dispatched:
            return False
        return _outputs_ready(req.outputs)

    @property
    def outputs(self) -> Dict[str, Any]:
        """Raw flat output arrays (async: dispatched, not awaited)."""
        self._disp.dispatch_through(self._req)
        if self._req.error is not None:
            # surfacing the error reclaims the bookkeeping entry, same
            # as an explicit sync would — no leak on the launch() path
            self._disp.forget(self._req)
            raise self._req.error
        return self._req.outputs

    def _reshaped(self) -> Dict[str, Any]:
        req = self._req
        for k, v in req.outputs.items():
            if _is_deleted(v):
                raise CoxUnsupported(
                    f"launch output '{k}' was donated to a later "
                    f"donate=True launch and its buffer is gone — "
                    f"materialize the handle before donating its "
                    f"outputs, or keep the downstream handle instead")
        return {k: v.reshape(req.shapes[k]) for k, v in req.outputs.items()}

    def arrays(self) -> Dict[str, Any]:
        """Reshaped outputs *without* a host sync — still XLA futures,
        exactly what the pre-stream ``KernelFn.launch`` returned.  The
        launch (and everything it depends on) is dispatched first."""
        outs = self.outputs      # dispatch + surface this request's error
        del outs
        return self._reshaped()

    def result(self) -> Dict[str, Any]:
        """Materialize: flush, block on this launch, reshape outputs."""
        self._disp.sync_request(self._req)
        return self._reshaped()


class Stream:
    """An in-order launch queue (CUDA ``cudaStream_t``).

    Launches enqueued on one stream dispatch in program order; launches
    on different streams are unordered unless an :class:`Event` edge —
    or the legacy default stream — connects them.  The **default
    stream** has CUDA's legacy-sync semantics: a launch on it is ordered
    after the current tail of *every* stream, and every stream's next
    launch is ordered after the default stream's tail."""

    _names = itertools.count()

    def __init__(self, name: Optional[str] = None,
                 dispatcher: Optional["Dispatcher"] = None, *,
                 _default: bool = False):
        self._disp = dispatcher if dispatcher is not None else get_dispatcher()
        self._default = _default
        self.name = name or ("default" if _default
                             else f"stream{next(self._names)}")
        self._wait_deps: List[int] = []   # event edges for the next launch

    def __repr__(self):
        return f"Stream({self.name!r})"

    @property
    def is_default(self) -> bool:
        return self._default

    @property
    def dispatcher(self) -> "Dispatcher":
        return self._disp

    def launch(self, kern, *, grid, block, args, **knobs) -> LaunchHandle:
        """Enqueue ``kern<<<grid, block>>>(*args)`` on this stream and
        return a :class:`LaunchHandle` immediately.  ``kern`` is an
        ``api.KernelFn``; ``knobs`` are the usual launch knobs
        (``backend=``, ``warp_exec=``, ``donate=``, ...).

        Dispatch is **eager**, exactly like a CUDA launch: the request
        (and anything still pending) goes straight through the
        dispatcher's topological flush into XLA's async dispatch, so
        the kernel starts executing while the host issues the next
        launch — the handle only defers the *wait*, never the work.
        Enqueue order is always a legal linearization (an event edge
        requires its ``record`` to precede the ``wait``), so eager
        dispatch can never violate a dependency."""
        req = kern.make_request(grid=grid, block=block, args=args, **knobs)
        handle = self._disp.enqueue(req, self)
        self._disp.flush()
        return handle

    def wait_event(self, event: "Event") -> None:
        """All *subsequent* launches on this stream wait for ``event``
        (CUDA ``cudaStreamWaitEvent``).  Waiting on an unrecorded event
        is a no-op, as on CUDA."""
        event.wait(self)

    def record_event(self, event: Optional["Event"] = None) -> "Event":
        """Record (a new) event at this stream's current tail."""
        ev = event if event is not None else Event()
        ev.record(self)
        return ev

    def synchronize(self) -> None:
        """Block the host until every launch enqueued on this stream has
        completed.  Idempotent — synchronizing an already-idle stream is
        a no-op."""
        self._disp.sync_stream(self)

    def _consume_wait_deps(self) -> List[int]:
        deps, self._wait_deps = self._wait_deps, []
        return deps


class Event:
    """CUDA-style event: a recorded point in a stream's program order.

    ``record(stream)`` captures the stream's current tail;
    ``wait(stream)`` orders another stream's subsequent launches after
    that point; ``synchronize()`` blocks the host until the recorded
    work completed and stamps the completion time; ``elapsed(end)``
    returns milliseconds between two events' stamps.  Timing caveat:
    the stamp is taken when completion is first *observed* (at a
    ``synchronize()``), not at true device completion — synchronize
    promptly for tight timings."""

    def __init__(self):
        self._req: Optional[LaunchRequest] = None
        self._disp: Optional[Dispatcher] = None
        self._recorded = False
        self._t_done: Optional[float] = None

    def record(self, stream: Optional[Stream] = None) -> "Event":
        stream = stream if stream is not None else get_dispatcher().default
        self._disp = stream.dispatcher
        self._req = self._disp.tail_request(stream)   # None: empty stream
        self._recorded = True
        # recording on an idle stream completes immediately (CUDA: an
        # event completes once all preceding stream work has) — stamp now
        self._t_done = None if self._req is not None else time.perf_counter()
        return self

    def wait(self, stream: Stream) -> None:
        if not self._recorded or self._req is None:
            return                       # CUDA: wait-before-record is a no-op
        stream._wait_deps.append(self._req.seq)

    def query(self) -> bool:
        """True when the recorded work has completed (never blocks)."""
        if not self._recorded:
            return True
        if self._req is None:
            return True
        if not self._req.dispatched:
            return False
        return _outputs_ready(self._req.outputs)

    def synchronize(self) -> "Event":
        """Block until the recorded work completed; idempotent.  The
        first call stamps the event's completion time."""
        if not self._recorded:
            raise CoxUnsupported("Event.synchronize() before record()")
        if self._req is not None:
            self._disp.sync_request(self._req)
        if self._t_done is None:
            self._t_done = time.perf_counter()
        return self

    def elapsed(self, end: "Event") -> float:
        """Milliseconds between this (start) event and ``end`` — CUDA
        ``cudaEventElapsedTime``.  Synchronizes both events."""
        self.synchronize()
        end.synchronize()
        return (end._t_done - self._t_done) * 1e3

    elapsed_time = elapsed   # cupy-style alias


class Dispatcher:
    """Host-side launch scheduler + the shared staging cache.

    :meth:`flush` topologically orders the pending request graph —
    stream program order plus event edges, FIFO (enqueue-order)
    tie-break — and dispatches each request's staged executable via
    XLA's **async dispatch**: the ``exe(...)`` call returns futures
    immediately, so a dispatched kernel executes while the host binds
    and dispatches later requests.  Stream launches flush eagerly (a
    CUDA launch starts the kernel, it does not queue it on the host);
    requests can still sit pending between ``enqueue`` and ``flush``
    when the dispatcher is driven directly.  Nothing in the dispatch
    path calls ``block_until_ready``.

    Staged executables are cached here, keyed on kernel identity plus
    the request's resolved geometry/knobs (``LaunchRequest.stage_key``),
    so every stream — and the synchronous ``KernelFn.launch`` path —
    shares one staging per distinct launch shape."""

    def __init__(self, stage_cache_size: int = STAGE_CACHE_SIZE):
        # _lock guards the queues/caches and is only ever held briefly;
        # _dispatch_lock serializes whole flush drains so concurrent
        # flushes cannot interleave dispatch out of dependency order,
        # while staging (JAX trace/compile) runs with only it held —
        # other threads' enqueues/syncs never wait on a compile
        self._lock = threading.RLock()
        self._dispatch_lock = threading.Lock()
        self._stage_cache_size = stage_cache_size
        self._staged: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._pending: "OrderedDict[int, LaunchRequest]" = OrderedDict()
        self._inflight: Dict[int, LaunchRequest] = {}
        # stream -> weakref to its tail request.  Both sides are weak on
        # purpose: a pending/in-flight request is kept alive by
        # _pending/_inflight (and keeps its stream alive via req.stream),
        # while a *completed* request whose handle was dropped may be
        # collected — ordering against completed work is vacuous, so a
        # dead tail simply means "no edge needed".
        self._tails: "weakref.WeakKeyDictionary[Stream, Any]" = \
            weakref.WeakKeyDictionary()
        self._seq = itertools.count()
        self.dispatch_log: List[int] = []   # seq order of dispatches
        self.stage_hits = 0
        self.stage_misses = 0
        self.default = Stream(dispatcher=self, _default=True)

    # ---------------- enqueue ----------------

    def enqueue(self, req: LaunchRequest, stream: Stream) -> LaunchHandle:
        """Assign the request its place in the launch order: program
        order on its stream, pending event edges, and the default
        stream's legacy-sync edges."""
        with self._lock:
            req.seq = next(self._seq)
            req.stream = stream
            deps = []
            tail = self.tail_request(stream)
            if tail is not None:
                deps.append(tail.seq)            # in-order within the stream
            if stream.is_default:
                # legacy sync: the default stream is ordered after the
                # current tail of every other stream
                for s in list(self._tails):
                    if s is stream:
                        continue
                    t = self._tails[s]()
                    if t is not None:
                        deps.append(t.seq)
            else:
                dt = self.tail_request(self.default)
                if dt is not None:
                    deps.append(dt.seq)          # ...and every stream after it
            deps.extend(stream._consume_wait_deps())
            req.deps = tuple(sorted(set(deps)))
            self._pending[req.seq] = req
            self._tails[stream] = weakref.ref(req)
            return LaunchHandle(req, self)

    def tail_request(self, stream: Stream) -> Optional[LaunchRequest]:
        with self._lock:
            ref = self._tails.get(stream)
            return ref() if ref is not None else None

    # ---------------- staging (the shared launch cache) ----------------

    def stage(self, req: LaunchRequest):
        """Resolve the request to a staged ``(plan, exe)``, shared
        across streams.  ``id(ck)`` is safe in the key because the
        cached plan holds a strong reference to the same ck — the id
        cannot be recycled while the entry lives."""
        key = (id(req.ck),) + req.stage_key()
        with self._lock:
            hit = self._staged.get(key)
            if hit is not None:
                self._staged.move_to_end(key)
                self.stage_hits += 1
                return hit
        staged = _runtime.build_resolved(
            req.ck, req.rl, simd=req.simd, mesh=req.mesh, axis=req.axis,
            chunk=req.chunk, donate=req.donate)
        with self._lock:
            self.stage_misses += 1
            self._staged[key] = staged
            while len(self._staged) > self._stage_cache_size:
                self._staged.popitem(last=False)
        return staged

    def cache_view(self, cks) -> Dict[tuple, tuple]:
        """The staged entries for the given compiled kernels, keyed
        without the kernel-identity element — the backward-compatible
        ``KernelFn._launch_cache`` shape."""
        ids = {id(ck) for ck in cks}
        with self._lock:
            return {k[1:]: v for k, v in self._staged.items() if k[0] in ids}

    # ---------------- dispatch ----------------

    def _toposorted(self) -> List[LaunchRequest]:
        """Kahn's algorithm over the pending graph: edges are stream
        program order + event edges (``req.deps``, restricted to
        still-pending requests); ties break FIFO by enqueue order, so
        the dispatch order is deterministic."""
        pending = self._pending
        indeg = {seq: sum(1 for d in r.deps if d in pending)
                 for seq, r in pending.items()}
        ready = sorted(seq for seq, n in indeg.items() if n == 0)
        out: List[LaunchRequest] = []
        fwd: Dict[int, List[int]] = {}
        for seq, r in pending.items():
            for d in r.deps:
                if d in pending:
                    fwd.setdefault(d, []).append(seq)
        heapq.heapify(ready)
        while ready:
            seq = heapq.heappop(ready)
            out.append(pending[seq])
            for nxt in fwd.get(seq, ()):
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    heapq.heappush(ready, nxt)
        if len(out) != len(pending):     # impossible by construction:
            raise AssertionError("cycle in launch-dependency graph")
        return out

    def _dispatch(self, req: LaunchRequest) -> None:
        try:
            _, exe = self.stage(req)      # may trace/compile — no _lock
            req.outputs = exe(req.globals_, req.scalars)   # async dispatch
        except Exception as e:            # surfaces at *this* request's sync
            req.error = e
        req.dispatched = True
        req.globals_ = None               # release (or donated) inputs
        req.scalars = None
        with self._lock:
            self._inflight[req.seq] = req
            self.dispatch_log.append(req.seq)
            if len(self.dispatch_log) > 2 * DISPATCH_LOG_MAX:
                del self.dispatch_log[:-DISPATCH_LOG_MAX]

    def flush(self) -> None:
        """Dispatch every pending request in topological order.  The
        drain loop holds only the dispatch lock; the queue lock is
        taken just to snapshot a batch, so concurrent enqueues (and
        already-staged launches) never wait on a first-launch compile."""
        with self._dispatch_lock:
            while True:
                with self._lock:
                    if not self._pending:
                        break
                    order = self._toposorted()
                    self._pending = OrderedDict()
                for req in order:
                    self._dispatch(req)
            with self._lock:
                self._prune_inflight()

    def dispatch_through(self, req: LaunchRequest) -> None:
        """Ensure ``req`` (and, by topological order, everything it
        depends on) has been dispatched."""
        if not req.dispatched:
            self.flush()

    def _prune_inflight(self) -> None:
        for seq in list(self._inflight):
            r = self._inflight[seq]
            if r.error is not None:
                continue                 # kept until its sync re-raises
            if _outputs_ready(r.outputs):
                del self._inflight[seq]

    # ---------------- synchronization ----------------

    def forget(self, req: LaunchRequest) -> None:
        """Drop a request from the in-flight set (its error/result has
        been surfaced to the caller)."""
        with self._lock:
            self._inflight.pop(req.seq, None)

    def sync_request(self, req: LaunchRequest) -> None:
        """Flush, then block until this request's outputs are ready."""
        self.dispatch_through(req)
        self.forget(req)
        if req.error is not None:
            raise req.error
        _block_outputs(req.outputs)

    def _take_inflight(self, stream: Optional[Stream]) -> List[LaunchRequest]:
        """Atomically remove (and return, seq-ordered) the in-flight
        requests of ``stream`` — or of every stream when ``None``.  The
        caller blocks on them *outside* the lock, so concurrent
        enqueues/flushes never wait on device completion."""
        with self._lock:
            taken = []
            for seq in sorted(self._inflight):
                r = self._inflight[seq]
                if stream is None or r.stream is stream:
                    del self._inflight[seq]
                    taken.append(r)
            return taken

    def sync_stream(self, stream: Optional[Stream]) -> None:
        """Block until every launch enqueued on ``stream`` completed
        (``None``: on any stream).  The first deferred launch error of
        the synced set is raised, CUDA's sticky-async-error analogue."""
        self.flush()
        errs = []
        for r in self._take_inflight(stream):
            if r.error is not None:
                errs.append(r.error)
                continue
            _block_outputs(r.outputs)
        if errs:
            raise errs[0]

    def sync_all(self) -> None:
        """Device-wide barrier (CUDA ``cudaDeviceSynchronize``)."""
        self.sync_stream(None)


# ---------------------------------------------------------------------------
# module singletons — the process-wide dispatcher and its default stream
# ---------------------------------------------------------------------------

_DISPATCHER = Dispatcher()
default_stream = _DISPATCHER.default


def get_dispatcher() -> Dispatcher:
    return _DISPATCHER


def synchronize() -> None:
    """Device-wide barrier over the default dispatcher."""
    _DISPATCHER.sync_all()
