"""CUDA streams & events: the async launch-dispatch layer.

CUDA programs overlap independent kernels by issuing them on *streams* —
in-order launch queues whose cross-stream ordering is constrained only
by *events* (and by the legacy default stream, which synchronizes with
everything).  COX's runtime (paper §4) stops at synchronous single-queue
launches; this module refactors every launch into an explicit
request/dispatch architecture and builds streams on top:

* :class:`LaunchRequest` — resolved knobs (:class:`~repro.core.runtime.
  ResolvedLaunch`) plus bound args, the unit the dispatcher consumes.
  ``api.KernelFn.launch`` is now "build a request, enqueue it on the
  default stream, dispatch" — the returned arrays stay XLA futures
  exactly as before the refactor (no host block), one launch path.
* :class:`Stream` — an in-order launch queue.  ``stream.launch(...)``
  returns a :class:`LaunchHandle` future immediately; ``.result()``
  materializes the outputs.
* :class:`Event` — ``record()`` captures a point in a stream's program
  order; ``wait(stream)`` makes another stream's *subsequent* launches
  depend on it; ``synchronize()`` blocks the host; ``elapsed(end)``
  reports wall-clock milliseconds between two recorded events.
* :class:`Dispatcher` — the host-side scheduler.  Every flush
  **topologically orders** the pending requests by stream program order
  plus event edges and dispatches each staged executable through XLA's
  async dispatch — no ``block_until_ready`` inside the graph, so the
  host issues launch *B* while *A* is still executing (stream launches
  flush eagerly, like a CUDA launch; handles defer only the *wait*).
  The launch-level executable cache lives here (not on the kernel), so
  **all streams share staged executables**: identical geometry launched
  from two streams stages exactly once.

What maps to what (see README "Streams & events" for the full table):
in-stream order and event edges become host *dispatch order*; overlap
comes from XLA's async dispatch (a dispatched executable runs while the
host binds and dispatches the next request).  A single XLA device
executes one computation at a time, so two streams overlap host work
with device work — the CUDA H2D/compute-overlap story, not two
simultaneous device queues.  Buffer donation (``donate=True``) lets an
in-order stream re-launching over the same globals reuse their buffers
instead of copying (``jax.jit(..., donate_argnums=...)``).

**Error model** (README "Error model & fault tolerance"): failures are
typed (``repro.core.errors``) and follow CUDA's contract — a failed
launch surfaces its error at *its own* sync, its DAG descendants
(stream program order + event edges + ``handle.outputs`` data edges,
the same edge set graph capture records) fail fast with
:class:`~repro.core.errors.CoxDependencyError` instead of dispatching
on stale inputs, the failing stream is poisoned until the error is
surfaced (or ``stream.reset()``), sticky errors
(:class:`~repro.core.errors.CoxDeviceError`) poison every enqueue
until :func:`device_reset`, and ``get_last_error()`` /
``peek_at_last_error()`` are the ``cudaGetLastError`` /
``cudaPeekAtLastError`` analogues.  Transient staging failures get a
bounded retry-with-backoff; non-transient failures on auto-chosen
knobs walk a graceful-degradation ladder (batched→serial warp
execution, vmap→scan backend — each rung re-staged, bitwise-correct by
the backend-equivalence contract, and logged as a structured
degradation event).  A per-launch deadline (``launch_deadline_s``,
enforced through :class:`~repro.ft.watchdog.StepWatchdog`) turns a
hung launch into :class:`~repro.core.errors.CoxTimeoutError` at sync.

**Multi-device placement & priorities** (README "Multi-device
placement"): when the dispatcher's device pool holds more than one
device, each non-default stream is *placed* on one
(``repro.core.placement`` policies: round-robin, affinity-by-resident-
buffers, health-aware) so independent streams execute concurrently on
different XLA devices — true CUDA multi-queue concurrency, not just
host/device pipelining.  Placement happens at dispatch: inputs are
``jax.device_put`` to the stream's device (a no-op for already-resident
buffers, an explicit async transfer node when a cross-stream data/event
edge crosses devices), staged executables are per-device (the stage key
carries the target device), and sticky :class:`~repro.core.errors.
CoxDeviceError` is scoped to the failing device — placement routes new
work around a poisoned device; ``device_reset(device=...)`` revives
one.  ``Stream(priority=...)`` biases the Kahn ready-set: among
simultaneously-ready requests, lower priority numbers dispatch first
(CUDA's convention — ``cudaStreamCreateWithPriority``'s
``greatestPriority`` is the most negative).  The default stream, mesh
(sharded) launches, and single-device pools keep the exact legacy
dispatch path.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
import weakref
from collections import OrderedDict, deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax

from . import costmodel as _costmodel
from . import errors as _errors
from . import faults as _faults
from . import placement as _placement
from . import runtime as _runtime
from ..ft.watchdog import StepWatchdog
from .errors import (CoxDependencyError, CoxTimeoutError)
from .types import CoxUnsupported, GraphRef

# staged-executable LRU bound: far above any real working set (every
# distinct (kernel, geometry, knobs) combination is one entry); evicted
# entries are simply re-staged on next use
STAGE_CACHE_SIZE = 1024

# dispatch_log retention: the log is introspection/test surface, not an
# audit trail — a long-lived serving process must not grow per-launch
# state, so the log is a bounded ``deque(maxlen=...)`` holding only the
# most recent dispatches (older entries fall off structurally)
DISPATCH_LOG_MAX = 8192

# errored-request retention: a caller that drops a failed handle without
# syncing must not leak its request forever — errored entries move to a
# bounded OrderedDict (newest kept, oldest evicted) surfaced via
# ``get_last_error()`` / ``Dispatcher.error_log``
ERROR_LOG_MAX = 256

# structured degradation events (ladder fallbacks) — bounded the same way
DEGRADATION_LOG_MAX = 1024

# transient-failure retry knobs: attempts beyond the first, and the
# exponential-backoff base (sleep = base * 2**attempt)
RETRY_LIMIT = 3
RETRY_BACKOFF_S = 0.005

# deadline-wait poll period: the watchdog timer marks the deadline; the
# waiter polls readiness at this granularity (host-side, no device cost)
DEADLINE_POLL_S = 0.001

# per-stage-key telemetry retention (launch counts, dispatch time, cost
# estimates) — bounded like every other long-lived dispatcher structure
TELEMETRY_MAX = 512


def _is_deleted(x) -> bool:
    """True for a jax.Array whose buffer was donated away (a later
    ``donate=True`` launch consumed it).  Deleted outputs are
    unwaitable — and vacuously complete: deletion happens when a
    downstream consumer was dispatched, and that consumer's own data
    dependency covers the producer."""
    try:
        return bool(x.is_deleted())
    except AttributeError:
        return False


def _outputs_ready(outputs: Dict[str, Any]) -> bool:
    """Non-blocking readiness over an output dict, donation-aware."""
    try:
        return all(_is_deleted(o) or o.is_ready() for o in outputs.values())
    except AttributeError:      # jax without Array.is_ready
        return True


def _block_outputs(outputs: Dict[str, Any]) -> None:
    """``block_until_ready`` over an output dict, skipping buffers that
    a donating relaunch already consumed."""
    for o in outputs.values():
        if not _is_deleted(o):
            jax.block_until_ready(o)


def _dev_id(dev) -> Optional[int]:
    """A stable hashable stand-in for a device in cache keys and the
    per-device sticky map (``None`` = unplaced / legacy path)."""
    return None if dev is None else dev.id


def _to_device(val, dev):
    """``jax.device_put`` to ``dev`` unless the value is already
    resident there — the identity-preserving transfer node.  Returning
    the original array for already-resident buffers is load-bearing:
    a donating relaunch over the same globals must see the *same*
    buffers to alias them instead of copying."""
    try:
        if val.devices() == {dev}:
            return val
    except (AttributeError, TypeError):
        pass
    return jax.device_put(val, dev)


def _mesh_key(mesh) -> Any:
    """A hashable stand-in for the mesh in staging-cache keys, built
    from stable content (axis names/sizes + device ids).  Object
    identity is NOT a safe key: ``id()`` of a garbage-collected mesh can
    be recycled by a new mesh, which would then hit a stale executable
    closed over the old devices."""
    if mesh is None:
        return None
    try:
        return ("mesh", tuple(mesh.shape.items()),
                tuple(d.id for d in mesh.devices.flat))
    except (AttributeError, TypeError):
        pass
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return ("unhashable-mesh", id(mesh), repr(mesh))


@dataclasses.dataclass
class LaunchRequest:
    """One ``kernel<<<grid, block, stream>>>(*args)`` as data: the
    resolved launch knobs plus the bound arguments.  This is the unit
    the :class:`Dispatcher` consumes — ``KernelFn.make_request`` builds
    one, a :class:`Stream` enqueues it, the dispatcher stages and
    dispatches it."""
    ck: Any                      # CompiledKernel
    token: tuple                 # pass-pipeline cache key (stable per ck)
    rl: Any                      # runtime.ResolvedLaunch
    simd: bool
    chunk: Optional[int]
    mesh: Any
    axis: str
    donate: bool
    globals_: Optional[Dict[str, Any]]   # dropped after dispatch
    shapes: Dict[str, tuple]
    scalars: Optional[Dict[str, Any]]
    # the *requested* (pre-resolution) knobs — the degradation ladder
    # only falls back along rungs the user left on 'auto'; explicitly
    # requested knobs are honored and fail as requested
    req_backend: str = "auto"
    req_warp_exec: str = "auto"
    # target device: an explicit ``device=`` knob pins it here; else the
    # dispatcher's placement policy fills it at dispatch (stays None on
    # the single-device / default-stream / mesh legacy paths)
    device: Any = None
    # dispatch priority, inherited from the stream at enqueue — lower
    # numbers dispatch first among simultaneously-ready requests
    priority: int = 0
    # dispatcher bookkeeping (set at enqueue / dispatch)
    seq: int = -1
    stream: Optional["Stream"] = None
    deps: Tuple[int, ...] = ()
    data_deps: Tuple[int, ...] = ()            # handle.outputs edges
    outputs: Optional[Dict[str, Any]] = None   # raw flat arrays (futures)
    dispatched: bool = False
    error: Optional[BaseException] = None
    surfaced: bool = False       # error raised to (or consumed by) the caller
    injected_hang: bool = False  # timeout-site fault: outputs never ready
    out_ids: List[int] = dataclasses.field(default_factory=list)

    def fn_key(self) -> tuple:
        """Everything that determines the request's *traced program* —
        the raw launcher's identity, shared between eager staging and
        graph staging.  Donation is a jit-wrapper property (buffer
        aliasing), not a trace property, so it lives only in
        :meth:`stage_key`."""
        rl = self.rl
        return (self.token, self.ck.n_phases, rl.backend, rl.mode,
                rl.grid.astuple(), rl.block.astuple(), rl.n_warps,
                self.simd, self.chunk, rl.warp_exec, rl.schedule,
                rl.n_resident, _mesh_key(self.mesh), self.axis)

    def stage_key(self) -> tuple:
        """The staging-cache key *without* the kernel-identity element
        (the dispatcher prepends it).  Same layout as the old
        ``KernelFn._launch_cache`` key — the compile token first, the
        phase count second — with ``donate`` and the target device
        appended: a donating executable aliases its input buffers and
        must never be handed a launch that expects copies, and a placed
        executable runs on committed inputs so its compiled program is
        per-device."""
        return self.fn_key() + (self.donate, _dev_id(self.device))


class LaunchHandle:
    """Future for an enqueued launch.  ``.result()`` flushes the
    dispatcher, blocks until this launch's outputs are ready, and
    returns them reshaped — the synchronous endpoint.  ``.outputs`` is
    the async endpoint: it only guarantees the launch has been
    *dispatched* and hands back the raw flat arrays (still XLA futures),
    the currency for chaining dependent launches without a host sync."""

    __slots__ = ("_req", "_disp")

    def __init__(self, req: LaunchRequest, disp: "Dispatcher"):
        self._req = req
        self._disp = disp

    @property
    def stream(self) -> "Stream":
        return self._req.stream

    @property
    def request(self) -> LaunchRequest:
        return self._req

    def done(self) -> bool:
        """True once the launch has been dispatched and its outputs are
        ready (never blocks)."""
        req = self._req
        if req.error is not None:
            return True
        if not req.dispatched or req.injected_hang:
            return False
        return _outputs_ready(req.outputs)

    @property
    def outputs(self) -> Dict[str, Any]:
        """Raw flat output arrays (async: dispatched, not awaited)."""
        self._disp.dispatch_through(self._req)
        if self._req.error is not None:
            # surfacing the error reclaims the bookkeeping entry, same
            # as an explicit sync would — no leak on the launch() path
            self._disp.forget(self._req)
            raise self._req.error
        return self._req.outputs

    def _reshaped(self) -> Dict[str, Any]:
        req = self._req
        for k, v in req.outputs.items():
            if _is_deleted(v):
                raise CoxUnsupported(
                    f"launch output '{k}' was donated to a later "
                    f"donate=True launch and its buffer is gone — "
                    f"materialize the handle before donating its "
                    f"outputs, or keep the downstream handle instead")
        return {k: v.reshape(req.shapes[k]) for k, v in req.outputs.items()}

    def arrays(self) -> Dict[str, Any]:
        """Reshaped outputs *without* a host sync — still XLA futures,
        exactly what the pre-stream ``KernelFn.launch`` returned.  The
        launch (and everything it depends on) is dispatched first."""
        outs = self.outputs      # dispatch + surface this request's error
        del outs
        return self._reshaped()

    def result(self) -> Dict[str, Any]:
        """Materialize: flush, block on this launch, reshape outputs."""
        self._disp.sync_request(self._req)
        return self._reshaped()


class Stream:
    """An in-order launch queue (CUDA ``cudaStream_t``).

    Launches enqueued on one stream dispatch in program order; launches
    on different streams are unordered unless an :class:`Event` edge —
    or the legacy default stream — connects them.  The **default
    stream** has CUDA's legacy-sync semantics: a launch on it is ordered
    after the current tail of *every* stream, and every stream's next
    launch is ordered after the default stream's tail.

    While a stream is **capturing** into a :class:`~repro.core.graphs.
    Graph` (``begin_capture()``/``end_capture()``, CUDA's
    ``cudaStreamBeginCapture``), launches record graph nodes instead of
    dispatching, and host-blocking operations (``synchronize``, waiting
    on eager events) raise :class:`CoxUnsupported` — exactly the set of
    operations cudaStreamCapture invalidates a capture over."""

    _names = itertools.count()

    def __init__(self, name: Optional[str] = None,
                 dispatcher: Optional["Dispatcher"] = None, *,
                 priority: int = 0, device: Any = None,
                 _default: bool = False):
        self._disp = dispatcher if dispatcher is not None else get_dispatcher()
        self._default = _default
        self.name = name or ("default" if _default
                             else f"stream{next(self._names)}")
        # dispatch priority (CUDA cudaStreamCreateWithPriority): lower
        # numbers dispatch first among simultaneously-ready requests
        self.priority = int(priority)
        # placement: an explicit device pins every launch on this stream
        # to it; otherwise the dispatcher's placement policy assigns one
        # on first dispatch (multi-device pools only) and the stream
        # keeps it — device affinity — until it is poisoned
        self._device = device
        self._device_pinned = device is not None
        self._wait_deps: List[int] = []   # event edges for the next launch
        self._capture = None              # Graph while capturing, else None
        self._capture_deps: List[int] = []   # captured event edges (node idx)
        # first un-surfaced failure on this stream: while set, subsequent
        # launches on the stream fail fast with CoxDependencyError (they
        # are program-order descendants of the failed request).  Cleared
        # when the error is surfaced to the caller, or by reset().
        self._error: Optional[BaseException] = None

    def __repr__(self):
        return f"Stream({self.name!r})"

    @property
    def is_default(self) -> bool:
        return self._default

    @property
    def device(self) -> Any:
        """The device this stream's launches run on: its pin, the
        placement policy's assignment, or ``None`` (unplaced — the
        legacy single-device path)."""
        return self._device

    @property
    def dispatcher(self) -> "Dispatcher":
        return self._disp

    def launch(self, kern, *, grid, block, args, **knobs) -> LaunchHandle:
        """Enqueue ``kern<<<grid, block>>>(*args)`` on this stream and
        return a :class:`LaunchHandle` immediately.  ``kern`` is an
        ``api.KernelFn``; ``knobs`` are the usual launch knobs
        (``backend=``, ``warp_exec=``, ``donate=``, ...).

        Dispatch is **eager**, exactly like a CUDA launch: the request
        (and anything still pending) goes straight through the
        dispatcher's topological flush into XLA's async dispatch, so
        the kernel starts executing while the host issues the next
        launch — the handle only defers the *wait*, never the work.
        Enqueue order is always a legal linearization (an event edge
        requires its ``record`` to precede the ``wait``), so eager
        dispatch can never violate a dependency.

        While capturing, the request is recorded as a graph node instead
        of dispatching, and the returned handle's ``.outputs`` /
        ``.arrays()`` hand back :class:`~repro.core.types.GraphRef`
        placeholders for chaining captured launches."""
        req = kern.make_request(grid=grid, block=block, args=args, **knobs)
        if self._capture is not None:
            return self._capture.add_request(req, stream=self)
        handle = self._disp.enqueue(req, self)
        self._disp.flush()
        return handle

    # ---------------- stream capture (CUDA graphs) ----------------

    def begin_capture(self, graph=None):
        """Start capturing this stream's schedule into ``graph`` (a new
        :class:`~repro.core.graphs.Graph` when ``None``) — CUDA
        ``cudaStreamBeginCapture``.  Returns the graph."""
        from . import graphs as _graphs      # late: graphs imports streams
        if self._capture is not None:
            raise CoxUnsupported(
                f"{self!r} is already capturing into "
                f"{self._capture!r} — end_capture() first")
        g = graph if graph is not None else _graphs.Graph()
        g._attach_stream(self)
        self._capture = g
        self._capture_deps = []
        self._disp._capturing.add(self)
        return g

    def end_capture(self):
        """End capture and return the captured graph (CUDA
        ``cudaStreamEndCapture``)."""
        if self._capture is None:
            raise CoxUnsupported(
                f"{self!r}.end_capture() without begin_capture()")
        g = self._capture
        g._detach_stream(self)
        self._capture = None
        self._capture_deps = []
        self._disp._capturing.discard(self)
        return g

    @property
    def capturing(self) -> bool:
        return self._capture is not None

    def wait_event(self, event: "Event") -> None:
        """All *subsequent* launches on this stream wait for ``event``
        (CUDA ``cudaStreamWaitEvent``).  Waiting on an unrecorded event
        is a no-op, as on CUDA."""
        event.wait(self)

    def record_event(self, event: Optional["Event"] = None) -> "Event":
        """Record (a new) event at this stream's current tail."""
        ev = event if event is not None else Event()
        ev.record(self)
        return ev

    def synchronize(self) -> None:
        """Block the host until every launch enqueued on this stream has
        completed.  Idempotent — synchronizing an already-idle stream is
        a no-op.  Illegal during capture (a capture records a schedule,
        it runs nothing — there is nothing to wait for, and CUDA
        invalidates the capture)."""
        if self._capture is not None:
            raise CoxUnsupported(
                f"{self!r}.synchronize() during stream capture — a "
                f"capture records the schedule without running it; "
                f"end_capture() first (cudaStreamSynchronize in a "
                f"capture invalidates it)")
        self._disp.sync_stream(self)

    # ---------------- error state (stream poisoning) ----------------

    @property
    def error(self) -> Optional[BaseException]:
        """The stream's first un-surfaced failure, or ``None`` when the
        stream is healthy.  While set, every subsequent launch on this
        stream fails fast with :class:`~repro.core.errors.
        CoxDependencyError` — CUDA's stream-poisoning behavior.  The
        state clears when the error is surfaced (a sync/``result()``/
        ``outputs`` raises it, or ``get_last_error()`` consumes it) or
        via :meth:`reset`."""
        return self._error

    def reset(self) -> "Stream":
        """Clear the stream's non-sticky error state and pending event
        edges so new work can be enqueued — the recovery point for a
        caller that dropped a failed handle without surfacing it.  A
        sticky device error is *not* cleared (only
        :func:`device_reset` is the ``cudaDeviceReset`` analogue)."""
        if self._capture is not None:
            raise CoxUnsupported(
                f"{self!r}.reset() during stream capture — "
                f"end_capture() first")
        self._error = None
        self._wait_deps = []
        # retire this stream's retained failed requests too: the next
        # launch's program-order tail points at them, and an un-surfaced
        # failure there would re-poison the fresh start
        self._disp.release_stream_errors(self)
        return self

    def _consume_wait_deps(self) -> List[int]:
        deps, self._wait_deps = self._wait_deps, []
        return deps

    def _consume_capture_deps(self) -> List[int]:
        deps, self._capture_deps = self._capture_deps, []
        return deps


class Event:
    """CUDA-style event: a recorded point in a stream's program order.

    ``record(stream)`` captures the stream's current tail;
    ``wait(stream)`` orders another stream's subsequent launches after
    that point; ``synchronize()`` blocks the host until the recorded
    work completed and stamps the completion time; ``elapsed(end)``
    returns milliseconds between two events' stamps.  Timing caveat:
    the stamp is taken when completion is first *observed* (at a
    ``synchronize()``), not at true device completion — synchronize
    promptly for tight timings."""

    def __init__(self):
        self._req: Optional[LaunchRequest] = None
        self._disp: Optional[Dispatcher] = None
        self._recorded = False
        self._t_done: Optional[float] = None
        self._graph = None                 # capture graph, when recorded there
        self._gnode = None                 # captured tail node (None: idle)

    def record(self, stream: Optional[Stream] = None) -> "Event":
        stream = stream if stream is not None else get_dispatcher().default
        self._disp = stream.dispatcher
        if stream._capture is not None:
            # capture-recorded: the event marks the stream's captured
            # tail node — a schedule edge, not a completion point
            self._graph = stream._capture
            self._gnode = stream._capture._tail_node(stream)
            self._req = None
            self._recorded = True
            self._t_done = None
            return self
        self._graph = self._gnode = None
        self._req = self._disp.tail_request(stream)   # None: empty stream
        self._recorded = True
        # recording on an idle stream completes immediately (CUDA: an
        # event completes once all preceding stream work has) — stamp now
        self._t_done = None if self._req is not None else time.perf_counter()
        return self

    def wait(self, stream: Stream) -> None:
        if not self._recorded:
            return                       # CUDA: wait-before-record is a no-op
        if self._graph is not None:      # capture-recorded event
            if stream._capture is None:
                raise CoxUnsupported(
                    f"eager stream {stream.name!r} cannot wait on an "
                    f"event recorded during capture — the captured "
                    f"schedule has not run; wait inside the same "
                    f"capture or replay the graph first")
            if stream._capture is not self._graph:
                raise CoxUnsupported(
                    f"stream {stream.name!r} is capturing into a "
                    f"different graph than the one this event was "
                    f"recorded in — cross-graph event edges are not "
                    f"capturable")
            if self._gnode is not None:
                stream._capture_deps.append(self._gnode.idx)
            return
        if stream._capture is not None:
            raise CoxUnsupported(
                f"capturing stream {stream.name!r} cannot wait on an "
                f"event recorded outside its capture — CUDA invalidates "
                f"the capture; record the event inside the capture")
        if self._req is None:
            return
        stream._wait_deps.append(self._req.seq)

    def query(self) -> bool:
        """True when the recorded work has completed (never blocks).
        Illegal for a capture-recorded event — captured work never runs
        until replay, so completion is not a meaningful question."""
        if self._graph is not None:
            raise CoxUnsupported(
                "Event.query() on an event recorded during stream "
                "capture — the captured schedule runs only at "
                "graph.replay(); a capture event is a schedule edge, "
                "not a completion point")
        if not self._recorded:
            return True
        if self._req is None:
            return True
        if not self._req.dispatched or self._req.injected_hang:
            return False
        if self._req.error is not None:
            return True                  # failed work is "complete"
        return _outputs_ready(self._req.outputs)

    def synchronize(self) -> "Event":
        """Block until the recorded work completed; idempotent.  The
        first call stamps the event's completion time."""
        if self._graph is not None:
            raise CoxUnsupported(
                "Event.synchronize() on an event recorded during stream "
                "capture — the captured schedule runs only at "
                "graph.replay()")
        if not self._recorded:
            raise CoxUnsupported("Event.synchronize() before record()")
        if self._req is not None:
            self._disp.sync_request(self._req)
        if self._t_done is None:
            self._t_done = time.perf_counter()
        return self

    def elapsed(self, end: "Event") -> float:
        """Milliseconds between this (start) event and ``end`` — CUDA
        ``cudaEventElapsedTime``.  Synchronizes both events."""
        self.synchronize()
        end.synchronize()
        return (end._t_done - self._t_done) * 1e3

    elapsed_time = elapsed   # cupy-style alias


class Dispatcher:
    """Host-side launch scheduler + the shared staging cache.

    :meth:`flush` topologically orders the pending request graph —
    stream program order plus event edges, FIFO (enqueue-order)
    tie-break — and dispatches each request's staged executable via
    XLA's **async dispatch**: the ``exe(...)`` call returns futures
    immediately, so a dispatched kernel executes while the host binds
    and dispatches later requests.  Stream launches flush eagerly (a
    CUDA launch starts the kernel, it does not queue it on the host);
    requests can still sit pending between ``enqueue`` and ``flush``
    when the dispatcher is driven directly.  Nothing in the dispatch
    path calls ``block_until_ready``.

    Staged executables are cached here, keyed on kernel identity plus
    the request's resolved geometry/knobs (``LaunchRequest.stage_key``),
    so every stream — and the synchronous ``KernelFn.launch`` path —
    shares one staging per distinct launch shape."""

    def __init__(self, stage_cache_size: int = STAGE_CACHE_SIZE,
                 dispatch_log_max: int = DISPATCH_LOG_MAX, *,
                 launch_deadline_s: Optional[float] = None,
                 max_strikes: int = 8,
                 error_log_max: int = ERROR_LOG_MAX,
                 retry_limit: int = RETRY_LIMIT,
                 retry_backoff_s: float = RETRY_BACKOFF_S,
                 devices: Optional[Tuple[Any, ...]] = None,
                 placement: Optional[Any] = None):
        # _lock guards the queues/caches and is only ever held briefly;
        # _dispatch_lock serializes whole flush drains so concurrent
        # flushes cannot interleave dispatch out of dependency order,
        # while staging (JAX trace/compile) runs with only it held —
        # other threads' enqueues/syncs never wait on a compile
        self._lock = threading.RLock()
        self._dispatch_lock = threading.Lock()
        self._stage_cache_size = stage_cache_size
        self._staged: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._staged_fns: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._pending: "OrderedDict[int, LaunchRequest]" = OrderedDict()
        self._inflight: Dict[int, LaunchRequest] = {}
        # stream -> weakref to its tail request.  Both sides are weak on
        # purpose: a pending/in-flight request is kept alive by
        # _pending/_inflight (and keeps its stream alive via req.stream),
        # while a *completed* request whose handle was dropped may be
        # collected — ordering against completed work is vacuous, so a
        # dead tail simply means "no edge needed".
        self._tails: "weakref.WeakKeyDictionary[Stream, Any]" = \
            weakref.WeakKeyDictionary()
        self._seq = itertools.count()
        # bounded structurally: maxlen evicts the oldest entries, so a
        # long-lived serving loop cannot grow per-launch host state
        self.dispatch_log: Deque[int] = deque(maxlen=dispatch_log_max)
        self.stage_hits = 0
        self.stage_misses = 0
        self.stage_fn_hits = 0
        self.stage_fn_misses = 0
        # ---- telemetry (README "Autotune & telemetry") ----
        # per-stage-key live counters: launches, host dispatch seconds,
        # estimated bytes/FLOPs (repro.core.costmodel), plus measured
        # wall seconds when a caller notes them (note_measurement) —
        # benchmarks/roofline.py computes roofline position from these
        # real counters instead of dry-run JSON
        self._telemetry: "OrderedDict[tuple, Dict[str, Any]]" = OrderedDict()
        self._capturing: "weakref.WeakSet[Stream]" = weakref.WeakSet()
        # ---- fault tolerance (README "Error model & fault tolerance") ----
        # errored requests whose handle was dropped without a sync move
        # here (bounded, oldest evicted) so a long-lived serving loop
        # stays bounded under repeated failures; surfaced via
        # get_last_error() / error_log
        self.error_log_max = error_log_max
        self._errored: "OrderedDict[int, LaunchRequest]" = OrderedDict()
        # id(output array) -> (weakref-or-None, producer seq): the data
        # edges behind handle.outputs chaining.  An entry lives exactly
        # as long as its producer sits in _inflight/_errored — the
        # producer's req.outputs holds the array strongly, so the id
        # cannot be recycled while the entry exists.
        self._out_producers: Dict[int, Tuple[Any, int]] = {}
        # device-poisoning errors, scoped per device: key = device id of
        # the placed request that faulted, or None for an unplaced
        # (legacy single-device / default-stream / mesh) fault — the
        # process-wide CUDA behavior.  Placement routes new work around
        # poisoned devices; enqueue only fails once *no* healthy device
        # remains (which on a one-device pool is the first sticky fault,
        # exactly the old contract).
        self._sticky: "OrderedDict[Optional[int], BaseException]" = \
            OrderedDict()
        self._last_error: Optional[BaseException] = None   # cudaGetLastError
        # ---- multi-device placement (repro.core.placement) ----
        # the device pool is lazy: this constructor runs at module import
        # (the default dispatcher singleton) and must not initialize jax
        self._devices = tuple(devices) if devices is not None else None
        self.placement = placement       # policy; defaults to round-robin
        # per-device dispatch counters: str(device) (or "default" for
        # unplaced work) -> {dispatches, failures, degradations}
        self._dev_counters: Dict[str, Dict[str, int]] = {}
        # device-id -> display name, learned as devices pass through
        # (labels sticky-map keys without resolving the lazy pool)
        self._dev_names: Dict[int, str] = {}
        self.launch_deadline_s = launch_deadline_s
        self.max_strikes = max_strikes
        self.retry_limit = retry_limit
        self.retry_backoff_s = retry_backoff_s
        self.failures = 0        # requests that ended with an error
        self.retries = 0         # transient-failure retry attempts
        self.degradations = 0    # ladder fallbacks taken
        self.timeouts = 0        # launches killed by the deadline
        self.degradation_log: Deque[Dict[str, Any]] = \
            deque(maxlen=DEGRADATION_LOG_MAX)
        self.watchdog: Optional[StepWatchdog] = None   # lazily armed
        self._wd_lock = threading.Lock()   # serializes deadline awaits
        self.default = Stream(dispatcher=self, _default=True)

    # ---------------- placement (multi-device scale-out) ----------------

    @property
    def devices(self) -> Tuple[Any, ...]:
        """The device pool streams are placed over (default: every jax
        device, resolved lazily so constructing a dispatcher — including
        the import-time singleton — never initializes jax)."""
        devs = self._devices
        if devs is None:
            devs = self._devices = tuple(jax.devices())
        return devs

    def _healthy_devices(self) -> List[Any]:
        with self._lock:
            poisoned = set(self._sticky) - {None}
        return [d for d in self.devices if d.id not in poisoned]

    def _sticky_blocking(self) -> Optional[BaseException]:
        """The sticky error that must fail an enqueue/sync outright:
        an unplaced (device-less) sticky fault poisons the process —
        the CUDA contract — while placed faults only block once every
        device in the pool is poisoned (placement routes around
        anything less)."""
        with self._lock:
            if not self._sticky:
                return None
            glob = self._sticky.get(None)
            if glob is not None:
                return glob
            if not self._healthy_devices():
                return next(iter(self._sticky.values()))
            return None

    def _sticky_for(self, device) -> Optional[BaseException]:
        """The sticky error covering a request bound for ``device``:
        its own device's, or — for unplaced work, which runs on the
        pool's first device — that device's.  Caller holds ``_lock``."""
        glob = self._sticky.get(None)
        if glob is not None:
            return glob
        if not self._sticky:
            return None
        if device is not None:
            return self._sticky.get(device.id)
        devs = self.devices
        return self._sticky.get(devs[0].id) if devs else None

    def _place(self, req: LaunchRequest) -> None:
        """Assign the request a target device (fills ``req.device``).
        Explicitly placed requests, mesh (sharded) launches, default-
        stream launches, and single-device pools keep ``device=None`` —
        the exact legacy dispatch path, no transfers.  Raises the first
        sticky error when no healthy device remains."""
        if req.device is not None or req.mesh is not None:
            return
        devices = self.devices
        if len(devices) <= 1:
            return
        s = req.stream
        if s is None or s.is_default:
            return                   # CUDA: default stream = current device
        if s._device_pinned:
            req.device = s._device
            return
        healthy = self._healthy_devices()
        if not healthy:
            err = self._sticky_blocking()
            if err is not None:
                raise err
            healthy = list(devices)      # racing device_reset: pool is back
        pol = self.placement
        if pol is None:
            pol = self.placement = _placement.RoundRobinPlacement()
        req.device = pol.place(req, healthy, self)

    @staticmethod
    def _dev_of(req: "LaunchRequest"):
        """The device a request's counters attribute to: its placement,
        else its stream's (a descendant failed *before* placement still
        belongs to its stream's device), else None (unplaced)."""
        if req.device is not None:
            return req.device
        s = req.stream
        return s._device if s is not None else None

    def _bump_dev(self, device, key: str) -> None:
        """Per-device health counter bump.  Caller holds ``_lock``."""
        name = str(device) if device is not None else "default"
        c = self._dev_counters.get(name)
        if c is None:
            c = self._dev_counters[name] = {
                "dispatches": 0, "failures": 0, "degradations": 0}
        c[key] += 1

    def device_health(self) -> Dict[str, Dict[str, int]]:
        """Per-device dispatch counters, keyed by ``str(device)``
        (``"default"`` collects unplaced work) — what
        :class:`~repro.core.placement.HealthAwarePlacement` reads."""
        with self._lock:
            return {k: dict(v) for k, v in self._dev_counters.items()}

    # ---------------- enqueue ----------------

    def enqueue(self, req: LaunchRequest, stream: Stream) -> LaunchHandle:
        """Assign the request its place in the launch order: program
        order on its stream, pending event edges, and the default
        stream's legacy-sync edges."""
        if req.globals_:
            for name, val in req.globals_.items():
                if isinstance(val, GraphRef):
                    raise CoxUnsupported(
                        f"kernel '{req.ck.kernel.name}': argument "
                        f"'{name}' is a capture placeholder ({val!r}) "
                        f"that escaped its graph — captured outputs "
                        f"only exist inside the capture; replay the "
                        f"graph and use its real outputs instead")
        blocking = self._sticky_blocking()
        if blocking is not None:
            # CUDA: after a sticky error every launch fails synchronously
            # with that error until cudaDeviceReset (device_reset here).
            # With a multi-device pool this only fires once every device
            # is poisoned — placement routes around anything less.
            raise blocking
        with self._lock:
            req.seq = next(self._seq)
            req.stream = stream
            req.priority = stream.priority
            if req.device is None and stream._device_pinned:
                req.device = stream._device
            deps = []
            tail = self.tail_request(stream)
            if tail is not None:
                deps.append(tail.seq)            # in-order within the stream
            if stream.is_default:
                # legacy sync: the default stream is ordered after the
                # current tail of every other stream
                for s in list(self._tails):
                    if s is stream:
                        continue
                    t = self._tails[s]()
                    if t is not None:
                        deps.append(t.seq)
            else:
                dt = self.tail_request(self.default)
                if dt is not None:
                    deps.append(dt.seq)          # ...and every stream after it
            deps.extend(stream._consume_wait_deps())
            req.deps = tuple(sorted(set(deps)))
            if req.globals_:
                # handle.outputs data edges: an argument that is a live
                # launch output makes this request a DAG descendant of
                # its producer
                ddeps = {self._producer_seq(v) for v in req.globals_.values()}
                ddeps.discard(None)
                req.data_deps = tuple(sorted(ddeps))
            self._pending[req.seq] = req
            self._tails[stream] = weakref.ref(req)
            return LaunchHandle(req, self)

    def _producer_seq(self, val) -> Optional[int]:
        """The in-flight/errored producer seq of ``val``, if ``val`` is
        one of its raw output arrays (identity-checked — ``id()`` alone
        is not trusted across object lifetimes)."""
        try:
            entry = self._out_producers.get(id(val))
        except TypeError:
            return None
        if entry is None:
            return None
        ref, seq = entry
        if ref is not None and ref() is not val:
            return None
        return seq

    def tail_request(self, stream: Stream) -> Optional[LaunchRequest]:
        with self._lock:
            ref = self._tails.get(stream)
            return ref() if ref is not None else None

    # ---------------- staging (the shared launch cache) ----------------

    def stage(self, req: LaunchRequest):
        """Resolve the request to a staged ``(plan, exe)``, shared
        across streams.  ``id(ck)`` is safe in the key because the
        cached plan holds a strong reference to the same ck — the id
        cannot be recycled while the entry lives.

        The executable is the jit wrap of the raw launcher from
        :meth:`stage_fn`, so eager staging and graph staging share one
        trace recipe per launch shape — a graph capturing a kernel the
        streams already launched re-traces nothing, and vice versa."""
        key = (id(req.ck),) + req.stage_key()
        with self._lock:
            hit = self._staged.get(key)
            if hit is not None:
                self._staged.move_to_end(key)
                self.stage_hits += 1
                return hit
        plan, fn = self.stage_fn(req)
        staged = (plan, jax.jit(fn, donate_argnums=(0,) if req.donate
                                else ()))
        with self._lock:
            self.stage_misses += 1
            self._staged[key] = staged
            while len(self._staged) > self._stage_cache_size:
                self._staged.popitem(last=False)
        return staged

    def stage_fn(self, req: LaunchRequest):
        """Resolve the request to its *raw* (un-jitted) launcher,
        ``(plan, fn)`` — the form the graph tracer inlines.  Cached
        separately from :meth:`stage` (an fn is a trace recipe, an exe
        is a compiled program) but shared across every graph that
        captures the same launch shape, so two graphs over the same
        kernel trace it once."""
        key = (id(req.ck),) + req.fn_key()
        with self._lock:
            hit = self._staged_fns.get(key)
            if hit is not None:
                self._staged_fns.move_to_end(key)
                self.stage_fn_hits += 1
                return hit
        staged = _runtime.build_traceable(
            req.ck, req.rl, simd=req.simd, mesh=req.mesh, axis=req.axis,
            chunk=req.chunk)
        with self._lock:
            self.stage_fn_misses += 1
            self._staged_fns[key] = staged
            while len(self._staged_fns) > self._stage_cache_size:
                self._staged_fns.popitem(last=False)
        return staged

    def stage_graph(self, key: tuple, builder):
        """Stage a captured graph's fused executable in the shared LRU.
        ``key`` starts with the literal ``"graph"`` tag (so
        :meth:`cache_view`'s kernel-id filter never surfaces graph
        entries) followed by the captured DAG's per-node stage keys —
        two structurally identical captures hit the same executable.
        ``builder()`` runs without the queue lock (it traces)."""
        with self._lock:
            hit = self._staged.get(key)
            if hit is not None:
                self._staged.move_to_end(key)
                self.stage_hits += 1
                return hit
        staged = builder()
        with self._lock:
            self.stage_misses += 1
            self._staged[key] = staged
            while len(self._staged) > self._stage_cache_size:
                self._staged.popitem(last=False)
        return staged

    def cache_view(self, cks) -> Dict[tuple, tuple]:
        """The staged entries for the given compiled kernels, keyed
        without the kernel-identity element — the backward-compatible
        ``KernelFn._launch_cache`` shape."""
        ids = {id(ck) for ck in cks}
        with self._lock:
            return {k[1:]: v for k, v in self._staged.items() if k[0] in ids}

    # ---------------- dispatch ----------------

    def _toposorted(self) -> List[LaunchRequest]:
        """Kahn's algorithm over the pending graph: edges are stream
        program order + event edges (``req.deps``, restricted to
        still-pending requests).  The ready-set is a priority heap:
        among simultaneously-ready requests the lowest stream priority
        number dispatches first (latency-sensitive streams preempt bulk
        work in the issue order), with FIFO enqueue-order tie-break so
        dispatch stays deterministic."""
        pending = self._pending
        indeg = {seq: sum(1 for d in r.deps if d in pending)
                 for seq, r in pending.items()}
        ready = [(pending[seq].priority, seq)
                 for seq, n in indeg.items() if n == 0]
        out: List[LaunchRequest] = []
        fwd: Dict[int, List[int]] = {}
        for seq, r in pending.items():
            for d in r.deps:
                if d in pending:
                    fwd.setdefault(d, []).append(seq)
        heapq.heapify(ready)
        while ready:
            _, seq = heapq.heappop(ready)
            out.append(pending[seq])
            for nxt in fwd.get(seq, ()):
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    heapq.heappush(ready, (pending[nxt].priority, nxt))
        if len(out) != len(pending):     # impossible by construction:
            raise AssertionError("cycle in launch-dependency graph")
        return out

    def _dispatch(self, req: LaunchRequest) -> None:
        name = req.ck.kernel.name
        if req.error is not None:         # already failed fast (descendant)
            self._finish_failed(req)
            return
        with self._lock:
            dep_err = self._first_dep_error(req)
        if dep_err is not None:
            # fail fast: never dispatch on a failed upstream's stale
            # outputs — CUDA's poisoned stream simply never runs these
            root = _errors.root_of(dep_err)
            self._fail_request(req, CoxDependencyError(
                f"kernel '{name}' (seq {req.seq}) not dispatched: "
                f"upstream failure {type(root).__name__}: {root}",
                root=root))
            return
        try:
            self._place(req)             # fills req.device (policy/pin)
        except Exception as e:
            self._fail_request(req, e)
            return
        with self._lock:
            sticky = self._sticky_for(req.device)
        if sticky is not None:
            # the request's target device is poisoned (explicit pin, or
            # unplaced work on a poisoned first device): fail it with
            # the device's sticky error — placement never *chooses* a
            # poisoned device, so a policy-placed request cannot land here
            self._fail_request(req, sticky)
            return
        try:
            outputs = self._run_attempts(req, name)   # stage + async dispatch
        except Exception as e:            # surfaces at *this* request's sync
            self._fail_request(req, e)
            return
        req.outputs = outputs
        req.dispatched = True
        req.globals_ = None               # release (or donated) inputs
        req.scalars = None
        with self._lock:
            for o in outputs.values():
                try:
                    ref = weakref.ref(o)
                except TypeError:
                    ref = None
                self._out_producers[id(o)] = (ref, req.seq)
                req.out_ids.append(id(o))
            self._inflight[req.seq] = req
            self.dispatch_log.append(req.seq)   # deque: maxlen-bounded
            self._bump_dev(self._dev_of(req), "dispatches")

    def _first_dep_error(self, req: LaunchRequest) -> Optional[BaseException]:
        """The first un-surfaced failure among the request's DAG parents
        (program order + event edges + data edges) or on its stream.
        Caller holds ``_lock``."""
        for d in sorted(set(req.deps) | set(req.data_deps)):
            r = (self._inflight.get(d) or self._errored.get(d)
                 or self._pending.get(d))
            if r is not None and r.error is not None and not r.surfaced:
                return r.error
        s = req.stream
        if s is not None and s._error is not None:
            return s._error
        return None

    def _fail_request(self, req: LaunchRequest, err: BaseException) -> None:
        req.error = err
        self._finish_failed(req)

    def _finish_failed(self, req: LaunchRequest) -> None:
        """Bookkeeping for a request that failed at (or before) dispatch:
        record it, poison its stream, update the error registers."""
        req.dispatched = True
        req.globals_ = None
        req.scalars = None
        with self._lock:
            self._inflight[req.seq] = req
            self.dispatch_log.append(req.seq)
            self._last_error = req.error
            self.failures += 1
            self._bump_dev(self._dev_of(req), "failures")
            if _errors.is_sticky(req.error):
                self._note_sticky_locked(req.device, req.error)
            if req.stream is not None and req.stream._error is None:
                req.stream._error = req.error

    # -------- attempts: retry ladder + graceful degradation --------

    def _ladder(self, req: LaunchRequest) -> List[Tuple[Any, str]]:
        """The fallback rungs for this request, most-capable first.
        Only knobs the caller left on ``'auto'`` may degrade — an
        explicitly requested backend/warp_exec is honored and fails as
        requested.  Every rung computes bitwise-identical outputs by
        the backend-equivalence contract (scan/serial is the reference
        semantics every other cell is tested against)."""
        rungs: List[Tuple[Any, str]] = [(req.rl, "as-resolved")]
        rl = req.rl
        if rl.warp_exec == "batched" and req.req_warp_exec == "auto":
            rl = dataclasses.replace(rl, warp_exec="serial")
            rungs.append((rl, "warp_exec=serial"))
        if rl.backend == "vmap" and req.req_backend == "auto":
            rl = dataclasses.replace(rl, backend="scan")
            rungs.append((rl, "backend=scan"))
        return rungs

    def _run_attempts(self, req: LaunchRequest, name: str) -> Dict[str, Any]:
        """Try the request down its degradation ladder; each rung gets
        the bounded transient retry.  A sticky error aborts the ladder
        (the device is gone, no rung can help)."""
        rungs = self._ladder(req)
        last: Optional[BaseException] = None
        for i, (rl, tag) in enumerate(rungs):
            req.rl = rl                  # re-stage on this rung's knobs
            try:
                return self._attempt_with_retry(req, name)
            except Exception as e:
                if _errors.is_sticky(e):
                    raise
                last = e
                if i + 1 < len(rungs):
                    event = {"kernel": name, "seq": req.seq,
                             "from": tag, "to": rungs[i + 1][1],
                             "error": repr(e)}
                    with self._lock:
                        self.degradations += 1
                        self._bump_dev(self._dev_of(req), "degradations")
                        self.degradation_log.append(event)
        assert last is not None
        raise last

    def _attempt_with_retry(self, req: LaunchRequest,
                            name: str) -> Dict[str, Any]:
        attempt = 0
        while True:
            try:
                return self._attempt(req, name)
            except Exception as e:
                if (_errors.is_sticky(e) or not _errors.is_transient(e)
                        or attempt >= self.retry_limit):
                    raise
                with self._lock:
                    self.retries += 1
                time.sleep(self.retry_backoff_s * (2 ** attempt))
                attempt += 1

    def _attempt(self, req: LaunchRequest, name: str) -> Dict[str, Any]:
        """One stage+dispatch attempt, with the fault-injection consults
        (``repro.core.faults``) at each lifecycle site.  Injected
        dispatch faults fire *before* the executable runs, so a donating
        request's buffers survive for the fallback rung."""
        fault = _faults.consume("stage", name)
        if fault is not None:
            raise fault
        try:
            _, exe = self.stage(req)      # may trace/compile — no _lock
        except Exception as e:
            raise _errors.classify(e, site="stage", what=f"kernel '{name}'")
        fault = _faults.consume("sticky-device", name)
        if fault is not None:
            raise fault
        fault = _faults.consume("dispatch", name)
        if fault is not None:
            raise fault
        if req.device is not None:
            # the explicit transfer node: commit inputs to the placed
            # device (async device_put; a no-op returning the same
            # buffer when already resident, preserving donation
            # aliasing).  This is where a cross-stream data edge whose
            # producer landed on another device becomes a D2D copy.
            # Written back onto the request so retry/ladder rungs — and
            # a donating relaunch — reuse the transferred buffers.
            try:
                req.globals_ = {k: _to_device(v, req.device)
                                for k, v in req.globals_.items()}
                if req.scalars:
                    req.scalars = {k: _to_device(v, req.device)
                                   for k, v in req.scalars.items()}
            except Exception as e:
                raise _errors.classify(e, site="dispatch",
                                       what=f"kernel '{name}'")
        try:
            t0 = time.perf_counter()
            outputs = exe(req.globals_, req.scalars)   # async dispatch
            dispatch_s = time.perf_counter() - t0
        except Exception as e:
            raise _errors.classify(e, site="dispatch",
                                   what=f"kernel '{name}'")
        self._note_telemetry(req, dispatch_s)
        if _faults.consume("timeout", name) is not None:
            req.injected_hang = True      # outputs never report ready
        return outputs

    def flush(self) -> None:
        """Dispatch every pending request in topological order.  The
        drain loop holds only the dispatch lock; the queue lock is
        taken just to snapshot a batch, so concurrent enqueues (and
        already-staged launches) never wait on a first-launch compile."""
        with self._dispatch_lock:
            while True:
                with self._lock:
                    if not self._pending:
                        break
                    order = self._toposorted()
                    self._pending = OrderedDict()
                for req in order:
                    self._dispatch(req)
            with self._lock:
                self._prune_inflight()

    def dispatch_through(self, req: LaunchRequest) -> None:
        """Ensure ``req`` (and, by topological order, everything it
        depends on) has been dispatched."""
        if not req.dispatched:
            self.flush()

    def _prune_inflight(self) -> None:
        # DAG descendants of a still-hung launch must stay resident even
        # if their own outputs report ready (only possible under a
        # simulated hang): when the hang resolves into CoxTimeoutError,
        # _fail_descendants_locked has to find them to fail them.  Deps
        # point at earlier seqs and _inflight iterates in seq order, so
        # one pass tracks hang-blocked seqs transitively.
        blocked: set = set()
        for seq in list(self._inflight):
            r = self._inflight[seq]
            if r.error is not None:
                # retained (bounded) until surfaced — a dropped handle
                # must not leak its request forever
                del self._inflight[seq]
                self._retain_errored(r)
                continue
            if r.injected_hang:
                blocked.add(seq)
                continue                 # "hung": never reports ready
            if blocked and not blocked.isdisjoint((*r.deps, *r.data_deps)):
                blocked.add(seq)
                continue
            if _outputs_ready(r.outputs):
                del self._inflight[seq]
                self._drop_producers(r)

    def _retain_errored(self, r: LaunchRequest) -> None:
        self._errored[r.seq] = r
        while len(self._errored) > self.error_log_max:
            _, old = self._errored.popitem(last=False)
            self._drop_producers(old)

    def _drop_producers(self, req: LaunchRequest) -> None:
        for i in req.out_ids:
            entry = self._out_producers.get(i)
            if entry is not None and entry[1] == req.seq:
                del self._out_producers[i]
        req.out_ids = []

    # ---------------- synchronization ----------------

    def _surface_locked(self, req: LaunchRequest) -> None:
        """The request's error reached the caller: mark it surfaced and
        un-poison its stream if this error is what poisoned it — a
        surfaced non-sticky error leaves the stream usable, exactly
        CUDA's cudaGetLastError contract.  Caller holds ``_lock``."""
        req.surfaced = True
        s = req.stream
        if s is not None and s._error is req.error:
            s._error = None

    def forget(self, req: LaunchRequest) -> None:
        """Drop a request from the in-flight/errored sets (its
        error/result has been surfaced to the caller)."""
        with self._lock:
            self._inflight.pop(req.seq, None)
            self._errored.pop(req.seq, None)
            self._drop_producers(req)
            if req.error is not None:
                self._surface_locked(req)

    def sync_request(self, req: LaunchRequest) -> None:
        """Flush, then block until this request's outputs are ready.
        A failed request raises its typed error *here* — at its own
        sync — and surfacing it reclaims the bookkeeping entry."""
        self.dispatch_through(req)
        if req.error is None:
            self._await_request(req)
        self.forget(req)
        if req.error is not None:
            raise req.error

    def _await_request(self, req: LaunchRequest,
                       extra: Optional[List[LaunchRequest]] = None) -> None:
        """Block until the dispatched request's outputs are ready,
        enforcing the per-launch deadline when configured.  On failure
        (deadline, or an async error surfacing in the wait) the error is
        recorded on ``req`` and its DAG descendants fail fast."""
        deadline = self.launch_deadline_s
        if deadline is None and req.injected_hang:
            deadline = 0.0               # a hang with no deadline would spin
        name = req.ck.kernel.name
        if deadline is None:
            try:
                _block_outputs(req.outputs)
            except Exception as e:
                err = _errors.classify(e, site="dispatch",
                                       what=f"kernel '{name}'")
                self._record_async_failure(req, err, extra)
            return
        with self._wd_lock:              # one deadline wait at a time
            wd = self.watchdog
            if wd is None or wd.deadline_s != deadline:
                wd = StepWatchdog(deadline_s=deadline,
                                  max_strikes=self.max_strikes)
                self.watchdog = wd
            wd.start(step=req.seq)
            try:
                while True:
                    if not req.injected_hang and _outputs_ready(req.outputs):
                        try:
                            _block_outputs(req.outputs)
                        except Exception as e:
                            err = _errors.classify(e, site="dispatch",
                                                   what=f"kernel '{name}'")
                            self._record_async_failure(req, err, extra)
                        return
                    if wd.fired:
                        err = CoxTimeoutError(
                            f"kernel '{name}' (seq {req.seq}) exceeded "
                            f"its launch deadline of {deadline}s")
                        with self._lock:
                            self.timeouts += 1
                        self._record_async_failure(req, err, extra)
                        return
                    time.sleep(DEADLINE_POLL_S)
            finally:
                wd.stop()

    def _record_async_failure(self, req: LaunchRequest, err: BaseException,
                              extra: Optional[List[LaunchRequest]] = None,
                              ) -> None:
        """A failure detected *after* dispatch (deadline expiry, async
        error in the wait): record it and fail the DAG descendants."""
        with self._lock:
            req.error = err
            self._last_error = err
            self.failures += 1
            self._bump_dev(self._dev_of(req), "failures")
            if _errors.is_sticky(err):
                self._note_sticky_locked(req.device, err)
            if req.stream is not None and req.stream._error is None:
                req.stream._error = err
            self._fail_descendants_locked(req, err, extra)

    def _fail_descendants_locked(self, req: LaunchRequest,
                                 err: BaseException,
                                 extra: Optional[List[LaunchRequest]] = None,
                                 ) -> None:
        """Mark every (transitive) DAG descendant of ``req`` failed with
        :class:`CoxDependencyError` — their outputs were computed from
        (or will depend on) a failed launch.  Deps always point to
        earlier seqs, so one ascending pass reaches the fixpoint."""
        root = _errors.root_of(err)
        failed = {req.seq}
        pool: Dict[int, LaunchRequest] = {}
        for r in list(self._pending.values()) + list(self._inflight.values()) \
                + list(extra or ()):
            pool[r.seq] = r
        for seq in sorted(pool):
            r = pool[seq]
            if seq in failed or r.error is not None:
                continue
            if (set(r.deps) | set(r.data_deps)) & failed:
                r.error = CoxDependencyError(
                    f"kernel '{r.ck.kernel.name}' (seq {seq}) depends on "
                    f"failed launch seq {req.seq}: "
                    f"{type(root).__name__}: {root}", root=root)
                if r.stream is not None and r.stream._error is None:
                    r.stream._error = r.error
                failed.add(seq)

    def _take_inflight(self, stream: Optional[Stream]) -> List[LaunchRequest]:
        """Atomically remove (and return, seq-ordered) the in-flight —
        and retained errored — requests of ``stream``, or of every
        stream when ``None``.  The caller blocks on them *outside* the
        lock, so concurrent enqueues/flushes never wait on device
        completion."""
        with self._lock:
            taken = []
            for pool in (self._inflight, self._errored):
                for seq in list(pool):
                    r = pool[seq]
                    if stream is None or r.stream is stream:
                        del pool[seq]
                        taken.append(r)
                        self._drop_producers(r)
            return sorted(taken, key=lambda r: r.seq)

    def sync_stream(self, stream: Optional[Stream]) -> None:
        """Block until every launch enqueued on ``stream`` completed
        (``None``: on any stream).  The *earliest* deferred launch error
        of the synced set is raised, CUDA's async-error-at-sync
        analogue; every error in the set counts as surfaced (the stream
        is left usable unless the error was sticky).  Illegal while any
        stream of this dispatcher is capturing — CUDA invalidates an
        active capture on a device-wide sync."""
        if stream is not None and stream._capture is not None:
            raise CoxUnsupported(
                f"cannot synchronize {stream!r} during stream capture — "
                f"end_capture() first")
        if stream is None and self._capturing:
            names = sorted(s.name for s in self._capturing)
            raise CoxUnsupported(
                f"device-wide synchronize while stream(s) {names} are "
                f"capturing — a capture records the schedule without "
                f"running it; end_capture() first")
        self.flush()
        taken = self._take_inflight(stream)
        for r in taken:
            if r.error is None:
                # a failure here marks descendants in `taken` via extra
                self._await_request(r, extra=taken)
        pairs = [(r.seq, r.error) for r in taken if r.error is not None]
        with self._lock:
            for r in taken:
                if r.error is not None:
                    self._surface_locked(r)
            if stream is not None and stream._error is not None:
                # the poisoning request was evicted/collected — surface
                # the bare stream error so reset-by-sync still works
                pairs.append((float("inf"), stream._error))
                stream._error = None
        if pairs:
            raise min(pairs, key=lambda p: p[0])[1]
        blocking = self._sticky_blocking()
        if blocking is not None:
            raise blocking               # CUDA: sticky errors never clear

    def sync_all(self) -> None:
        """Device-wide barrier (CUDA ``cudaDeviceSynchronize``)."""
        self.sync_stream(None)

    # ------------- error surface (cudaGetLastError analogues) -------------

    @property
    def error_log(self) -> List[LaunchRequest]:
        """The retained (un-surfaced, handle-dropped) failed requests,
        oldest first — bounded at ``error_log_max``."""
        with self._lock:
            return list(self._errored.values())

    def get_last_error(self) -> Optional[BaseException]:
        """Return and *clear* the last launch error (``cudaGetLastError``).
        A sticky error is returned but never cleared — only
        :meth:`device_reset` recovers a poisoned device.  Consuming an
        error counts as surfacing it: matching retained requests are
        marked surfaced and their streams un-poisoned."""
        with self._lock:
            if self._sticky:
                return next(iter(self._sticky.values()))
            err = self._last_error
            self._last_error = None
            if err is not None:
                for pool in (self._errored, self._inflight):
                    for r in list(pool.values()):
                        if r.error is err:
                            self._surface_locked(r)
            return err

    def peek_at_last_error(self) -> Optional[BaseException]:
        """The last launch error without clearing it
        (``cudaPeekAtLastError``)."""
        with self._lock:
            return (next(iter(self._sticky.values())) if self._sticky
                    else self._last_error)

    def release_stream_errors(self, stream: Stream) -> None:
        """Retire (mark surfaced, drop retention for) every failed
        request of ``stream`` — the dispatcher half of
        ``stream.reset()``."""
        with self._lock:
            for pool in (self._inflight, self._errored):
                for seq in list(pool):
                    r = pool[seq]
                    if r.stream is stream and r.error is not None:
                        del pool[seq]
                        self._drop_producers(r)
                        r.surfaced = True
            for r in self._pending.values():
                if r.stream is stream and r.error is not None:
                    r.surfaced = True

    def device_reset(self, device: Any = None) -> "Dispatcher":
        """The ``cudaDeviceReset`` analogue.  With ``device=None``:
        clear every sticky error, the last-error register, every
        retained failed request, and every stream's poisoned state.
        With ``device=`` a device (or device id): clear only *that
        device's* sticky state, so placement resumes routing to it —
        the recovery point for a single poisoned device in a
        multi-device pool (everything else is left untouched).
        In-flight successful work is never disturbed (we have no
        device contexts to tear down)."""
        if device is not None:
            did = device if isinstance(device, int) else device.id
            with self._lock:
                self._sticky.pop(did, None)
            return self
        with self._lock:
            self._sticky.clear()
            self._last_error = None
            for r in self._errored.values():
                self._drop_producers(r)
                r.surfaced = True
            self._errored.clear()
            for seq in list(self._inflight):
                r = self._inflight[seq]
                if r.error is not None:
                    self._drop_producers(r)
                    r.surfaced = True
                    del self._inflight[seq]
            for r in self._pending.values():
                if r.error is not None:
                    r.surfaced = True
            for s in set(self._tails) | {self.default}:
                s._error = None
        return self

    def _note_sticky_locked(self, device, err: BaseException) -> None:
        """Record a sticky error against its device (``None`` = the
        process-wide CUDA contract), remembering the device's display
        name while we hold the object.  Caller holds ``_lock``."""
        did = _dev_id(device)
        self._sticky.setdefault(did, err)
        if did is not None:
            self._dev_names[did] = str(device)

    def _dev_label(self, did: Optional[int]) -> str:
        """Human-readable name for a sticky-map key.  Caller holds
        ``_lock`` (reads ``_devices`` without resolving the lazy pool —
        a health probe must not initialize jax)."""
        if did is None:
            return "unplaced"
        name = self._dev_names.get(did)
        if name is not None:
            return name
        for d in (self._devices or ()):
            if d.id == did:
                return str(d)
        return f"device:{did}"

    # ---------------- telemetry (per-stage-key live counters) --------------

    @staticmethod
    def _telemetry_key(req: LaunchRequest) -> tuple:
        """Human-readable stage identity: one row per distinct
        (kernel, backend, warp_exec, chunk, schedule, geometry,
        device)."""
        rl = req.rl
        return (req.ck.kernel.name, rl.backend, rl.warp_exec,
                rl.chunk, rl.schedule, rl.n_resident,
                rl.grid.astuple(), rl.block.astuple(),
                _dev_id(req.device))

    def _note_telemetry(self, req: LaunchRequest, dispatch_s: float) -> None:
        """Record one dispatched launch against its stage-key row.  The
        cost estimate comes from ``repro.core.costmodel`` (cached per
        launch shape; 'static' by default — ``COX_COSTMODEL=xla``
        upgrades to the compiled program's own cost analysis).  Never
        raises: telemetry must not be able to fail a launch."""
        try:
            est = _costmodel.estimate_request(req)
        except Exception:       # pragma: no cover - estimate never raises
            est = None
        key = self._telemetry_key(req)
        with self._lock:
            rec = self._telemetry.get(key)
            if rec is None:
                rec = self._telemetry[key] = {
                    "launches": 0, "dispatch_s": 0.0, "bytes": 0.0,
                    "flops": 0.0, "op_estimate": 0.0, "mem_estimate": 0.0,
                    "estimate_source": None, "chunk_source":
                        getattr(req.rl, "chunk_source", "heuristic"),
                    "schedule_source":
                        getattr(req.rl, "schedule_source", "heuristic"),
                    "measured_s": 0.0, "measured_launches": 0,
                }
                while len(self._telemetry) > TELEMETRY_MAX:
                    self._telemetry.popitem(last=False)
            else:
                self._telemetry.move_to_end(key)
            rec["launches"] += 1
            rec["dispatch_s"] += dispatch_s
            if est is not None:
                rec["op_estimate"] = est.op_estimate
                rec["mem_estimate"] = est.mem_estimate
                rec["estimate_source"] = est.source
                rec["bytes"] += est.mem_estimate
                rec["flops"] += est.op_estimate

    def note_measurement(self, req: LaunchRequest, seconds: float,
                         launches: int = 1) -> None:
        """Attach measured wall time to a request's stage-key row — the
        benchmark harness and autotuner call this after timing a
        synchronized launch, turning the row's estimates into achieved
        GFLOPS/bandwidth."""
        key = self._telemetry_key(req)
        with self._lock:
            rec = self._telemetry.get(key)
            if rec is None:
                return
            rec["measured_s"] += float(seconds)
            rec["measured_launches"] += int(launches)

    def telemetry(self) -> List[Dict[str, Any]]:
        """The per-stage-key counter rows, with achieved GFLOPS and
        GB/s derived where measured wall time is available (falling
        back to host dispatch time — a lower bound — otherwise)."""
        with self._lock:
            rows = [(k, dict(v)) for k, v in self._telemetry.items()]
        out: List[Dict[str, Any]] = []
        for (name, backend, warp_exec, chunk, schedule, n_resident,
             grid, block, dev), rec in rows:
            rec.update(kernel=name, backend=backend, warp_exec=warp_exec,
                       chunk=chunk, schedule=schedule,
                       n_resident=n_resident, grid=grid, block=block,
                       device=dev)
            n = max(1, rec["launches"])
            if rec["measured_launches"] > 0 and rec["measured_s"] > 0:
                per = rec["measured_s"] / rec["measured_launches"]
                rec["time_basis"] = "measured"
            elif rec["dispatch_s"] > 0:
                per = rec["dispatch_s"] / n
                rec["time_basis"] = "dispatch"
            else:
                per = 0.0
                rec["time_basis"] = "none"
            rec["s_per_launch"] = per
            rec["gflops"] = (rec["op_estimate"] / per / 1e9) if per else 0.0
            rec["gbps"] = (rec["mem_estimate"] / per / 1e9) if per else 0.0
            out.append(rec)
        return out

    def health(self) -> Dict[str, Any]:
        """Counters for monitoring a long-lived dispatcher — the serving
        layer and the benchmark gate read these.  ``devices`` carries
        the per-device dispatch/failure/degradation counters (the
        chaos drill asserts a fault stays confined to one device);
        ``sticky_devices`` the currently-poisoned devices; ``sticky``
        stays the first sticky error's repr (or None) for backward
        compatibility.  ``telemetry_keys``/``dispatch_s``/``bytes``
        summarize the live per-stage-key counters (full rows via
        :meth:`telemetry`); ``autotune`` carries the knob-tuner's
        hit/miss/measurement counters."""
        with self._lock:
            first_sticky = (repr(next(iter(self._sticky.values())))
                            if self._sticky else None)
            schedules: Dict[str, int] = {}
            for k in self._telemetry:        # k[4] is the schedule
                schedules[k[4]] = schedules.get(k[4], 0) + 1
            return {
                "failures": self.failures,
                "retries": self.retries,
                "degradations": self.degradations,
                "timeouts": self.timeouts,
                "errored_retained": len(self._errored),
                "inflight": len(self._inflight),
                "pending": len(self._pending),
                "sticky": first_sticky,
                "sticky_devices": {self._dev_label(k): repr(v)
                                   for k, v in self._sticky.items()},
                "devices": {k: dict(v)
                            for k, v in self._dev_counters.items()},
                "watchdog_strikes": (self.watchdog.strikes
                                     if self.watchdog else 0),
                "telemetry_keys": len(self._telemetry),
                "schedules": schedules,
                "dispatch_s": sum(r["dispatch_s"]
                                  for r in self._telemetry.values()),
                "bytes": sum(r["bytes"] for r in self._telemetry.values()),
                "autotune": _autotune_stats(),
            }


def _autotune_stats() -> Dict[str, int]:
    """The knob-tuner's counters (lazy import: autotune pulls in the
    cost model, which health probes must not pay for eagerly)."""
    try:
        from . import autotune as _autotune
        return _autotune.stats()
    except Exception:           # pragma: no cover - import always works
        return {}


# ---------------------------------------------------------------------------
# module singletons — the process-wide dispatcher and its default stream
# ---------------------------------------------------------------------------

_DISPATCHER = Dispatcher()
default_stream = _DISPATCHER.default


def get_dispatcher() -> Dispatcher:
    return _DISPATCHER


def synchronize() -> None:
    """Device-wide barrier over the default dispatcher."""
    _DISPATCHER.sync_all()


def get_last_error() -> Optional[BaseException]:
    """Return-and-clear the default dispatcher's last launch error —
    the ``cudaGetLastError`` analogue (sticky errors are returned but
    never cleared)."""
    return _DISPATCHER.get_last_error()


def peek_at_last_error() -> Optional[BaseException]:
    """The default dispatcher's last launch error, not cleared — the
    ``cudaPeekAtLastError`` analogue."""
    return _DISPATCHER.peek_at_last_error()


def device_reset(device: Any = None) -> Dispatcher:
    """Clear sticky/poisoned error state on the default dispatcher —
    the ``cudaDeviceReset`` analogue.  ``device=`` scopes the reset to
    one device's sticky state (see :meth:`Dispatcher.device_reset`)."""
    return _DISPATCHER.device_reset(device)
