"""Grid-barrier phase splitting — cooperative ``this_grid().sync()``.

The paper stops at block scope: COX's hierarchical collapsing has no
answer for a grid-wide barrier (Table 1's grid-sync ✗ rows), because its
pthread-per-block runtime would need every block resident and spinning.
Our schedule is functional, which makes the feature tractable: a grid
barrier is a *program split*.  The kernel body is cut at every top-level
``Barrier(GRID)`` into **phases**; each phase is an ordinary kernel
compiled by the unchanged hierarchical-collapsing pipeline, and the
launcher runs the phase executables in sequence with

* **global memory** carried from phase to phase (every block of phase
  *p+1* observes every write of phase *p* — exactly the grid barrier's
  guarantee), and
* **per-block persistent state** — locals that live across the sync
  (CUDA: registers/local memory persist for the thread's lifetime) and
  shared memory (persists for the block's lifetime) — threaded through
  as per-block carries (``(n_warps, W)`` planes for locals, the flat
  shared buffers for shared memory).

Alignment rule: a grid barrier must be reached by **every thread of
every block the same number of times** (CUDA cooperative launch makes a
misaligned grid sync a deadlock).  We enforce the static form of that
contract: grid syncs may only appear at the top level of the kernel
body — never inside ``if``/``while``/``for`` — so the phase count is a
compile-time constant and every block runs the same phase sequence.
"""
from __future__ import annotations

from typing import List, Sequence, Set

from . import kernel_ir as K
from .types import BarrierLevel, CoxUnsupported, ScalarSpec


def _is_grid_barrier(s: K.Stmt) -> bool:
    return isinstance(s, K.Barrier) and s.level == BarrierLevel.GRID


def validate_grid_syncs(kernel: K.Kernel) -> None:
    """Reject grid barriers inside control flow — the static alignment
    contract above.  A sync under ``if (blockIdx.x == 0)`` would have
    block 0 waiting at a barrier the other blocks never reach (deadlock
    on CUDA, UB at best); a sync inside a loop would need a dynamic
    phase count.  Both get a clear error instead of a wrong answer."""
    def rec(stmts: Sequence[K.Stmt], ctx: str):
        for s in stmts:
            if _is_grid_barrier(s) and ctx:
                raise CoxUnsupported(
                    f"grid_sync inside {ctx}: a grid-wide barrier must be "
                    f"reached by every thread of every block the same "
                    f"number of times (CUDA cooperative-launch alignment), "
                    f"so grid syncs are only supported at the top level of "
                    f"the kernel body — hoist the sync out of the "
                    f"conditional (e.g. keep the divergent work inside the "
                    f"branch and sync unconditionally after it)")
            if isinstance(s, K.If):
                rec(s.then_body, "divergent control flow (if)")
                rec(s.else_body, "divergent control flow (if)")
            elif isinstance(s, K.While):
                rec(s.body, "a loop body (dynamic phase count)")
    rec(kernel.body, "")


def split_phases(kernel: K.Kernel) -> List[K.Kernel]:
    """Cut the kernel body at top-level grid barriers into per-phase
    kernels.  A kernel with no grid sync returns ``[kernel]`` unchanged
    (the identity — single-phase programs compile exactly as before).
    Phase kernels share the original's params/shared specs and statement
    objects (type annotations made on the full kernel carry over)."""
    validate_grid_syncs(kernel)
    bodies: List[List[K.Stmt]] = [[]]
    for s in kernel.body:
        if _is_grid_barrier(s):
            bodies.append([])
        else:
            bodies[-1].append(s)
    if len(bodies) == 1:
        return [kernel]
    for body in bodies[:-1]:
        if any(isinstance(s, K.Return) for s in body):
            raise CoxUnsupported(
                "return before a grid_sync: a thread that exits cannot "
                "reach the grid barrier (cooperative-launch deadlock)")
    return [K.Kernel(f"{kernel.name}.phase{i}", kernel.params, kernel.shared,
                     body, source=kernel.source)
            for i, body in enumerate(bodies)]


# ---------------------------------------------------------------------------
# Cross-phase liveness
# ---------------------------------------------------------------------------


def _stmt_names(stmts: Sequence[K.Stmt], out: Set[str]) -> None:
    """Every local-variable name a statement list touches (reads or
    writes), descending into nested control flow."""
    def expr(e):
        if e is not None:
            out.update(K.expr_vars(e))

    for s in stmts:
        if isinstance(s, K.Assign):
            out.add(s.name)
            expr(s.value)
        elif isinstance(s, (K.StoreGlobal, K.StoreShared)):
            expr(s.index)
            expr(s.value)
        elif isinstance(s, K.AtomicRMW):
            expr(s.index)
            expr(s.value)
            if s.dst:
                out.add(s.dst)
        elif isinstance(s, K.WarpCall):
            if s.dst:
                out.add(s.dst)
            for a in s.args:
                expr(a)
        elif isinstance(s, K.If):
            expr(s.cond)
            _stmt_names(s.then_body, out)
            _stmt_names(s.else_body, out)
        elif isinstance(s, K.While):
            expr(s.cond)
            _stmt_names(s.body, out)


def carried_locals(kernel: K.Kernel, phase_kernels: Sequence[K.Kernel]
                   ) -> Set[str]:
    """Locals that must persist across phase boundaries: any variable
    name appearing in more than one phase.  (Conservative — a name
    reused as an unrelated temp in two phases is carried too; that only
    costs a ``(n_warps, W)`` plane in the carry, never correctness.)
    Scalar params are block-uniform inputs, not carried state."""
    uniforms = {p.name for p in kernel.params if isinstance(p, ScalarSpec)}
    per_phase: List[Set[str]] = []
    for pk in phase_kernels:
        names: Set[str] = set()
        _stmt_names(pk.body, names)
        per_phase.append(names - uniforms)
    carried: Set[str] = set()
    seen: Set[str] = set()
    for names in per_phase:
        carried |= names & seen
        seen |= names
    return carried
