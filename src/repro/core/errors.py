"""CUDA-faithful error model: the typed ``CoxError`` hierarchy.

CUDA ships a precise error contract that GPU-to-CPU frameworks
(CuPBoP, Polygeist's transpiler) inherit for free from the driver; a
pure-JAX substrate has to reproduce it deliberately.  The pieces:

* **Typed errors.**  Every failure the dispatch layer records is one of
  a small hierarchy rooted at :class:`CoxError`:
  :class:`CoxCompileError` (staging/trace/compile — CUDA's
  ``cudaErrorInvalidKernelImage`` class), :class:`CoxLaunchError`
  (dispatch/execution — ``cudaErrorLaunchFailure`` class),
  :class:`CoxTimeoutError` (per-launch deadline exceeded at sync —
  ``cudaErrorLaunchTimeout``), :class:`CoxDependencyError` (a DAG
  descendant of a failed launch, failed fast instead of dispatched on
  stale inputs — CUDA has no direct analogue because a poisoned stream
  simply never runs the dependents), and the **sticky**
  :class:`CoxDeviceError` (device/context corruption —
  ``cudaErrorIllegalAddress`` class: unrecoverable without a device
  reset).

* **Sticky vs. non-sticky.**  CUDA distinguishes errors that leave the
  context usable (non-sticky: cleared by ``cudaGetLastError``) from
  those that poison every subsequent call until ``cudaDeviceReset``
  (sticky).  Here :func:`is_sticky` keys the split; the dispatcher
  (``repro.core.streams``) poisons all enqueues after a sticky error
  and only :func:`~repro.core.streams.device_reset` clears it.

* **Transient errors.**  Resource-pressure failures worth a bounded
  retry-with-backoff (allocation pressure, injected transient faults)
  are flagged via :func:`is_transient`; everything else fails over to
  the graceful-degradation ladder or surfaces.

Pre-existing exception types stay meaningful: :class:`~repro.core.
types.CoxUnsupported` / :class:`~repro.core.types.CoxTypeError` are
*user* errors (bad kernel / bad knobs) — :func:`classify` passes them
through unchanged so call sites keep their historical exception types,
and wraps only foreign exceptions (XLA runtime errors, ``ValueError``
from a trace) into the typed hierarchy.
"""
from __future__ import annotations

from typing import Optional

from .types import CoxTypeError, CoxUnsupported


class CoxError(Exception):
    """Base of the typed launch-error hierarchy.

    ``sticky`` — the error poisons the whole dispatcher (device) until
    a reset; ``transient`` — the error is worth a bounded retry."""

    sticky = False
    transient = False

    def __init__(self, *args, transient: Optional[bool] = None):
        super().__init__(*args)
        if transient is not None:
            self.transient = transient


class CoxCompileError(CoxError):
    """Staging failed: the launch could not be traced/compiled."""


class CoxLaunchError(CoxError):
    """Dispatch/execution failed: the staged executable raised."""


class CoxTimeoutError(CoxError):
    """The launch exceeded its deadline (detected at its sync) —
    ``cudaErrorLaunchTimeout``.  Non-sticky here: the deadline is a
    host-side watchdog, not device corruption; the launch's stream is
    poisoned and its DAG descendants fail fast, but the device (the
    dispatcher) stays usable."""


class CoxDependencyError(CoxError):
    """A DAG descendant of a failed launch, failed fast instead of
    dispatched on stale inputs.  ``root`` is the originating error."""

    def __init__(self, *args, root: Optional[BaseException] = None):
        super().__init__(*args)
        self.root = root


class CoxDeviceError(CoxError):
    """Sticky device/context corruption — every subsequent enqueue
    fails with this error until ``cox.device_reset()``."""

    sticky = True


def is_sticky(e: BaseException) -> bool:
    return bool(getattr(e, "sticky", False))


# substrings that mark a foreign exception as resource pressure worth a
# retry (jaxlib surfaces allocation failures with these status tags)
_TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "out of memory", "OOM")


def is_transient(e: BaseException) -> bool:
    """True for errors a bounded retry-with-backoff may clear."""
    if getattr(e, "transient", False):
        return True
    msg = str(e)
    return any(m in msg for m in _TRANSIENT_MARKERS)


def root_of(e: BaseException) -> BaseException:
    """The originating failure behind a (possibly chained) dependency
    error — so a descendant-of-a-descendant still names the root."""
    while isinstance(e, CoxDependencyError) and e.root is not None:
        e = e.root
    return e


def classify(e: BaseException, *, site: str,
             what: str = "") -> BaseException:
    """Map an exception to its typed surface form.

    Cox-typed errors (the hierarchy above plus the user-error types
    ``CoxUnsupported``/``CoxTypeError``) pass through unchanged —
    call sites keep their historical exception types.  Foreign
    exceptions wrap into :class:`CoxCompileError` (``site='stage'``)
    or :class:`CoxLaunchError` (any other site), chained via
    ``__cause__`` so the original traceback survives."""
    if isinstance(e, (CoxError, CoxUnsupported, CoxTypeError)):
        return e
    cls = CoxCompileError if site == "stage" else CoxLaunchError
    prefix = f"{what}: " if what else ""
    wrapped = cls(f"{prefix}{site} failed: {type(e).__name__}: {e}")
    wrapped.__cause__ = e
    return wrapped
