"""Per-launch cost model — op/mem estimates for every staged executable.

tinygrad's ``ASTRunner`` (SNIPPETS.md §1) attaches ``op_estimate``/
``mem_estimate`` to each compiled kernel and logs achieved GFLOPS per
dispatch; this module is that idiom for COX launches.  Two estimate
sources, cheapest first:

* ``static`` — an IR walk: arithmetic-instruction count × threads for
  ops, 2 × bound global bytes for memory (read+write traffic proxy).
  No compile, no trace — cheap enough for the dispatcher to record on
  every launch.
* ``xla``    — the launch's *actual* staged program: lower + compile
  abstractly (``jax.ShapeDtypeStruct`` args, no data) and read
  ``hlo_analysis.xla_cost`` (``compiled.cost_analysis()``), falling
  back to the while-aware HLO parse when the backend reports nothing.
  One extra compile per distinct launch shape — the autotuner and the
  benchmark harness use it; ``COX_COSTMODEL=xla`` forces it on the
  dispatcher's telemetry too.

Both carry the static *kernel features* the autotuner prunes with:
shared-memory footprint, warp peel count, and collective density.
``chunk_footprint``/``stride_footprint`` are the wave residency models
— per-block copies of global memory plus per-warp shared copies, with
the chunked schedule additionally charged for its materialized O(grid)
block-id table — and ``schedule_verdict`` turns them into the
chunked-vs-grid-stride lowering decision (``COX_FOOTPRINT_BUDGET``
overrides the budget so tests can force the stride path).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

from . import kernel_ir as K
from . import flat as _flat
from .execute import CompiledKernel, walk_instrs

# estimate source for the dispatcher's always-on telemetry.  'static'
# (default) never compiles; 'xla' lowers each distinct launch shape once
ENV_MODE = "COX_COSTMODEL"

# residency budget for a chunked wave's schedule-dependent footprint —
# the chunk× copies of global memory plus the materialized O(grid)
# block-id table — sized to a desktop L3.  Launches whose chunked
# footprint blows it are lowered to the grid-stride schedule
# (schedule_verdict below); COX_FOOTPRINT_BUDGET overrides the value
# (positive byte count) so tests/CI can force the grid-stride path on
# small inputs.
FOOTPRINT_BUDGET = 64 << 20
ENV_BUDGET = "COX_FOOTPRINT_BUDGET"

# wave widths the residency sizer considers, widest first — the same
# family as autotune.CHUNK_CANDIDATES so a grid-stride wave and a tuned
# chunk are directly comparable cells
RESIDENT_CANDIDATES = (32, 16, 8, 4, 2, 1)


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """One staged launch's cost record (the ASTRunner fields plus the
    static features the autotuner prunes candidates with)."""
    op_estimate: float        # FLOPs (or arith-op proxy) per dispatch
    mem_estimate: float       # bytes touched per dispatch
    coll_estimate: float      # collective bytes (sharded launches)
    shared_footprint: int     # static shared-memory bytes per block
    peel_count: int           # warp-graph peel blocks (batched-exec cost)
    collective_density: float  # warp collectives per IR instruction
    source: str               # 'xla' | 'static'

    def gflops(self, seconds: float) -> float:
        """Achieved GFLOPS for a measured wall time."""
        if seconds <= 0:
            return 0.0
        return self.op_estimate / seconds / 1e9

    def gbps(self, seconds: float) -> float:
        """Achieved memory bandwidth (GB/s) for a measured wall time."""
        if seconds <= 0:
            return 0.0
        return self.mem_estimate / seconds / 1e9


_cache: Dict[tuple, CostEstimate] = {}
_cache_lock = threading.Lock()
_CACHE_MAX = 1024


def telemetry_mode() -> str:
    mode = os.environ.get(ENV_MODE, "static").strip().lower()
    return mode if mode in ("static", "xla") else "static"


def footprint_budget() -> int:
    """The live residency budget: ``COX_FOOTPRINT_BUDGET`` (a positive
    byte count, validated — garbage raises at the launch that reads it
    rather than silently disabling the model) or the built-in
    ``FOOTPRINT_BUDGET`` default."""
    raw = os.environ.get(ENV_BUDGET)
    if raw is None or not raw.strip():
        return FOOTPRINT_BUDGET
    try:
        val = int(raw.strip())
    except ValueError:
        raise ValueError(
            f"{ENV_BUDGET}={raw!r} is not an integer byte count") from None
    if val <= 0:
        raise ValueError(
            f"{ENV_BUDGET}={raw!r} must be a positive byte count")
    return val


def kernel_features(ck: CompiledKernel) -> Tuple[int, int, float]:
    """Static features: (shared bytes/block, peel count, collective
    density).  Peels come from the compiled warp machines — a batched
    PC machine runs every ``lax.switch`` branch, so peel-heavy kernels
    price warp batching out; collective density is the fraction of
    instructions that are warp collectives (the batched win scales
    with it, BENCH_PR2.json)."""
    shared = _flat.shared_footprint(ck.kernel)
    from .regions import warp_peel_count
    machines = (ck.machine if not ck.phases
                else tuple(p.machine for p in ck.phases))
    if not isinstance(machines, (tuple, list)):
        machines = (machines,)
    peels = sum(warp_peel_count(m) for m in machines)
    instrs = list(walk_instrs(ck))
    n_coll = sum(1 for s in instrs if isinstance(s, K.WarpCall))
    density = n_coll / max(1, len(instrs))
    return shared, peels, density


def global_bytes(ck: CompiledKernel, shapes: Dict[str, tuple]) -> int:
    """Total bytes of the bound global-memory arrays."""
    total = 0
    from .types import ArraySpec
    for spec in ck.kernel.params:
        if not isinstance(spec, ArraySpec):
            continue
        shape = shapes.get(spec.name)
        if shape is None:
            continue
        n = 1
        for d in shape:
            n *= int(d)
        total += n * np.dtype(spec.dtype.jnp).itemsize
    return total


def _per_block_bytes(ck: CompiledKernel, shapes: Dict[str, tuple], *,
                     n_warps: int, warp_exec: str) -> int:
    """One block's resident bytes in a vmap wave: its copy of global
    memory (the write-mask merge's cost) plus its shared memory — per
    warp when the batched plane copies it."""
    shared, _, _ = kernel_features(ck)
    per_block = global_bytes(ck, shapes)
    per_block += shared * (n_warps if warp_exec == "batched" else 1)
    return per_block


def bid_table_bytes(grid: int, chunk: int) -> int:
    """Bytes of the materialized ``(n_chunks, chunk)`` -1-padded block-id
    table the chunked schedule scans over (``LaunchPlan.chunked_bids``)
    — the O(grid) term the grid-stride schedule eliminates."""
    chunk = max(1, int(chunk))
    n_chunks = -(-int(grid) // chunk)
    return n_chunks * chunk * 4          # int32 entries


def chunk_footprint(ck: CompiledKernel, shapes: Dict[str, tuple], *,
                    chunk: int, n_warps: int,
                    warp_exec: str = "serial",
                    grid: Optional[int] = None) -> int:
    """Schedule-dependent resident bytes of the *chunked* schedule:
    ``chunk`` per-block copies of global memory plus shared memory, and
    — when the caller supplies ``grid`` — the materialized O(grid)
    block-id table the chunk walk scans over.  The table term is what a
    smaller chunk cannot shrink (``ceil(grid/chunk) × chunk`` entries ≈
    grid regardless of chunk), which is exactly why an over-budget
    verdict routes to grid-stride instead of clamping."""
    per_block = _per_block_bytes(ck, shapes, n_warps=n_warps,
                                 warp_exec=warp_exec)
    total = int(chunk) * per_block
    if grid is not None:
        total += bid_table_bytes(grid, chunk)
    return total


def stride_footprint(ck: CompiledKernel, shapes: Dict[str, tuple], *,
                     n_resident: int, n_warps: int,
                     warp_exec: str = "serial") -> int:
    """Resident bytes of one grid-stride wave: ``n_resident`` slot
    copies, no table term — block ids are computed in-graph
    (``bid = wave × n_resident + slot``), so the footprint is
    grid-independent."""
    return int(n_resident) * _per_block_bytes(ck, shapes, n_warps=n_warps,
                                              warp_exec=warp_exec)


def resident_slots(ck: CompiledKernel, shapes: Dict[str, tuple], *,
                   grid: int, n_warps: int, warp_exec: str = "serial",
                   budget: Optional[int] = None) -> int:
    """Cost-model-sized grid-stride wave width: the widest
    ``RESIDENT_CANDIDATES`` entry whose :func:`stride_footprint` fits
    the budget, floored at ``min(grid, DEFAULT_CHUNK)``.

    The floor matters: one copy of global memory is live under *every*
    schedule (scan included), so once ``per_block`` alone exceeds the
    budget, shrinking the wave below the default width stops saving
    real memory while multiplying the per-wave merge passes — the
    clamped-chunk fallback's failure mode.  Grid-stride keeps the wave
    useful and spends the budget where width actually helps."""
    from .backends.plan import DEFAULT_CHUNK
    budget = footprint_budget() if budget is None else int(budget)
    floor = min(int(grid), DEFAULT_CHUNK)
    for width in RESIDENT_CANDIDATES:
        if width <= floor:
            break
        if width <= grid and stride_footprint(
                ck, shapes, n_resident=width, n_warps=n_warps,
                warp_exec=warp_exec) <= budget:
            return width
    return max(1, floor)


def schedule_verdict(ck: CompiledKernel, shapes: Dict[str, tuple], *,
                     grid: int, chunk: int, n_warps: int,
                     warp_exec: str = "serial", backend: str = "vmap",
                     budget: Optional[int] = None
                     ) -> Tuple[str, Optional[int]]:
    """Pick the launch schedule from the footprint model: ``('chunked',
    None)`` when the materialized chunk-table schedule fits the budget
    (or the grid is a single wave — there is no table to speak of),
    else ``('grid_stride', n_resident)`` with the wave width sized by
    :func:`resident_slots`.  Pure policy — the caller threads the
    verdict into ``ResolvedLaunch`` with provenance.

    ``backend='scan'`` keys on the block-id sequence alone: scan holds
    one copy of global memory under every schedule, so its only O(grid)
    materialized state is the ``arange(grid)`` it scans over — the
    grid-stride form replaces it with a counted ``fori_loop`` (width 1
    by construction)."""
    grid = int(grid)
    chunk = max(1, int(chunk))
    budget = footprint_budget() if budget is None else int(budget)
    if backend == "scan":
        if bid_table_bytes(grid, 1) > budget:
            return "grid_stride", 1
        return "chunked", None
    if grid <= chunk:
        return "chunked", None
    fits = chunk_footprint(ck, shapes, chunk=chunk, n_warps=n_warps,
                           warp_exec=warp_exec, grid=grid) <= budget
    if fits:
        return "chunked", None
    return "grid_stride", resident_slots(ck, shapes, grid=grid,
                                         n_warps=n_warps,
                                         warp_exec=warp_exec, budget=budget)


def _static_estimate(ck: CompiledKernel, rl, shapes: Dict[str, tuple]
                     ) -> CostEstimate:
    shared, peels, density = kernel_features(ck)
    instrs = list(walk_instrs(ck))
    # arithmetic proxy: every non-structural instruction is ~1 op per
    # thread; warp collectives cost ~log2(W) lane ops
    arith = 0.0
    for s in instrs:
        if isinstance(s, K.WarpCall):
            arith += max(1, int(np.log2(max(2, ck.warp_size))))
        elif not isinstance(s, (K.Barrier,)):
            arith += 1
    threads = rl.grid.total * rl.block.total
    gbytes = global_bytes(ck, shapes)
    return CostEstimate(
        op_estimate=arith * threads,
        mem_estimate=2.0 * gbytes,
        coll_estimate=0.0,
        shared_footprint=shared, peel_count=peels,
        collective_density=density, source="static")


def _abstract_args(ck: CompiledKernel, shapes: Dict[str, tuple]):
    """(globals, scalars) as ``ShapeDtypeStruct`` pytrees matching the
    staged launcher's calling convention (flat 1-D globals)."""
    import jax
    from .types import ArraySpec
    globals_: Dict[str, Any] = {}
    scalars: Dict[str, Any] = {}
    for spec in ck.kernel.params:
        if isinstance(spec, ArraySpec):
            shape = shapes.get(spec.name, (1,))
            n = 1
            for d in shape:
                n *= int(d)
            globals_[spec.name] = jax.ShapeDtypeStruct((n,), spec.dtype.jnp)
        else:
            scalars[spec.name] = jax.ShapeDtypeStruct((), spec.dtype.jnp)
    return globals_, scalars


def _xla_estimate(ck: CompiledKernel, rl, shapes: Dict[str, tuple], *,
                  simd: bool, mesh, axis: str) -> CostEstimate:
    import jax
    from . import runtime as _runtime
    from ..launch import hlo_analysis
    _, fn = _runtime.build_traceable(ck, rl, simd=simd, mesh=mesh, axis=axis)
    g, s = _abstract_args(ck, shapes)
    compiled = jax.jit(fn).lower(g, s).compile()
    cost = hlo_analysis.xla_cost(compiled)
    flops = float(cost.get("flops", 0.0))
    mem = float(cost.get("bytes accessed", 0.0))
    coll = 0.0
    if flops <= 0.0 or mem <= 0.0:
        # some jaxlib builds report empty cost_analysis on CPU; fall
        # back to the while-aware HLO parse (same numbers the dry-run
        # bench JSON used to carry)
        totals = hlo_analysis.analyze(compiled.as_text())
        flops = flops if flops > 0.0 else float(totals.get("flops", 0.0))
        mem = mem if mem > 0.0 else float(totals.get("out_bytes", 0.0))
        coll = float(totals.get("coll_bytes", 0.0))
    st = _static_estimate(ck, rl, shapes)
    return CostEstimate(
        op_estimate=flops if flops > 0.0 else st.op_estimate,
        mem_estimate=mem if mem > 0.0 else st.mem_estimate,
        coll_estimate=coll,
        shared_footprint=st.shared_footprint, peel_count=st.peel_count,
        collective_density=st.collective_density, source="xla")


def estimate(ck: CompiledKernel, rl, shapes: Dict[str, tuple], *,
             simd: bool = True, mesh=None, axis: str = "data",
             mode: Optional[str] = None) -> CostEstimate:
    """The cost record for one resolved launch shape, cached per
    (kernel, knobs, shapes).  ``mode=None`` follows ``COX_COSTMODEL``
    ('static' default); 'xla' lowers+compiles the staged program once
    per shape and reads the backend's cost analysis.  Never raises —
    an 'xla' failure degrades to the static walk."""
    mode = telemetry_mode() if mode is None else mode
    key = (id(ck), rl.backend, rl.mode, rl.warp_exec,
           rl.grid.astuple(), rl.block.astuple(), rl.chunk,
           getattr(rl, "schedule", "chunked"),
           getattr(rl, "n_resident", None), simd,
           mesh is not None, tuple(sorted(shapes.items())), mode)
    with _cache_lock:
        hit = _cache.get(key)
        if hit is not None:
            return hit
    if mode == "xla":
        try:
            est = _xla_estimate(ck, rl, shapes, simd=simd, mesh=mesh,
                                axis=axis)
        except Exception:
            est = _static_estimate(ck, rl, shapes)
    else:
        est = _static_estimate(ck, rl, shapes)
    with _cache_lock:
        _cache[key] = est
        while len(_cache) > _CACHE_MAX:
            _cache.pop(next(iter(_cache)))
    return est


def estimate_request(req, mode: Optional[str] = None) -> CostEstimate:
    """:func:`estimate` keyed off a dispatcher ``LaunchRequest``."""
    return estimate(req.ck, req.rl, req.shapes, simd=req.simd,
                    mesh=req.mesh, axis=req.axis, mode=mode)


def clear_cache() -> None:
    with _cache_lock:
        _cache.clear()
