"""Stream → device placement policies (the multi-device scale-out layer).

A single XLA device executes one computation at a time, so PR 5's
stream overlap is host/device pipelining — independent streams still
serialize through one executor queue.  This module gives the
:class:`~repro.core.streams.Dispatcher` a *placement* layer: each
non-default stream is assigned a device from the dispatcher's pool, so
launches on different streams execute **concurrently on different XLA
devices** — the CUDA multi-queue concurrency model, realized as one
committed-device jit program per stream.

Granularity is the stream, not the launch: launches within a stream are
in-order anyway, so spreading one stream over several devices buys no
concurrency and pays a transfer per hop.  A policy therefore picks a
device the first time a stream's work is dispatched and the stream
keeps it (device affinity) until the device is poisoned by a sticky
:class:`~repro.core.errors.CoxDeviceError` — then the policy re-picks
among the healthy survivors (health-aware routing instead of a
process-wide failure).

What stays single-device: the default stream (CUDA's "current device"),
mesh/sharded launches (they span their own device set), and any
dispatcher whose pool has one device — all three keep the exact legacy
dispatch path, no transfers inserted.

Policies:

* :class:`RoundRobinPlacement` — deal streams over the pool in arrival
  order; the default.
* :class:`AffinityPlacement` — prefer the device where the request's
  committed input buffers (e.g. a donated carry) already live, falling
  back to round-robin; saves the cross-device copy for relaunch-over-
  same-buffers loops.
* :class:`HealthAwarePlacement` — prefer the device with the cleanest
  per-device ``health()`` counters (fewest failures + degradations),
  round-robin among ties.
"""
from __future__ import annotations

import itertools
from typing import Any, List, Optional


def resident_device(val) -> Optional[Any]:
    """The single device a *committed* jax.Array lives on, else None
    (uncommitted arrays report the default device — that is a
    placement default, not an affinity signal)."""
    if not getattr(val, "_committed", False):
        return None
    try:
        devs = val.devices()
    except (AttributeError, TypeError):
        return None
    if len(devs) == 1:
        return next(iter(devs))
    return None


class PlacementPolicy:
    """Base policy: stream affinity + pluggable ``pick``.

    ``place(req, devices, disp)`` is the dispatcher's entry point:
    ``devices`` is the current *healthy* pool (sticky-poisoned devices
    already routed out).  A stream that already holds a healthy device
    keeps it; otherwise ``pick`` chooses and the stream records the
    choice.  Subclasses implement :meth:`pick` only."""

    name = "policy"

    def place(self, req, devices: List[Any], disp) -> Any:
        stream = getattr(req, "stream", None)
        if stream is not None:
            held = stream._device
            if held is not None and any(d.id == held.id for d in devices):
                return held
            dev = self.pick(req, devices, disp)
            stream._device = dev
            return dev
        return self.pick(req, devices, disp)

    def pick(self, req, devices: List[Any], disp) -> Any:
        raise NotImplementedError

    def __repr__(self):
        return f"{type(self).__name__}()"


class RoundRobinPlacement(PlacementPolicy):
    """Deal streams over the healthy pool in arrival order."""

    name = "round-robin"

    def __init__(self):
        self._counter = itertools.count()

    def pick(self, req, devices, disp):
        return devices[next(self._counter) % len(devices)]


class AffinityPlacement(PlacementPolicy):
    """Prefer the device where the request's committed input buffers
    already live — the donated-carry case: a stream relaunching over
    the buffers a previous launch produced should land where they are,
    not pay a transfer to honor a rotation."""

    name = "affinity"

    def __init__(self):
        self._fallback = RoundRobinPlacement()

    def pick(self, req, devices, disp):
        votes = {}
        for val in (req.globals_ or {}).values():
            dev = resident_device(val)
            if dev is not None:
                votes[dev.id] = votes.get(dev.id, 0) + 1
        if votes:
            best = max(votes, key=votes.get)
            for d in devices:
                if d.id == best:
                    return d
        return self._fallback.pick(req, devices, disp)


class HealthAwarePlacement(PlacementPolicy):
    """Prefer the device with the cleanest per-device health counters
    (PR 7's bookkeeping): fewest ``failures + degradations``, ties
    broken round-robin so clean devices still share load."""

    name = "health-aware"

    def __init__(self):
        self._counter = itertools.count()

    def pick(self, req, devices, disp):
        stats = disp.device_health()

        def load(dev):
            c = stats.get(str(dev), {})
            return c.get("failures", 0) + c.get("degradations", 0)

        best = min(load(d) for d in devices)
        clean = [d for d in devices if load(d) == best]
        return clean[next(self._counter) % len(clean)]
