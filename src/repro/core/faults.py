"""Deterministic fault injection for the dispatch layer.

Chaos testing for a launch stack needs *deterministic* faults: a test
(or a drill against a live serving process) declares exactly which
launch fails, where in its lifecycle, and with what error — then
asserts the blast radius.  This module is the injection surface the
dispatcher (``repro.core.streams``), the graph replayer
(``repro.core.graphs``), and the chaos suite
(``tests/test_fault_tolerance.py``) share:

    with cox.faults.inject("my_kernel", site="stage",
                           transient=True, times=2):
        kern.launch(...)        # first two stage attempts fail,
                                # the bounded retry clears it

Faults are keyed by **kernel name** (or graph name for replay-site
faults), **launch index** (the Nth matching consult), and **site**:

* ``stage``         — raised while staging (trace/compile) the launch;
* ``dispatch``      — raised while calling the staged executable (for a
  graph name: while calling the fused replay executable);
* ``timeout``       — the launch "hangs": its outputs never report
  ready, so the dispatcher's per-launch deadline fires
  :class:`~repro.core.errors.CoxTimeoutError` at its sync;
* ``sticky-device`` — raises a sticky
  :class:`~repro.core.errors.CoxDeviceError`, poisoning the dispatcher
  until ``cox.device_reset()``.

Specs are consulted (``consume``) once per attempt, so ``times=N``
composes with the retry/degradation ladder: a ``times=1`` stage fault
fails the first rung and lets the fallback rung succeed; a transient
``times=2`` fault is cleared by the second retry.  Registration is
process-global and thread-safe; the ``inject`` context manager removes
its spec on exit, so no fault outlives its ``with`` block.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, List, Optional, Union

from . import errors as _errors

SITES = ("stage", "dispatch", "timeout", "sticky-device")


@dataclasses.dataclass
class FaultSpec:
    """One armed fault.  ``kernel=None`` matches every name; ``index``
    selects the Nth matching consult (0-based, ``None`` = every);
    ``times`` caps how often it fires (``None`` = unlimited);
    ``error`` overrides the default error (an exception instance used
    as a prototype, or a zero-arg factory)."""

    kernel: Optional[str] = None
    site: str = "dispatch"
    index: Optional[int] = None
    times: Optional[int] = 1
    error: Union[BaseException, Callable[[], BaseException], None] = None
    transient: bool = False
    # bookkeeping
    seen: int = 0
    fired: int = 0
    hits: List[str] = dataclasses.field(default_factory=list)

    def make_error(self, name: str) -> BaseException:
        if callable(self.error):
            return self.error()
        if self.error is not None:
            return self.error
        if self.site == "sticky-device":
            return _errors.CoxDeviceError(
                f"injected sticky device fault at '{name}'")
        if self.site == "timeout":
            return _errors.CoxTimeoutError(
                f"injected hang at '{name}'")
        cls = (_errors.CoxCompileError if self.site == "stage"
               else _errors.CoxLaunchError)
        return cls(f"injected {self.site} fault at '{name}'",
                   transient=self.transient)


_lock = threading.Lock()
_active: List[FaultSpec] = []


def _register(spec: FaultSpec) -> FaultSpec:
    if spec.site not in SITES:
        raise ValueError(f"unknown fault site {spec.site!r}; "
                         f"sites: {SITES}")
    with _lock:
        _active.append(spec)
    return spec


def _unregister(spec: FaultSpec) -> None:
    with _lock:
        try:
            _active.remove(spec)
        except ValueError:
            pass


@contextlib.contextmanager
def inject(kernel: Optional[str] = None, *, site: str = "dispatch",
           index: Optional[int] = None, times: Optional[int] = 1,
           error: Union[BaseException, Callable[[], BaseException],
                        None] = None,
           transient: bool = False):
    """Arm a fault for the duration of the ``with`` block and yield the
    :class:`FaultSpec` (inspect ``spec.fired`` / ``spec.hits`` in
    assertions)."""
    spec = FaultSpec(kernel=kernel, site=site, index=index, times=times,
                     error=error, transient=transient)
    _register(spec)
    try:
        yield spec
    finally:
        _unregister(spec)


def consume(site: str, name: str) -> Optional[BaseException]:
    """Consult the armed faults for one attempt at ``site`` on
    ``name``; returns the error to apply (raise, or for the
    ``timeout`` site: treat the launch as hung), or ``None``.  Each
    matching consult advances the spec's ``seen`` counter so
    ``index``/``times`` stay deterministic under retries."""
    with _lock:
        for spec in _active:
            if spec.site != site:
                continue
            if spec.kernel is not None and spec.kernel != name:
                continue
            idx, spec.seen = spec.seen, spec.seen + 1
            if spec.index is not None and idx != spec.index:
                continue
            if spec.times is not None and spec.fired >= spec.times:
                continue
            spec.fired += 1
            spec.hits.append(f"{site}:{name}#{idx}")
            return spec.make_error(name)
    return None


def active() -> List[FaultSpec]:
    """Snapshot of the armed faults (for diagnostics)."""
    with _lock:
        return list(_active)
