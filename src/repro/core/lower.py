"""Structured kernel IR → CFG.

The decision point of the whole pipeline (see DESIGN.md §2): a
conditional construct becomes

* a **real CFG branch** iff its body contains a barrier (explicit, or
  implicit via a warp collective).  Its condition must then be uniform at
  the barrier's level — the paper's aligned-barrier assumption — and the
  branch is later *peeled* (lane 0 / warp 0 evaluates, the rest follow).
  The emitted branch block is pure (paper's ``if.cond`` rule); the
  condition is evaluated by *all* threads in the preceding block so side
  effects are preserved (paper §2.3, bullet 2).

* **predicated straight-line code** otherwise: the structured ``If`` /
  ``While`` node stays nested inside a basic block's instruction list and
  the executor evaluates it under an active-lane mask.  This is the
  whole-function-vectorization role clang plays for the paper's output.

Loops are emitted in canonical form (preheader → header(cond eval) →
cond-branch → body…latch → header), the shape LLVM's loop-simplify
guarantees the paper (§3.3.2/§3.3.3).
"""
from __future__ import annotations

from typing import List

from . import kernel_ir as K
from .cfg import CFG, Block, Br, Jmp, Ret
from .types import BarrierLevel, CoxUnsupported


class _Lowerer:
    def __init__(self, kernel: K.Kernel):
        self.kernel = kernel
        self.cfg = CFG(kernel.name)
        self._tmp = 0

    def fresh(self) -> str:
        self._tmp += 1
        return f".c{self._tmp}"

    def run(self) -> CFG:
        entry = self.cfg.new_block("entry")
        self.cfg.entry = entry.name
        exit_b = self.cfg.new_block("exit")
        exit_b.term = Ret()
        self.cfg.exit = exit_b.name

        last = self.lower_stmts(self.kernel.body, entry)
        if last.term is None:
            last.term = Jmp(exit_b.name)
        self.cfg.verify()
        return self.cfg

    # ------------------------------------------------------------------
    def lower_stmts(self, stmts: List[K.Stmt], cur: Block) -> Block:
        """Lower into `cur`; return the block where control continues."""
        for i, s in enumerate(stmts):
            if cur.term is not None:
                # unreachable code after a Return
                raise CoxUnsupported("statements after return are unreachable")
            if isinstance(s, K.Barrier) and s.level == BarrierLevel.GRID:
                # the region machine may not collapse across a grid
                # barrier: compile_kernel phase-splits (repro.core.phases)
                # before lowering, so one reaching the CFG is a misuse of
                # the low-level API
                raise CoxUnsupported(
                    "grid barrier reached CFG lowering: grid_sync kernels "
                    "must be phase-split first (compile via "
                    "repro.core.execute.compile_kernel, which handles it)")
            if isinstance(s, K.Return):
                if i != len(stmts) - 1:
                    raise CoxUnsupported("return must be the last statement")
                cur.term = Jmp(self.cfg.exit)
            elif isinstance(s, K.If):
                cur = self.lower_if(s, cur)
            elif isinstance(s, K.While):
                cur = self.lower_while(s, cur)
            else:
                # Straight-line instruction (Assign / stores / Barrier /
                # WarpCall / AtomicRMW) — appended as-is.
                cur.instrs.append(s)
        return cur

    # ------------------------------------------------------------------
    def lower_if(self, s: K.If, cur: Block) -> Block:
        level = K.subtree_barrier_level(s.then_body + s.else_body)
        if level == BarrierLevel.GRID:
            raise CoxUnsupported(
                "grid_sync inside divergent control flow — a grid barrier "
                "must be reached uniformly by the whole grid (see "
                "repro.core.phases.validate_grid_syncs)")
        if level is None:
            self._check_predicable(s.then_body)
            self._check_predicable(s.else_body)
            cur.instrs.append(s)  # predicated in-place
            return cur
        # Barrier-bearing: real branch.  Evaluate the condition in the head
        # (all threads, side effects preserved), branch from a pure block.
        cond_tmp = self.fresh()
        cur.instrs.append(K.Assign(cond_tmp, s.cond))
        condbr = self.cfg.new_block("if.cond")
        cur.term = Jmp(condbr.name)

        join = self.cfg.new_block("if.exit")
        then_entry = self.cfg.new_block("if.then")
        t_end = self.lower_stmts(s.then_body, then_entry)
        if t_end.term is None:
            t_end.term = Jmp(join.name)
        if s.else_body:
            else_entry = self.cfg.new_block("if.else")
            e_end = self.lower_stmts(s.else_body, else_entry)
            if e_end.term is None:
                e_end.term = Jmp(join.name)
            condbr.term = Br(cond_tmp, then_entry.name, else_entry.name, level)
        else:
            condbr.term = Br(cond_tmp, then_entry.name, join.name, level)
        return join

    # ------------------------------------------------------------------
    def lower_while(self, s: K.While, cur: Block) -> Block:
        level = K.subtree_barrier_level(s.body)
        if level == BarrierLevel.GRID:
            raise CoxUnsupported(
                "grid_sync inside a loop body — the phase count must be "
                "static (see repro.core.phases.validate_grid_syncs)")
        if level is None:
            self._check_predicable(s.body)
            cur.instrs.append(s)  # masked loop, executed in-place
            return cur
        cond_tmp = self.fresh()
        header = self.cfg.new_block("loop.header")
        condbr = self.cfg.new_block("loop.cond")
        exit_b = self.cfg.new_block("loop.exit")
        body_entry = self.cfg.new_block("loop.body")

        cur.term = Jmp(header.name)                       # cur is the preheader
        header.instrs.append(K.Assign(cond_tmp, s.cond))  # evaluated by all threads
        header.term = Jmp(condbr.name)
        condbr.term = Br(cond_tmp, body_entry.name, exit_b.name, level)

        latch = self.lower_stmts(s.body, body_entry)
        if latch.term is None:
            latch.term = Jmp(header.name)                 # single back edge
        else:
            raise CoxUnsupported("loop body must fall through to the latch")
        return exit_b

    # ------------------------------------------------------------------
    def _check_predicable(self, body: List[K.Stmt]):
        for s in body:
            if isinstance(s, K.Return):
                raise CoxUnsupported("return inside divergent control flow")
            if isinstance(s, K.If):
                self._check_predicable(s.then_body)
                self._check_predicable(s.else_body)
            elif isinstance(s, K.While):
                self._check_predicable(s.body)


def lower_kernel(kernel: K.Kernel) -> CFG:
    return _Lowerer(kernel).run()
