"""COX core: hierarchical collapsing for CUDA-style SPMD kernels in JAX.

Public surface:

    from repro.core import cox          # kernel decorator + dtypes
    from repro.core.execute import compile_kernel
    from repro.core.oracle import run_grid as oracle_run
"""
from . import api as cox  # noqa: F401
from .types import BarrierLevel, CoxUnsupported, DType, WARP_SIZE  # noqa: F401
