"""Control-flow graph for the COX pass pipeline.

The unit the paper's LLVM pass operates on.  Invariants guaranteed by
``lower.py`` (mirroring LLVM loop-simplify / lowerswitch, paper §3.3.3):

* every branch is two-way; every ``Br`` block is *pure* (no instructions —
  the paper's ``if.cond`` rule: "only a single conditional-branch
  instruction, no side effects"), and carries the barrier *level* of the
  construct that produced it (warp / block) for hierarchical-PR formation;
* every loop is canonical: single latch, header dominates exits;
* single entry block, single exit block;
* barrier-free divergent control flow never reaches the CFG — it is
  predicated inside straight-line instructions (``kernel_ir.If/While``
  nested in a block's instruction list), so every CFG branch condition is
  warp-uniform (block-uniform for block-level branches) under the paper's
  aligned-barrier assumption (§2.2.3).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Set

from . import kernel_ir as K
from .types import BarrierLevel, CoxUnsupported

# ----------------------------------------------------------------------------
# CFG-only instructions (products of warp-intrinsic lowering, paper §3.2)
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class WarpBufStore:
    """Each lane stores its operand into the 32-wide warp buffer
    (the paper's ``@warp_vote[tx] = flag``)."""
    buf: str
    value: K.Expr

    def __repr__(self):
        return f"@{self.buf}[lane] = {self.value}"


@dataclasses.dataclass
class WarpBufCompute:
    """Collective read of the warp buffer (the paper's ``warp_all`` /
    shuffle read — AVX on x86, VPU lane ops here)."""
    dst: str
    func: str           # shfl_down/up/xor/idx, vote_all/any, ballot, red_*
    buf: str
    args: List[K.Expr]  # offset / src-lane / none
    width: int = 0      # static tile width (cooperative groups); 0 = warp

    def __repr__(self):
        return f"{self.dst} = {self.func}(@{self.buf}, {self.args})"


# ----------------------------------------------------------------------------
# Terminators
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Br:
    cond: str                      # name of a b1 variable (pure block rule)
    true: str
    false: str
    level: BarrierLevel = BarrierLevel.WARP  # peel level of this branch

    def targets(self):
        return [self.true, self.false]


@dataclasses.dataclass
class Jmp:
    target: str

    def targets(self):
        return [self.target]


@dataclasses.dataclass
class Ret:
    def targets(self):
        return []


# ----------------------------------------------------------------------------
# Blocks and graph
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Block:
    name: str
    instrs: List = dataclasses.field(default_factory=list)
    term: object = None  # Br | Jmp | Ret

    def ends_with_barrier(self, level: Optional[BarrierLevel] = None) -> bool:
        if not self.instrs or not isinstance(self.instrs[-1], K.Barrier):
            return False
        if level is None:
            return True
        return self.instrs[-1].level >= level

    def has_barrier(self) -> bool:
        return any(isinstance(i, K.Barrier) for i in self.instrs)

    def is_pure_branch(self) -> bool:
        return isinstance(self.term, Br) and not self.instrs


class CFG:
    def __init__(self, name: str):
        self.name = name
        self.blocks: "OrderedDict[str, Block]" = OrderedDict()
        self.entry: str = ""
        self.exit: str = ""
        self._ctr = 0

    # ------------- construction -------------

    def new_block(self, hint: str = "bb") -> Block:
        self._ctr += 1
        b = Block(f"{hint}.{self._ctr}")
        self.blocks[b.name] = b
        return b

    def add_block(self, b: Block):
        self.blocks[b.name] = b

    # ------------- topology -------------

    def succs(self, name: str) -> List[str]:
        return list(self.blocks[name].term.targets())

    def preds(self, name: str) -> List[str]:
        return [b for b, blk in self.blocks.items() if name in blk.term.targets()]

    def pred_map(self) -> Dict[str, List[str]]:
        m: Dict[str, List[str]] = {b: [] for b in self.blocks}
        for b, blk in self.blocks.items():
            for t in blk.term.targets():
                m[t].append(b)
        return m

    def rpo(self) -> List[str]:
        seen: Set[str] = set()
        post: List[str] = []

        def dfs(n: str):
            stack = [(n, iter(self.succs(n)))]
            seen.add(n)
            while stack:
                node, it = stack[-1]
                advanced = False
                for s in it:
                    if s not in seen:
                        seen.add(s)
                        stack.append((s, iter(self.succs(s))))
                        advanced = True
                        break
                if not advanced:
                    post.append(node)
                    stack.pop()

        dfs(self.entry)
        return list(reversed(post))

    def verify(self):
        assert self.entry in self.blocks and self.exit in self.blocks
        reach = set(self.rpo())
        for name, blk in self.blocks.items():
            if blk.term is None:
                raise CoxUnsupported(f"block {name} missing terminator")
            for t in blk.term.targets():
                if t not in self.blocks:
                    raise CoxUnsupported(f"block {name} branches to unknown {t}")
            if isinstance(blk.term, Br) and blk.instrs:
                raise CoxUnsupported(
                    f"branch block {name} is not pure (paper's if.cond rule)")
        if self.exit not in reach:
            raise CoxUnsupported("exit unreachable")

    # ------------- dominators (Cooper-Harvey-Kennedy iterative) -------------

    def _idoms(self, reverse: bool) -> Dict[str, Optional[str]]:
        if reverse:
            root = self.exit
            preds = {b: self.succs(b) for b in self.blocks}   # reversed edges
            order_src = self._rpo_reverse()
        else:
            root = self.entry
            preds = self.pred_map()
            order_src = self.rpo()
        index = {b: i for i, b in enumerate(order_src)}
        idom: Dict[str, Optional[str]] = {b: None for b in order_src}
        idom[root] = root

        def intersect(a: str, b: str) -> str:
            while a != b:
                while index[a] > index[b]:
                    a = idom[a]  # type: ignore
                while index[b] > index[a]:
                    b = idom[b]  # type: ignore
            return a

        changed = True
        while changed:
            changed = False
            for b in order_src:
                if b == root:
                    continue
                new = None
                for p in preds[b]:
                    if p in index and idom.get(p) is not None:
                        new = p if new is None else intersect(new, p)
                if new is not None and idom[b] != new:
                    idom[b] = new
                    changed = True
        idom[root] = None
        return idom

    def _rpo_reverse(self) -> List[str]:
        seen: Set[str] = set()
        post: List[str] = []
        pm = self.pred_map()

        def dfs(n: str):
            stack = [(n, iter(pm[n]))]
            seen.add(n)
            while stack:
                node, it = stack[-1]
                advanced = False
                for s in it:
                    if s not in seen:
                        seen.add(s)
                        stack.append((s, iter(pm[s])))
                        advanced = True
                        break
                if not advanced:
                    post.append(node)
                    stack.pop()

        dfs(self.exit)
        return list(reversed(post))

    def dom_tree(self) -> "DomTree":
        return DomTree(self._idoms(reverse=False), self.entry)

    def postdom_tree(self) -> "DomTree":
        return DomTree(self._idoms(reverse=True), self.exit)

    # ------------- mutation helpers -------------

    def split_after(self, name: str, idx: int, hint: str = "split") -> str:
        """Split block so instrs[:idx+1] stay, rest + terminator move to a
        new block (paper §3.4: split before/after each barrier)."""
        blk = self.blocks[name]
        nb = self.new_block(hint)
        nb.instrs = blk.instrs[idx + 1:]
        nb.term = blk.term
        blk.instrs = blk.instrs[: idx + 1]
        blk.term = Jmp(nb.name)
        if self.exit == name:
            self.exit = nb.name
        return nb.name

    def dump(self) -> str:
        lines = [f"cfg {self.name} entry={self.entry} exit={self.exit}"]
        for name, blk in self.blocks.items():
            lines.append(f"  {name}:")
            for i in blk.instrs:
                lines.append(f"    {i}")
            lines.append(f"    -> {blk.term}")
        return "\n".join(lines)


class DomTree:
    def __init__(self, idom: Dict[str, Optional[str]], root: str):
        self.idom = idom
        self.root = root

    def dominates(self, a: str, b: str) -> bool:
        """True iff a dominates b (or post-dominates, for a PDT)."""
        cur: Optional[str] = b
        while cur is not None:
            if cur == a:
                return True
            cur = self.idom.get(cur)
        return False

    def strictly_dominates(self, a: str, b: str) -> bool:
        return a != b and self.dominates(a, b)
