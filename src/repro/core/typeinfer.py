"""Forward type inference over the structured kernel IR.

C-like model: a variable's type is fixed by its first assignment
(promoted if later assignments disagree — monotone, so the fixpoint
converges in ≤ |lattice| passes).  Assignments coerce the RHS to the
variable's type at execution, matching C assignment semantics.
"""
from __future__ import annotations

from typing import Dict, List

from . import kernel_ir as K
from .types import ArraySpec, CoxTypeError, DType, ScalarSpec, promote

_INT_PRESERVING = {"//", "%", "&", "|", "^", "<<", ">>"}


class TypeEnv:
    def __init__(self, kernel: K.Kernel):
        self.var: Dict[str, DType] = {}
        self.arrays: Dict[str, DType] = {}
        self.shared: Dict[str, DType] = {}
        for p in kernel.params:
            if isinstance(p, ArraySpec):
                self.arrays[p.name] = p.dtype
            elif isinstance(p, ScalarSpec):
                self.var[p.name] = p.dtype
        for s in kernel.shared:
            self.shared[s.name] = s.dtype

    def merge(self, name: str, dt: DType):
        cur = self.var.get(name)
        self.var[name] = dt if cur is None else promote(cur, dt)


def infer_expr(e: K.Expr, env: TypeEnv) -> DType:
    if isinstance(e, K.Const):
        if e.dtype is None:
            e.dtype = (DType.b1 if isinstance(e.value, bool)
                       else DType.i32 if isinstance(e.value, int) else DType.f32)
        return e.dtype
    if isinstance(e, K.Var):
        dt = env.var.get(e.name)
        e.dtype = dt if dt is not None else e.dtype or DType.i32
        return e.dtype
    if isinstance(e, K.Special):
        e.dtype = DType.i32
        return e.dtype
    if isinstance(e, K.BinOp):
        lt, rt = infer_expr(e.lhs, env), infer_expr(e.rhs, env)
        if e.op == "/":
            e.dtype = promote(promote(lt, rt), DType.f32)
        elif e.op in _INT_PRESERVING and not (lt.is_float or rt.is_float):
            e.dtype = promote(lt, rt)
        else:
            e.dtype = promote(lt, rt)
        return e.dtype
    if isinstance(e, K.CmpOp):
        infer_expr(e.lhs, env)
        infer_expr(e.rhs, env)
        e.dtype = DType.b1
        return e.dtype
    if isinstance(e, K.BoolOp):
        for a in e.args:
            infer_expr(a, env)
        e.dtype = DType.b1
        return e.dtype
    if isinstance(e, K.UnOp):
        it = infer_expr(e.operand, env)
        if e.op in ("f32", "i32", "f16", "bf16", "u32"):
            e.dtype = DType(e.op)
        elif e.op == "not":
            e.dtype = DType.b1
        elif e.op in ("exp", "log", "sqrt", "rsqrt", "tanh", "sigmoid"):
            e.dtype = promote(it, DType.f32)
        elif e.op == "floor":
            e.dtype = promote(it, DType.f32)
        else:  # neg abs
            e.dtype = it
        return e.dtype
    if isinstance(e, K.Select):
        infer_expr(e.cond, env)
        t = infer_expr(e.on_true, env)
        f = infer_expr(e.on_false, env)
        e.dtype = promote(t, f)
        return e.dtype
    if isinstance(e, K.LoadGlobal):
        infer_expr(e.index, env)
        e.dtype = env.arrays[e.array]
        return e.dtype
    if isinstance(e, K.LoadShared):
        infer_expr(e.index, env)
        e.dtype = env.shared[e.array]
        return e.dtype
    raise CoxTypeError(f"cannot infer {e!r}")


def _infer_stmts(body: List[K.Stmt], env: TypeEnv):
    for s in body:
        if isinstance(s, K.Assign):
            env.merge(s.name, infer_expr(s.value, env))
        elif isinstance(s, (K.StoreGlobal, K.StoreShared)):
            infer_expr(s.index, env)
            infer_expr(s.value, env)
        elif isinstance(s, K.AtomicRMW):
            infer_expr(s.index, env)
            infer_expr(s.value, env)
            if s.dst:
                env.merge(s.dst, env.arrays[s.array])
        elif isinstance(s, K.WarpCall):
            for a in s.args:
                infer_expr(a, env)
            if s.func in ("vote_all", "vote_any"):
                dt = DType.b1
            elif s.func == "ballot":
                dt = DType.u32
            else:  # shfl_*, red_*
                dt = s.args[0].dtype or DType.f32
            if s.dst:
                env.merge(s.dst, dt)
        elif isinstance(s, K.If):
            infer_expr(s.cond, env)
            _infer_stmts(s.then_body, env)
            _infer_stmts(s.else_body, env)
        elif isinstance(s, K.While):
            infer_expr(s.cond, env)
            _infer_stmts(s.body, env)
        elif isinstance(s, (K.Barrier, K.Return)):
            pass
        else:
            raise CoxTypeError(f"cannot type stmt {s!r}")


def infer(kernel: K.Kernel) -> Dict[str, DType]:
    """Run to fixpoint; return var -> dtype.  Expr nodes are annotated
    in place on the final pass."""
    env = TypeEnv(kernel)
    for _ in range(4):
        before = dict(env.var)
        _infer_stmts(kernel.body, env)
        if env.var == before:
            break
    _infer_stmts(kernel.body, env)  # final annotate with stable env
    return dict(env.var)
