"""Public COX API.

    from repro.core import cox

    @cox.kernel
    def vec_add(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                b: cox.Array(cox.f32), n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = a[i] + b[i]

    out = vec_add.launch(grid=4, block=256, args=(out, a, b, n))["out"]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

from . import flat as _flat
from . import kernel_ir as K
from . import runtime as _runtime
from .execute import CompiledKernel, compile_kernel
from .frontend import Array, parse_kernel  # noqa: F401  (cox.Array re-export)
from .types import (CoxUnsupported, DType, Dim3, WARP_SIZE,  # noqa: F401
                    as_dim3)  # Dim3 re-exported: cox.Dim3 launch geometry

# dtype shorthands (annotation + c.shared dtype arguments)
f32 = DType.f32
f16 = DType.f16
bf16 = DType.bf16
i32 = DType.i32
u32 = DType.u32
b1 = DType.b1


@dataclasses.dataclass
class KernelFn:
    """A parsed CUDA-style kernel plus two caches: the pass-pipeline
    cache (``compiled``) and a launch-level cache of staged executables
    keyed on the full launch geometry, so repeat launches skip both the
    pass pipeline and the JAX retrace."""
    ir: K.Kernel
    _cache: Dict[Any, CompiledKernel] = dataclasses.field(default_factory=dict)
    _launch_cache: Dict[Any, Any] = dataclasses.field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.ir.name

    def _compile_key(self, *, collapse: str, warp_size: int,
                     block: Optional[int]) -> tuple:
        """The pass-pipeline cache key — also the stable per-compile
        token in launch-cache keys (``id(ck)`` would be recycled by the
        allocator if a compiled kernel were ever dropped)."""
        choice = _flat.choose_collapse(self.ir, collapse)
        if choice == "flat":
            if block is None:
                raise ValueError("flat collapsing specializes on block size; "
                                 "pass block=")
            ws = block
        else:
            ws = warp_size
        return (choice, ws)

    def _compiled_for(self, key: tuple) -> CompiledKernel:
        ck = self._cache.get(key)
        if ck is None:
            ck = self._cache[key] = compile_kernel(self.ir, warp_size=key[1])
        return ck

    def compiled(self, *, collapse: str = "hybrid",
                 warp_size: int = WARP_SIZE,
                 block=None) -> CompiledKernel:
        """Run the pass pipeline.  collapse='flat' uses warp_size=block
        (single block-wide loop; requires `block`, whose dim3 total is
        used); 'hier' is the paper's hierarchical collapsing; 'hybrid'
        picks automatically."""
        if block is not None:
            block = as_dim3(block, "block").total
        return self._compiled_for(self._compile_key(
            collapse=collapse, warp_size=warp_size, block=block))

    def launch(self, *, grid, block, args: Sequence[Any],
               collapse: str = "hybrid", mode: str = "auto",
               simd: bool = True, warp_size: int = WARP_SIZE,
               mesh=None, axis: str = "data", backend: str = "auto",
               chunk: Optional[int] = None,
               warp_exec: str = "auto") -> Dict[str, Any]:
        """Launch with backend dispatch (see ``repro.core.backends``).

        ``grid``/``block`` accept CUDA dim3 geometry — ``int | (x, y[,
        z])`` — normalized to one canonical form (missing axes are 1),
        so ``grid=4`` and ``grid=(4, 1, 1)`` share a cache entry.
        backend='auto'|'scan'|'vmap'|'sharded'; ``chunk`` bounds how many
        blocks the vmap-based backends run simultaneously;
        ``warp_exec='auto'|'serial'|'batched'`` picks between the serial
        inter-warp loop and the batched (n_warps, W) lane plane;
        ``mode='auto'|'normal'|'jit'`` picks loop-carried vs unrolled
        inter-warp iteration (all three resolved by ``repro.core.flat``
        heuristics when 'auto', keyed on the normalized totals)."""
        block3 = as_dim3(block, "block")
        token = self._compile_key(collapse=collapse, warp_size=warp_size,
                                  block=block3.total)
        ck = self._compiled_for(token)
        rl = _runtime.resolve_launch(ck, grid=grid, block=block3, mode=mode,
                                     backend=backend, warp_exec=warp_exec,
                                     mesh=mesh)
        # n_phases is derivable from the compile token but spelled out so
        # cooperative (grid-sync) staging can never collide with a
        # single-phase executable of the same geometry
        key = (token, ck.n_phases, rl.backend, rl.mode, rl.grid.astuple(),
               rl.block.astuple(), rl.n_warps, simd, chunk, rl.warp_exec,
               _mesh_key(mesh), axis)
        cached = self._launch_cache.get(key)
        if cached is None:
            cached = self._launch_cache[key] = _runtime.build_resolved(
                ck, rl, simd=simd, mesh=mesh, axis=axis, chunk=chunk)
        plan, exe = cached
        globals_, shapes, scalars = plan.bind_args(args)
        out = exe(globals_, scalars)
        return {k: v.reshape(shapes[k]) for k, v in out.items()}

    def uses_warp_features(self) -> bool:
        return K.uses_warp_features(self.ir)


def _mesh_key(mesh) -> Any:
    """A hashable stand-in for the mesh in launch-cache keys, built from
    stable content (axis names/sizes + device ids).  Object identity is
    NOT a safe key: ``id()`` of a garbage-collected mesh can be recycled
    by a new mesh, which would then hit a stale executable closed over
    the old devices."""
    if mesh is None:
        return None
    try:
        return ("mesh", tuple(mesh.shape.items()),
                tuple(d.id for d in mesh.devices.flat))
    except (AttributeError, TypeError):
        pass
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return ("unhashable-mesh", id(mesh), repr(mesh))


def kernel(fn=None, *, name: Optional[str] = None):
    """Decorator: parse a restricted-Python CUDA-style kernel."""
    def wrap(f):
        return KernelFn(parse_kernel(f, name=name))
    if fn is None:
        return wrap
    return wrap(fn)
