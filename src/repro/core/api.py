"""Public COX API.

    from repro.core import cox

    @cox.kernel
    def vec_add(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                b: cox.Array(cox.f32), n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = a[i] + b[i]

    out = vec_add.launch(grid=4, block=256, args=(out, a, b, n))["out"]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence

from . import flat as _flat
from . import kernel_ir as K
from .execute import CompiledKernel, compile_kernel
from .frontend import Array, parse_kernel
from .runtime import build_launcher as _build_launcher
from .types import CoxUnsupported, DType, WARP_SIZE

# dtype shorthands (annotation + c.shared dtype arguments)
f32 = DType.f32
f16 = DType.f16
bf16 = DType.bf16
i32 = DType.i32
u32 = DType.u32
b1 = DType.b1


@dataclasses.dataclass
class KernelFn:
    """A parsed CUDA-style kernel plus two caches: the pass-pipeline
    cache (``compiled``) and a launch-level cache of staged executables
    keyed on the full launch geometry, so repeat launches skip both the
    pass pipeline and the JAX retrace."""
    ir: K.Kernel
    _cache: Dict[Any, CompiledKernel] = dataclasses.field(default_factory=dict)
    _launch_cache: Dict[Any, Any] = dataclasses.field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.ir.name

    def compiled(self, *, collapse: str = "hybrid",
                 warp_size: int = WARP_SIZE,
                 block: Optional[int] = None) -> CompiledKernel:
        """Run the pass pipeline.  collapse='flat' uses warp_size=block
        (single block-wide loop; requires `block`); 'hier' is the paper's
        hierarchical collapsing; 'hybrid' picks automatically."""
        choice = _flat.choose_collapse(self.ir, collapse)
        if choice == "flat":
            if block is None:
                raise ValueError("flat collapsing specializes on block size; "
                                 "pass block=")
            ws = block
        else:
            ws = warp_size
        key = (choice, ws)
        if key not in self._cache:
            self._cache[key] = compile_kernel(self.ir, warp_size=ws)
        return self._cache[key]

    def launch(self, *, grid: int, block: int, args: Sequence[Any],
               collapse: str = "hybrid", mode: str = "auto",
               simd: bool = True, warp_size: int = WARP_SIZE,
               mesh=None, axis: str = "data", backend: str = "auto",
               chunk: Optional[int] = None,
               warp_exec: str = "auto") -> Dict[str, Any]:
        """Launch with backend dispatch (see ``repro.core.backends``):
        backend='auto'|'scan'|'vmap'|'sharded'; ``chunk`` bounds how many
        blocks the vmap-based backends run simultaneously;
        ``warp_exec='auto'|'serial'|'batched'`` picks between the serial
        inter-warp loop and the batched (n_warps, W) lane plane;
        ``mode='auto'|'normal'|'jit'`` picks loop-carried vs unrolled
        inter-warp iteration (all three resolved by ``repro.core.flat``
        heuristics when 'auto')."""
        ck = self.compiled(collapse=collapse, warp_size=warp_size, block=block)
        bname = _flat.choose_backend(self.ir, grid=grid, mesh=mesh,
                                     requested=backend)
        n_warps = -(-block // ck.warp_size)
        mode = _flat.choose_mode(self.ir, n_warps=n_warps, requested=mode)
        wexec = _flat.choose_warp_exec(self.ir, n_warps=n_warps,
                                       requested=warp_exec,
                                       machine=ck.machine)
        key = (id(ck), bname, mode, grid, block, n_warps, simd, chunk,
               wexec, _mesh_key(mesh), axis)
        cached = self._launch_cache.get(key)
        if cached is None:
            plan, exe = _build_launcher(
                ck, grid=grid, block=block, mode=mode, simd=simd,
                mesh=mesh, axis=axis, backend=bname, chunk=chunk,
                warp_exec=wexec)
            cached = self._launch_cache[key] = (plan, exe)
        plan, exe = cached
        globals_, shapes, scalars = plan.bind_args(args)
        out = exe(globals_, scalars)
        return {k: v.reshape(shapes[k]) for k, v in out.items()}

    def uses_warp_features(self) -> bool:
        return K.uses_warp_features(self.ir)


def _mesh_key(mesh) -> Any:
    """A hashable stand-in for the mesh in launch-cache keys, built from
    stable content (axis names/sizes + device ids).  Object identity is
    NOT a safe key: ``id()`` of a garbage-collected mesh can be recycled
    by a new mesh, which would then hit a stale executable closed over
    the old devices."""
    if mesh is None:
        return None
    try:
        return ("mesh", tuple(mesh.shape.items()),
                tuple(d.id for d in mesh.devices.flat))
    except (AttributeError, TypeError):
        pass
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return ("unhashable-mesh", id(mesh), repr(mesh))


def kernel(fn=None, *, name: Optional[str] = None):
    """Decorator: parse a restricted-Python CUDA-style kernel."""
    def wrap(f):
        return KernelFn(parse_kernel(f, name=name))
    if fn is None:
        return wrap
    return wrap(fn)
