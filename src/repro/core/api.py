"""Public COX API.

    from repro.core import cox

    @cox.kernel
    def vec_add(c, out: cox.Array(cox.f32), a: cox.Array(cox.f32),
                b: cox.Array(cox.f32), n: cox.i32):
        i = c.block_idx() * c.block_dim() + c.thread_idx()
        if i < n:
            out[i] = a[i] + b[i]

    out = vec_add.launch(grid=4, block=256, args=(out, a, b, n))["out"]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

from . import autotune  # noqa: F401  (cox.autotune — measured knob tuning)
from . import autotune as _autotune  # distinct alias: make_request's
#                                      autotune= knob shadows the module
from . import costmodel  # noqa: F401  (cox.costmodel — op/mem estimates)
from . import errors  # noqa: F401  (cox.errors — typed error hierarchy)
from . import faults  # noqa: F401  (cox.faults — fault injection)
from . import flat as _flat
from . import kernel_ir as K
from . import placement  # noqa: F401  (cox.placement — device policies)
from . import runtime as _runtime
from . import streams as _streams
from .backends.plan import bind_kernel_args, check_donate_supported
from .errors import (CoxCompileError, CoxDependencyError,  # noqa: F401
                     CoxDeviceError, CoxError, CoxLaunchError,
                     CoxTimeoutError)
from .execute import CompiledKernel, compile_kernel
from .frontend import Array, parse_kernel  # noqa: F401  (cox.Array re-export)
from .graphs import (Graph, GraphExec,  # noqa: F401  (cox.Graph capture API)
                     GraphNodeHandle)
from .streams import (Event, default_stream, synchronize,  # noqa: F401
                      LaunchHandle, Stream, get_dispatcher)
from .streams import (device_reset, get_last_error,  # noqa: F401
                      peek_at_last_error)  # cudaGetLastError analogues
from .streams import _mesh_key  # noqa: F401  (compat re-export for tests)
from .placement import (AffinityPlacement,  # noqa: F401  (placement API)
                        HealthAwarePlacement, PlacementPolicy,
                        RoundRobinPlacement)
from .types import (CoxUnsupported, DType, Dim3, WARP_SIZE,  # noqa: F401
                    GraphRef, as_dim3)  # Dim3 re-exported: launch geometry

# dtype shorthands (annotation + c.shared dtype arguments)
f32 = DType.f32
f16 = DType.f16
bf16 = DType.bf16
i32 = DType.i32
u32 = DType.u32
b1 = DType.b1


@dataclasses.dataclass
class KernelFn:
    """A parsed CUDA-style kernel plus the pass-pipeline cache
    (``compiled``).  The launch-level cache of staged executables lives
    behind the stream dispatcher (``repro.core.streams``) and is shared
    across every stream — ``_launch_cache`` below is a read view of this
    kernel's entries, keyed exactly as before."""
    ir: K.Kernel
    _cache: Dict[Any, CompiledKernel] = dataclasses.field(default_factory=dict)

    @property
    def _launch_cache(self) -> Dict[Any, Any]:
        """This kernel's staged ``(plan, exe)`` entries in the
        dispatcher's shared cache (backward-compatible key shape:
        compile token first, phase count second)."""
        return get_dispatcher().cache_view(self._cache.values())

    @property
    def name(self) -> str:
        return self.ir.name

    def _compile_key(self, *, collapse: str, warp_size: int,
                     block: Optional[int]) -> tuple:
        """The pass-pipeline cache key — also the stable per-compile
        token in launch-cache keys (``id(ck)`` would be recycled by the
        allocator if a compiled kernel were ever dropped)."""
        choice = _flat.choose_collapse(self.ir, collapse)
        if choice == "flat":
            if block is None:
                raise ValueError("flat collapsing specializes on block size; "
                                 "pass block=")
            ws = block
        else:
            ws = warp_size
        return (choice, ws)

    def _compiled_for(self, key: tuple) -> CompiledKernel:
        ck = self._cache.get(key)
        if ck is None:
            ck = self._cache[key] = compile_kernel(self.ir, warp_size=key[1])
        return ck

    def compiled(self, *, collapse: str = "hybrid",
                 warp_size: int = WARP_SIZE,
                 block=None) -> CompiledKernel:
        """Run the pass pipeline.  collapse='flat' uses warp_size=block
        (single block-wide loop; requires `block`, whose dim3 total is
        used); 'hier' is the paper's hierarchical collapsing; 'hybrid'
        picks automatically."""
        if block is not None:
            block = as_dim3(block, "block").total
        return self._compiled_for(self._compile_key(
            collapse=collapse, warp_size=warp_size, block=block))

    def make_request(self, *, grid, block, args: Sequence[Any],
                     collapse: str = "hybrid", mode: str = "auto",
                     simd: bool = True, warp_size: int = WARP_SIZE,
                     mesh=None, axis: str = "data", backend: str = "auto",
                     chunk=None, warp_exec: str = "auto",
                     schedule: str = "auto",
                     n_resident: Optional[int] = None,
                     donate: bool = False, device: Any = None,
                     autotune: Optional[bool] = None
                     ) -> _streams.LaunchRequest:
        """Resolve the launch knobs and bind the arguments into a
        :class:`~repro.core.streams.LaunchRequest` — the unit the stream
        dispatcher consumes.  Compilation (the pass pipeline) and knob
        resolution happen here, eagerly, so bad launches fail at the
        call site; staging and dispatch happen later, behind the
        dispatcher.

        ``chunk=`` accepts an int (explicit, never overridden by the
        autotuner), ``None`` (the heuristic default) or ``'auto'``
        (tune the chunk by measurement).  ``autotune=True`` measures
        every knob left on auto — candidate cells pruned by the cost
        model, winners persisted in the on-disk cache
        (``repro.core.autotune``) — and ``autotune=None`` defers to the
        ``COX_AUTOTUNE`` env (plus ``chunk='auto'``, which always
        tunes).

        ``schedule=`` picks the launch schedule: ``'auto'`` (default)
        lets the footprint verdict choose between the chunk-table walk
        and the grid-stride loop once argument shapes are bound;
        ``'chunked'``/``'grid_stride'`` force either (explicit, never
        overridden by the autotuner), and ``n_resident=`` sizes the
        grid-stride wave (implies ``schedule='grid_stride'``).

        ``device=`` pins the launch to one XLA device (multi-device
        placement; mutually exclusive with ``mesh``, which spans its
        own device set) — left ``None``, the dispatcher's placement
        policy assigns the stream a device when its pool is
        multi-device."""
        if device is not None and mesh is not None:
            raise CoxUnsupported(
                f"kernel '{self.name}': device= and mesh= are mutually "
                f"exclusive — a sharded launch spans the mesh's own "
                f"devices; placement applies to single-device launches")
        block3 = as_dim3(block, "block")
        token = self._compile_key(collapse=collapse, warp_size=warp_size,
                                  block=block3.total)
        ck = self._compiled_for(token)
        rl = _runtime.resolve_launch(ck, grid=grid, block=block3, mode=mode,
                                     backend=backend, warp_exec=warp_exec,
                                     chunk=chunk, schedule=schedule,
                                     n_resident=n_resident, mesh=mesh)
        globals_, shapes, scalars = bind_kernel_args(ck, args)
        rl = _runtime.resolve_schedule(ck, rl, shapes)
        tune = (autotune if autotune is not None
                else (chunk == "auto" or _autotune.enabled()))
        if tune:
            rl = _autotune.tune(ck, token, rl, shapes=shapes,
                                scalars=scalars, globals_=globals_,
                                simd=simd, mesh=mesh, req_backend=backend,
                                req_warp_exec=warp_exec)
        if donate:
            # fail at the call site, not at deferred staging
            check_donate_supported(rl.backend, ck.kernel.name)
        return _streams.LaunchRequest(
            ck=ck, token=token, rl=rl, simd=simd, chunk=rl.chunk, mesh=mesh,
            axis=axis, donate=donate, globals_=globals_, shapes=shapes,
            scalars=scalars, device=device,
            # pre-resolution knobs: the degradation ladder may only fall
            # back along rungs the caller left on 'auto'
            req_backend=backend, req_warp_exec=warp_exec)

    def launch(self, *, grid, block, args: Sequence[Any],
               collapse: str = "hybrid", mode: str = "auto",
               simd: bool = True, warp_size: int = WARP_SIZE,
               mesh=None, axis: str = "data", backend: str = "auto",
               chunk=None, warp_exec: str = "auto",
               schedule: str = "auto", n_resident: Optional[int] = None,
               donate: bool = False,
               device: Any = None, autotune: Optional[bool] = None,
               stream: Optional[Stream] = None) -> Dict[str, Any]:
        """Launch with backend dispatch (see ``repro.core.backends``):
        enqueue on the (default) stream and dispatch — the async CUDA
        ``kernel<<<...>>>()`` itself, with the outputs handed back as
        XLA futures.

        ``grid``/``block`` accept CUDA dim3 geometry — ``int | (x, y[,
        z])`` — normalized to one canonical form (missing axes are 1),
        so ``grid=4`` and ``grid=(4, 1, 1)`` share a cache entry.
        backend='auto'|'scan'|'vmap'|'sharded'; ``chunk`` bounds how many
        blocks the vmap-based backends run simultaneously;
        ``warp_exec='auto'|'serial'|'batched'`` picks between the serial
        inter-warp loop and the batched (n_warps, W) lane plane;
        ``mode='auto'|'normal'|'jit'`` picks loop-carried vs unrolled
        inter-warp iteration (all three resolved by ``repro.core.flat``
        heuristics when 'auto', keyed on the normalized totals).

        ``donate=True`` donates the flat global buffers to the staged
        executable (buffer reuse instead of copies — the bound arrays
        are consumed); ``device=`` pins the launch to one XLA device
        (see :meth:`make_request`); ``stream=`` enqueues on a
        non-default :class:`cox.Stream` instead.

        The returned arrays are XLA futures, exactly as before the
        stream refactor — the launch is *dispatched* (host errors
        surface here) but the host does not block on device completion,
        so back-to-back launches keep pipelining; use
        :meth:`launch_async` / ``stream.launch`` to also defer
        dispatch."""
        return self.launch_async(
            grid=grid, block=block, args=args, collapse=collapse,
            mode=mode, simd=simd, warp_size=warp_size, mesh=mesh,
            axis=axis, backend=backend, chunk=chunk, warp_exec=warp_exec,
            schedule=schedule, n_resident=n_resident,
            donate=donate, device=device, autotune=autotune,
            stream=stream).arrays()

    def launch_async(self, *, stream: Optional[Stream] = None,
                     **knobs) -> LaunchHandle:
        """Enqueue on ``stream`` (default: the legacy-sync default
        stream) and return a :class:`LaunchHandle` future immediately —
        the async CUDA launch.  Takes the same keyword knobs as
        :meth:`launch`."""
        st = stream if stream is not None else get_dispatcher().default
        return st.launch(self, **knobs)

    def uses_warp_features(self) -> bool:
        return K.uses_warp_features(self.ir)


def kernel(fn=None, *, name: Optional[str] = None):
    """Decorator: parse a restricted-Python CUDA-style kernel."""
    def wrap(f):
        return KernelFn(parse_kernel(f, name=name))
    if fn is None:
        return wrap
    return wrap(fn)
