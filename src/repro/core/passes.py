"""The hierarchical-collapsing pass pipeline (paper §3, Fig. 4 steps 1-3).

Step 1  lower_warp_intrinsics   — warp collectives → buffer store,
                                  RAW warp barrier, collective compute,
                                  WAR warp barrier (paper §3.2, Code 5).
Step 2  insert_extra_barriers   — entry/exit barriers (POCL rule) and the
                                  conditional-construct barriers of
                                  Algorithm 1 + the for-loop rule (§3.3).
Step 3  split_blocks_at_barriers — barriers terminate their block (§3.4).

PR discovery (Fig. 4 steps 4-5 / Algorithm 2) lives in regions.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from . import kernel_ir as K
from .cfg import CFG, Block, Br, WarpBufCompute, WarpBufStore
from .types import BarrierLevel, CoxUnsupported, DType

# ----------------------------------------------------------------------------
# Step 1: warp-intrinsic lowering
# ----------------------------------------------------------------------------

_VOTE_FUNCS = {"vote_all", "vote_any", "ballot"}


def warp_buf_name(dtype: DType) -> str:
    return f".warpbuf_{dtype.value}"


def lower_warp_intrinsics(cfg: CFG, var_types: Dict[str, DType]) -> Dict[str, DType]:
    """Replace WarpCall instrs; returns {buffer name: dtype} used.

    The RAW barrier orders every lane's buffer store before the collective
    read; the WAR barrier orders the read before the *next* collective's
    store into the same (reused) buffer — exactly Code 5 in the paper.  In
    SIMD execution both are naturally satisfied by lane vectorization; in
    scalar (per-lane) execution they are real ordering points.
    """
    bufs: Dict[str, DType] = {}
    for blk in cfg.blocks.values():
        out: List = []
        for ins in blk.instrs:
            if isinstance(ins, K.WarpCall):
                src = ins.args[0]
                if ins.func in _VOTE_FUNCS:
                    bdt = DType.b1
                else:
                    bdt = src.dtype or DType.f32
                buf = warp_buf_name(bdt)
                bufs[buf] = bdt
                out.append(WarpBufStore(buf, src))
                out.append(K.Barrier(BarrierLevel.WARP, source="raw"))
                out.append(WarpBufCompute(ins.dst, ins.func, buf,
                                          list(ins.args[1:]), ins.width))
                out.append(K.Barrier(BarrierLevel.WARP, source="war"))
            else:
                out.append(ins)
        blk.instrs = out
    return bufs


# ----------------------------------------------------------------------------
# Step 2: extra barriers
# ----------------------------------------------------------------------------


def _block_barrier_level(blk: Block) -> Optional[BarrierLevel]:
    lvl: Optional[BarrierLevel] = None
    for i in blk.instrs:
        if isinstance(i, K.Barrier):
            if lvl is None or i.level.rank > lvl.rank:
                lvl = i.level
    return lvl


def _reachable_from(cfg: CFG, src: str) -> Set[str]:
    seen = {src}
    stack = [src]
    while stack:
        n = stack.pop()
        for s in cfg.succs(n):
            if s not in seen:
                seen.add(s)
                stack.append(s)
    return seen


def insert_extra_barriers(cfg: CFG):
    """Algorithm 1, adapted: walk the idom chain (robust form of the
    paper's predecessor walk) from each conditionally-executed barrier
    block up to the governing branch block; insert same-level barriers at
    the construct's head end / body end / exit begin (if-then) or around
    the back edge (canonical loop).  Fixpoint until no new conditional
    barrier blocks appear."""
    # POCL-style entry/exit barriers first (paper §3.3).
    ent = cfg.blocks[cfg.entry]
    ent.instrs.insert(0, K.Barrier(BarrierLevel.BLOCK, source="entry"))
    ext = cfg.blocks[cfg.exit]
    ext.instrs.append(K.Barrier(BarrierLevel.BLOCK, source="exit"))

    processed: Set[Tuple[str, str]] = set()  # (branch block, level)
    for _round in range(64):
        dt = cfg.dom_tree()
        pdt = cfg.postdom_tree()
        work = [name for name, blk in cfg.blocks.items()
                if _block_barrier_level(blk) is not None
                and not pdt.dominates(name, cfg.entry)]
        changed = False
        for name in work:
            level = _block_barrier_level(cfg.blocks[name])
            assert level is not None
            # --- find the governing branch block via the idom chain ---
            cur = dt.idom.get(name)
            while cur is not None and pdt.dominates(name, cur):
                cur = dt.idom.get(cur)
            if cur is None or not isinstance(cfg.blocks[cur].term, Br):
                continue  # not governed by a conditional (e.g. already fixed)
            key = (cur, level.value)
            if key in processed:
                continue
            processed.add(key)
            changed = True
            is_loop = cur in _reachable_from(cfg, name)  # back edge to the cond
            if is_loop:
                _barriers_for_loop(cfg, cur, level)
            else:
                _barriers_for_if(cfg, cur, name, level, dt, pdt)
        if not changed:
            break
    else:
        raise CoxUnsupported("extra-barrier insertion did not converge")


def _append_barrier(blk: Block, level: BarrierLevel):
    if blk.instrs and isinstance(blk.instrs[-1], K.Barrier) \
            and blk.instrs[-1].level >= level:
        return
    blk.instrs.append(K.Barrier(level, source="extra"))


def _prepend_barrier(blk: Block, level: BarrierLevel):
    if blk.instrs and isinstance(blk.instrs[0], K.Barrier) \
            and blk.instrs[0].level >= level:
        return
    blk.instrs.insert(0, K.Barrier(level, source="extra"))


def _barriers_for_if(cfg: CFG, condbr: str, barrier_block: str,
                     level: BarrierLevel, dt, pdt):
    """Paper Alg. 1: barrier at end of if-head, end of if-body,
    beginning of if-exit — all at the inner barrier's level.  The if-exit
    is the immediate post-dominator of the branch block; the if-body ends
    at the join's predecessors dominated by the taken arm (robust to
    nesting, unlike the raw predecessor walk in the paper's pseudocode)."""
    br: Br = cfg.blocks[condbr].term  # type: ignore
    # end of if-head: every predecessor of the (pure) branch block
    for p in cfg.preds(condbr):
        _append_barrier(cfg.blocks[p], level)
    join = pdt.idom.get(condbr)
    if join is None:
        return
    # which arm contains the barrier block?
    side = br.true if dt.dominates(br.true, barrier_block) else br.false
    # end of if-body: join predecessors inside that arm
    for p in cfg.preds(join):
        if dt.dominates(side, p):
            _append_barrier(cfg.blocks[p], level)
    # beginning of if-exit
    _prepend_barrier(cfg.blocks[join], level)


def _barriers_for_loop(cfg: CFG, condbr: str, level: BarrierLevel):
    """Paper §3.3.2: barriers before/after the loop's back-edge branch.
    With canonical loops (header = cond eval block, single latch) this is:
    begin of header (covers preheader entry and each next iteration) and
    end of the latch; plus begin of the loop exit."""
    br: Br = cfg.blocks[condbr].term  # type: ignore
    header = None
    for p in cfg.preds(condbr):
        header = p  # canonical: single pred (the cond-eval header)
    assert header is not None, "canonical loop must have a cond-eval header"
    _prepend_barrier(cfg.blocks[header], level)
    for p in cfg.preds(header):
        _append_barrier(cfg.blocks[p], level)   # latch end + preheader end
    # loop exit: the Br target that does not re-enter the loop
    body, exit_b = br.true, br.false
    _prepend_barrier(cfg.blocks[exit_b], level)


# ----------------------------------------------------------------------------
# Step 3: split blocks at barriers
# ----------------------------------------------------------------------------


def split_blocks_at_barriers(cfg: CFG):
    """After this pass every barrier is the *last* instruction of its
    block (paper §3.4), so PRs are unions of whole blocks."""
    work = list(cfg.blocks.keys())
    while work:
        name = work.pop()
        blk = cfg.blocks[name]
        for i, ins in enumerate(blk.instrs):
            if isinstance(ins, K.Barrier) and i != len(blk.instrs) - 1:
                nb = cfg.split_after(name, i, hint="bar")
                work.append(nb)
                break


# ----------------------------------------------------------------------------
# Algorithm 2 (literal) — used for validation in tests
# ----------------------------------------------------------------------------


def find_parallel_regions_alg2(cfg: CFG, level: BarrierLevel) -> List[frozenset]:
    """A direct transliteration of the paper's Algorithm 2 ("Find all
    warp-level PRs"; block-level variant considers only block barriers).
    regions.py computes the same partition constructively; tests assert
    they agree."""
    def is_end_block(blk: Block) -> bool:
        lvl = _block_barrier_level(blk)
        if lvl is None:
            return False
        return True if level == BarrierLevel.WARP else lvl >= BarrierLevel.BLOCK

    pr_set: List[frozenset] = []
    end_blocks = [n for n, b in cfg.blocks.items() if is_end_block(b)]
    pm = cfg.pred_map()
    for name in end_blocks:
        pr = {name}
        pending = list(pm[name])
        visited = set()
        while pending:
            cur = pending.pop(0)
            if cur in visited:
                continue
            visited.add(cur)
            if is_end_block(cfg.blocks[cur]):
                continue
            if cfg.blocks[cur].is_pure_branch():
                continue  # loop-peeling blocks belong to no PR
            pr.add(cur)
            pending.extend(pm[cur])
        pr_set.append(frozenset(pr))
    return pr_set
