"""Ground-truth oracle: a per-thread CUDA-semantics interpreter.

Executes the *untransformed* kernel IR exactly the way a GPU would under
the paper's assumptions: one Python generator per CUDA thread, real
barriers (threads advance region-by-region between synchronization
events), real warp collectives (the scheduler gathers each lane's
contribution and distributes results).  Completely independent of the
hierarchical-collapsing pipeline and of JAX — numpy only — so agreement
between this oracle and the compiled executor is strong evidence of
transformation correctness.

Scheduling model: between events, a released group's threads run to
their next event one at a time (tid order).  For correctly synchronized
programs (CUDA race-freedom between barriers) every legal schedule gives
the same answer, so this is a valid oracle; racy programs are UB in CUDA
too.  Volta-style intra-warp lockstep is NOT simulated — kernels must
use __syncwarp()/collectives for intra-warp communication, which is
required by post-Volta CUDA anyway.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from . import kernel_ir as K
from .types import (ArraySpec, BarrierLevel, CoxUnsupported, DType,
                    dim3_tuple)


class OracleMisaligned(Exception):
    """Threads reached different synchronization points — the kernel
    violates the aligned-barrier assumption (paper §2.2.3)."""


def _np(dt: DType):
    return {DType.f32: np.float32, DType.f16: np.float16,
            DType.bf16: np.float32,  # numpy has no bf16; f32 stand-in
            DType.i32: np.int32, DType.i64: np.int64,
            DType.u32: np.uint32, DType.b1: np.bool_}[dt]


class _Thread:
    def __init__(self, kernel: K.Kernel, tid: int, warp_size: int,
                 uniforms: Dict[str, Any], globals_: Dict[str, np.ndarray],
                 shmem: Dict[str, np.ndarray],
                 var_types: Dict[str, DType]):
        self.k = kernel
        self.tid = tid
        self.W = warp_size
        self.uniforms = uniforms
        self.globals = globals_
        self.shmem = shmem
        self.vars: Dict[str, Any] = {}
        self.var_types = var_types

    # ------------- expression evaluation (pure, per-thread) -------------

    def ev(self, e: K.Expr):
        if isinstance(e, K.Const):
            return e.value
        if isinstance(e, K.Var):
            if e.name in self.uniforms:
                return self.uniforms[e.name]
            return self.vars.get(e.name, 0)
        if isinstance(e, K.Special):
            if e.kind == "lane":
                return self.tid % self.W
            if e.kind == "wid":
                return self.tid // self.W
            if e.kind == "wsize":
                return self.W
            ax = {"x": 0, "y": 1, "z": 2}[getattr(e, "axis", "x")]
            if e.kind == "tid":
                bx, by, _ = self.uniforms["bdim3"]
                return (self.tid % bx, (self.tid // bx) % by,
                        self.tid // (bx * by))[ax]
            if e.kind == "bid":
                gx, gy, _ = self.uniforms["gdim3"]
                bid = self.uniforms["bid"]
                return (bid % gx, (bid // gx) % gy, bid // (gx * gy))[ax]
            if e.kind == "bdim":
                return self.uniforms["bdim3"][ax]
            if e.kind == "gdim":
                return self.uniforms["gdim3"][ax]
            return self.uniforms[e.kind]
        if isinstance(e, K.BinOp):
            a, b = self.ev(e.lhs), self.ev(e.rhs)
            if e.op == "+":
                return a + b
            if e.op == "-":
                return a - b
            if e.op == "*":
                return a * b
            if e.op == "/":
                return float(a) / float(b)
            if e.op == "//":
                return a // b
            if e.op == "%":
                return a % b
            if e.op == "&":
                return (a and b) if isinstance(a, (bool, np.bool_)) else a & b
            if e.op == "|":
                return (a or b) if isinstance(a, (bool, np.bool_)) else a | b
            if e.op == "^":
                return (bool(a) != bool(b)) if isinstance(a, (bool, np.bool_)) else a ^ b
            if e.op == "<<":
                return a << b
            if e.op == ">>":
                return a >> b
            if e.op == "min":
                return min(a, b)
            if e.op == "max":
                return max(a, b)
            raise CoxUnsupported(e.op)
        if isinstance(e, K.CmpOp):
            a, b = self.ev(e.lhs), self.ev(e.rhs)
            return {"<": a < b, "<=": a <= b, ">": a > b, ">=": a >= b,
                    "==": a == b, "!=": a != b}[e.op]
        if isinstance(e, K.BoolOp):
            vals = [bool(self.ev(a)) for a in e.args]
            return all(vals) if e.op == "and" else any(vals)
        if isinstance(e, K.UnOp):
            v = self.ev(e.operand)
            if e.op == "neg":
                return -v
            if e.op == "not":
                return not bool(v)
            if e.op == "abs":
                return abs(v)
            if e.op in ("f32",):
                return float(v)
            if e.op in ("i32", "u32"):
                return int(v)
            if e.op in ("f16", "bf16"):
                return float(np.float16(v)) if e.op == "f16" else float(v)
            if e.op == "exp":
                return math.exp(v)
            if e.op == "log":
                return math.log(v)
            if e.op == "sqrt":
                return math.sqrt(v)
            if e.op == "rsqrt":
                return 1.0 / math.sqrt(v)
            if e.op == "tanh":
                return math.tanh(v)
            if e.op == "sigmoid":
                return 1.0 / (1.0 + math.exp(-v))
            if e.op == "floor":
                return math.floor(v)
            raise CoxUnsupported(e.op)
        if isinstance(e, K.Select):
            return self.ev(e.on_true) if bool(self.ev(e.cond)) else self.ev(e.on_false)
        if isinstance(e, K.LoadGlobal):
            idx = int(self.ev(e.index))
            arr = self.globals[e.array]
            return arr[idx] if 0 <= idx < arr.size else arr.dtype.type(0)
        if isinstance(e, K.LoadShared):
            idx = int(self.ev(e.index))
            arr = self.shmem[e.array]
            return arr[idx] if 0 <= idx < arr.size else arr.dtype.type(0)
        raise CoxUnsupported(f"oracle cannot eval {e!r}")

    def _coerce(self, name: str, v):
        dt = self.var_types.get(name)
        if dt is None:
            return v
        return _np(dt)(v)

    # ------------- statement execution (generator; yields sync events) ----

    def run(self):
        yield from self.stmts(self.k.body)

    def stmts(self, body: Sequence[K.Stmt]):
        for s in body:
            if isinstance(s, K.Assign):
                self.vars[s.name] = self._coerce(s.name, self.ev(s.value))
            elif isinstance(s, K.StoreGlobal):
                idx = int(self.ev(s.index))
                arr = self.globals[s.array]
                if 0 <= idx < arr.size:
                    arr[idx] = self.ev(s.value)
            elif isinstance(s, K.StoreShared):
                idx = int(self.ev(s.index))
                arr = self.shmem[s.array]
                if 0 <= idx < arr.size:
                    arr[idx] = self.ev(s.value)
            elif isinstance(s, K.AtomicRMW):
                idx = int(self.ev(s.index))
                arr = self.globals[s.array]
                if 0 <= idx < arr.size:
                    old = arr[idx]
                    if s.dst:
                        self.vars[s.dst] = self._coerce(s.dst, old)
                    v = self.ev(s.value)
                    if s.op == "add":
                        arr[idx] = old + v
                    elif s.op == "max":
                        arr[idx] = max(old, v)
                    else:
                        arr[idx] = min(old, v)
            elif isinstance(s, K.Barrier):
                yield ("barrier", s.level)
            elif isinstance(s, K.WarpCall):
                val = self.ev(s.args[0])
                extra = [self.ev(a) for a in s.args[1:]]
                res = yield ("collective", s.func, val, tuple(extra),
                             s.width or self.W)
                if s.dst:
                    self.vars[s.dst] = self._coerce(s.dst, res)
            elif isinstance(s, K.If):
                if bool(self.ev(s.cond)):
                    yield from self.stmts(s.then_body)
                else:
                    yield from self.stmts(s.else_body)
            elif isinstance(s, K.While):
                guard = 0
                while bool(self.ev(s.cond)):
                    yield from self.stmts(s.body)
                    guard += 1
                    if guard > 1_000_000:
                        raise CoxUnsupported("oracle loop guard tripped")
            elif isinstance(s, K.Return):
                return
            else:
                raise CoxUnsupported(f"oracle cannot run {s!r}")


# ---------------------------------------------------------------------------
# Warp-collective math (independent scalar implementations)
# ---------------------------------------------------------------------------


def _collective(func: str, lanes: List[int], vals: Dict[int, Any],
                extras: Dict[int, tuple], width: int) -> Dict[int, Any]:
    """lanes: lane ids (within warp) present; returns result per lane."""
    out: Dict[int, Any] = {}
    segs: Dict[int, List[int]] = {}
    for ln in lanes:
        segs.setdefault(ln // width, []).append(ln)
    for seg_lanes in segs.values():
        seg_set = set(seg_lanes)
        base = (seg_lanes[0] // width) * width
        if func == "vote_all":
            r = all(bool(vals[ln]) for ln in seg_lanes)
            for ln in seg_lanes:
                out[ln] = r
        elif func == "vote_any":
            r = any(bool(vals[ln]) for ln in seg_lanes)
            for ln in seg_lanes:
                out[ln] = r
        elif func == "ballot":
            r = 0
            for ln in seg_lanes:
                if bool(vals[ln]):
                    r |= 1 << (ln - base)
            for ln in seg_lanes:
                out[ln] = r
        elif func == "red_add":
            r = sum(vals[ln] for ln in seg_lanes)
            for ln in seg_lanes:
                out[ln] = r
        elif func == "red_max":
            r = max(vals[ln] for ln in seg_lanes)
            for ln in seg_lanes:
                out[ln] = r
        elif func == "red_min":
            r = min(vals[ln] for ln in seg_lanes)
            for ln in seg_lanes:
                out[ln] = r
        elif func == "shfl_down":
            for ln in seg_lanes:
                src = ln + int(extras[ln][0])
                out[ln] = vals[src] if (src - base) < width and src in seg_set \
                    else vals[ln]
        elif func == "shfl_up":
            for ln in seg_lanes:
                src = ln - int(extras[ln][0])
                out[ln] = vals[src] if (src - base) >= 0 and src in seg_set \
                    else vals[ln]
        elif func == "shfl_xor":
            for ln in seg_lanes:
                src = ln ^ int(extras[ln][0])
                out[ln] = vals[src] if src in seg_set else vals[ln]
        elif func == "shfl_idx":
            for ln in seg_lanes:
                src = base + (int(extras[ln][0]) % width)
                out[ln] = vals[src] if src in seg_set else vals[ln]
        else:
            raise CoxUnsupported(f"oracle collective {func}")
    return out


# ---------------------------------------------------------------------------
# Block scheduler
# ---------------------------------------------------------------------------


def run_block(kernel: K.Kernel, *, bid: int, block: int, grid: int,
              warp_size: int, scalars: Dict[str, Any],
              globals_: Dict[str, np.ndarray], var_types: Dict[str, DType],
              block_dim=None, grid_dim=None, state: Optional[dict] = None):
    """Run one block to completion.  ``state`` carries the block's
    persistent context across cooperative grid-sync phases: per-thread
    local variables (CUDA: registers live for the thread's lifetime) and
    shared memory (lives for the block's lifetime).  Returns the state
    for the next phase."""
    uniforms = {"bid": bid, "bdim": block, "gdim": grid,
                "bdim3": dim3_tuple(block_dim) or (block, 1, 1),
                "gdim3": dim3_tuple(grid_dim) or (grid, 1, 1)}
    uniforms.update(scalars)
    shmem = (state["shmem"] if state is not None else
             {s.name: np.zeros(int(np.prod(s.shape)), _np(s.dtype))
              for s in kernel.shared})
    gens = []
    threads = []
    for tid in range(block):
        th = _Thread(kernel, tid, warp_size, uniforms, globals_, shmem,
                     var_types)
        if state is not None:
            th.vars = dict(state["vars"][tid])
        threads.append(th)
        gens.append(th.run())

    event: List[Optional[tuple]] = [None] * block
    done = [False] * block

    def step(tid, send=None):
        try:
            event[tid] = gens[tid].send(send) if send is not None or \
                event[tid] is not None else next(gens[tid])
        except StopIteration:
            event[tid] = None
            done[tid] = True

    def first_step(tid):
        try:
            event[tid] = next(gens[tid])
        except StopIteration:
            event[tid] = None
            done[tid] = True

    for tid in range(block):
        first_step(tid)

    n_warps = -(-block // warp_size)
    for _ in range(10_000_000):
        if all(done):
            return {"vars": [th.vars for th in threads], "shmem": shmem}
        progressed = False
        # 1) release any warp whose live lanes all sit at the same warp event
        for w in range(n_warps):
            tids = [t for t in range(w * warp_size,
                                     min((w + 1) * warp_size, block))]
            live = [t for t in tids if not done[t]]
            if not live:
                continue
            evs = [event[t] for t in live]
            if any(e is None for e in evs):
                continue
            kinds = {e[0] for e in evs}
            if kinds == {"collective"}:
                funcs = {(e[1], e[4]) for e in evs}
                if len(funcs) != 1:
                    raise OracleMisaligned(
                        f"warp {w}: lanes at different collectives {funcs}")
                func, width = evs[0][1], evs[0][4]
                lanes = [t - w * warp_size for t in live]
                vals = {t - w * warp_size: event[t][2] for t in live}
                extras = {t - w * warp_size: event[t][3] for t in live}
                res = _collective(func, lanes, vals, extras, width)
                for t in live:
                    ev_res = res[t - w * warp_size]
                    try:
                        event[t] = gens[t].send(ev_res)
                    except StopIteration:
                        event[t] = None
                        done[t] = True
                progressed = True
            elif kinds == {"barrier"} and all(
                    e[1] == BarrierLevel.WARP for e in evs):
                for t in live:
                    try:
                        event[t] = gens[t].send(None)
                    except StopIteration:
                        event[t] = None
                        done[t] = True
                progressed = True
        if progressed:
            continue
        # 2) all live threads at a block barrier → release everyone
        live = [t for t in range(block) if not done[t]]
        if live and all(event[t] is not None and event[t][0] == "barrier"
                        and event[t][1] == BarrierLevel.BLOCK for t in live):
            for t in live:
                try:
                    event[t] = gens[t].send(None)
                except StopIteration:
                    event[t] = None
                    done[t] = True
            continue
        raise OracleMisaligned(
            f"deadlock: events={[(t, event[t]) for t in live][:8]}")
    raise CoxUnsupported("oracle scheduler guard tripped")


def run_grid(kernel: K.Kernel, *, grid, block, args: Sequence[Any],
             warp_size: int = 32) -> Dict[str, np.ndarray]:
    """Reference execution of kernel<<<grid, block>>>(*args); ``grid``
    and ``block`` accept ``int | (x, y[, z])`` dim3 geometry (threads
    linearize x-fastest into warps, blocks into the grid walk).

    Cooperative kernels (``this_grid().sync()``) run with the same phase
    split the compiler uses (``repro.core.phases``): all blocks complete
    phase *p* before any block starts phase *p+1* — the grid barrier's
    guarantee — with each block's per-thread locals and shared memory
    persisting across phases."""
    from .phases import split_phases
    from .typeinfer import infer
    from .types import as_dim3
    grid3 = as_dim3(grid, "grid")
    block3 = as_dim3(block, "block")
    var_types = infer(kernel)
    phase_kernels = split_phases(kernel)
    globals_: Dict[str, np.ndarray] = {}
    shapes: Dict[str, tuple] = {}
    scalars: Dict[str, Any] = {}
    for spec, val in zip(kernel.params, args):
        if isinstance(spec, ArraySpec):
            a = np.asarray(val, _np(spec.dtype))
            shapes[spec.name] = a.shape
            globals_[spec.name] = a.reshape(-1).copy()
        else:
            scalars[spec.name] = _np(spec.dtype)(val)
    states: List[Optional[dict]] = [None] * grid3.total
    for pk in phase_kernels:
        for bid in range(grid3.total):
            states[bid] = run_block(
                pk, bid=bid, block=block3.total, grid=grid3.total,
                warp_size=warp_size, scalars=scalars, globals_=globals_,
                var_types=var_types, block_dim=block3, grid_dim=grid3,
                state=states[bid])
    return {k: v.reshape(shapes[k]) for k, v in globals_.items()}
