"""Two-level vectorized executor for hierarchically-collapsed kernels.

Generated-code shape (paper Code 3):

    for each block-level PR:                 # block machine node
        for wid in range(n_warps):           # inter-warp loop
            run the PR's warp-level machine  # warp PRs + peeled branches
              — every warp PR evaluates all W lanes at once (intra-warp
                "loop" == the vector lane axis; AVX in the paper, VPU
                lanes on TPU, XLA-vectorized on CPU)

Loop peeling (paper §3.3.1 / Code 3 line 10): branch conditions are
evaluated by *all* lanes (side effects preserved) but the branch
direction is taken from lane 0 (warp level) or warp 0 lane 0 (block
level) — sound under the aligned-barrier assumption.

Modes:
* ``jit``    — inter-warp loops unrolled at trace time (block size burned
               in; the paper's JIT mode, Fig. 13) and static-trip
               predicated loops unrolled;
* ``normal`` — `lax.fori_loop` inter-warp loop, one trace serves any
               grid; block size still static per JAX shape rules (the
               runtime-configuration analogue).

Warp execution (``warp_exec``, orthogonal to the mode):
* ``serial``  — the inter-warp loop above: one warp at a time threads
                through each block-level PR (the paper's Code 3 shape);
* ``batched`` — COX's guarantee that warps are independent *between
                barriers* is exposed to XLA: all ``n_warps`` warps of a
                block-level PR run simultaneously as one ``(n_warps, W)``
                lane plane (``jax.vmap`` over the warp axis of the
                warp-level machine walk).  Each warp runs on its own copy
                of shared memory and global memory with write-mask /
                atomic-delta tracking; the copies are reconciled at every
                block-level PR boundary (== every block barrier) by the
                same bit-exact single-writer select merge the grid backends
                use (``backends/merge.py``) — bitwise-identical to serial
                execution for race-free kernels.  Block-replicated vars
                are handed to each warp as its own (W,) row; the stacked
                rows are the merged plane.  Warp-peel branch directions become
                per-warp (each warp's lane 0 decides; divergent warps
                advance their PC machines independently under vmap's
                masked while/switch batching).

``simd=False`` switches warp collectives to per-lane loop emulation
(Table 2's "w/o AVX" baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives
from . import kernel_ir as K
from .cfg import CFG, WarpBufCompute, WarpBufStore
from .lower import lower_kernel
from .passes import (insert_extra_barriers, lower_warp_intrinsics,
                     split_blocks_at_barriers)
from .regions import (EXIT, BlockPR, Machine, WarpPR, build_machine,
                      replication_classes, warp_peel_count)
from .typeinfer import infer
from .types import (ArraySpec, CoxUnsupported, DType, ScalarSpec,
                    dim3_tuple)

_UNROLL_LIMIT = 64  # static-trip predicated loops up to this are unrolled in jit mode


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledKernel:
    """Result of the full pass pipeline, ready to stage into JAX.

    A kernel with grid-wide barriers (``c.grid_sync()``) compiles to a
    *multi-phase* container: ``phases`` holds one ordinary single-phase
    CompiledKernel per inter-sync program segment (``repro.core.phases``)
    and ``cfg``/``machine``/``classes`` of the container itself are
    unset — backends build one executable per phase and thread global
    memory plus the ``carried`` per-block state between them.  Kernels
    without a grid sync compile exactly as before (``phases == ()``).
    """
    kernel: K.Kernel
    cfg: Optional[CFG]
    machine: Optional[Machine]
    var_types: Dict[str, DType]
    classes: Dict[str, str]            # var -> 'block' | 'warp'
    warp_bufs: Dict[str, DType]
    warp_size: int
    phases: Tuple["CompiledKernel", ...] = ()   # per-phase compilations
    carried: Tuple[str, ...] = ()               # locals live across phases

    @property
    def n_phases(self) -> int:
        return len(self.phases) or 1

    def phase_list(self) -> Tuple["CompiledKernel", ...]:
        """The executable phase sequence — ``(self,)`` for the ordinary
        single-phase case."""
        return self.phases or (self,)

    @property
    def array_params(self) -> List[ArraySpec]:
        return [p for p in self.kernel.params if isinstance(p, ArraySpec)]

    @property
    def scalar_params(self) -> List[ScalarSpec]:
        return [p for p in self.kernel.params if isinstance(p, ScalarSpec)]

    def summary(self) -> str:
        if self.phases:
            inner = "; ".join(p.summary() for p in self.phases)
            return (f"kernel {self.kernel.name}: {len(self.phases)} "
                    f"grid-sync phases, {len(self.carried)} carried "
                    f"locals [{inner}]")
        n_bpr = sum(isinstance(n, BlockPR) for n in self.machine.nodes)
        n_wpr = sum(
            sum(isinstance(w, WarpPR) for w in n.warp.nodes)
            for n in self.machine.nodes if isinstance(n, BlockPR))
        n_peel = warp_peel_count(self.machine)
        return (f"kernel {self.kernel.name}: {len(self.cfg.blocks)} blocks, "
                f"{n_bpr} block-level PRs, {n_wpr} warp-level PRs, "
                f"{n_peel} warp peels, "
                f"{len([v for v, c in self.classes.items() if c == 'block'])} "
                f"block-replicated vars")


def compile_kernel(kernel: K.Kernel, warp_size: int = 32) -> CompiledKernel:
    """Run the hierarchical-collapsing pipeline (paper Fig. 4 steps 1-5).

    Kernels using ``c.grid_sync()`` are phase-split first (see
    ``repro.core.phases``): type inference runs once over the full
    kernel so cross-phase locals agree, each phase runs the unchanged
    pipeline, and locals live across phase boundaries are forced to the
    'block' replication class in every phase so their ``(n_warps, W)``
    planes can be carried between phase executables."""
    from .phases import carried_locals, split_phases
    phase_kernels = split_phases(kernel)
    if len(phase_kernels) == 1:
        return _compile_one(kernel, warp_size, infer(kernel), ())
    var_types = infer(kernel)          # full-kernel: cross-phase var types
    carried = tuple(sorted(carried_locals(kernel, phase_kernels)))
    compiled = tuple(_compile_one(pk, warp_size, dict(var_types), carried)
                     for pk in phase_kernels)
    uniforms = {p.name for p in kernel.params if isinstance(p, ScalarSpec)}
    vt = {v: t for v, t in var_types.items() if v not in uniforms}
    return CompiledKernel(kernel, None, None, vt, {}, {}, warp_size,
                          phases=compiled, carried=carried)


def _compile_one(kernel: K.Kernel, warp_size: int,
                 var_types: Dict[str, DType],
                 force_block: Tuple[str, ...]) -> CompiledKernel:
    """The single-phase pipeline (paper Fig. 4 steps 1-5)."""
    cfg = lower_kernel(kernel)
    warp_bufs = lower_warp_intrinsics(cfg, var_types)
    for b, dt in warp_bufs.items():
        var_types[b] = dt
    insert_extra_barriers(cfg)
    split_blocks_at_barriers(cfg)
    cfg.verify()
    machine = build_machine(cfg)
    uniforms = {p.name for p in kernel.params if isinstance(p, ScalarSpec)}
    for u in uniforms:  # scalar params are block-uniform, never replicated
        var_types.pop(u, None)
    classes = replication_classes(machine, uniforms)
    # every var assigned anywhere must have a class; default to warp-local
    for v in var_types:
        classes.setdefault(v, "warp")
    # cross-phase locals must live in the carried (n_warps, W) plane
    for v in force_block:
        classes[v] = "block"
    return CompiledKernel(kernel, cfg, machine, var_types, classes,
                          warp_bufs, warp_size)


# ---------------------------------------------------------------------------
# Environment
# ---------------------------------------------------------------------------


class _Env:
    """Mutable view over the machine state for one (block, warp) context."""

    def __init__(self, ck: CompiledKernel, *, wid, n_warps: int,
                 uniforms: Dict[str, Any], warp_vars: Dict[str, Any],
                 block_vars: Dict[str, Any], shmem: Dict[str, Any],
                 globals_: Dict[str, Any], simd: bool,
                 track_writes: bool = False,
                 store_masks: Optional[Dict[str, Any]] = None,
                 atomic_deltas: Optional[Dict[str, Any]] = None,
                 shared_masks: Optional[Dict[str, Any]] = None,
                 block_rows: bool = False,
                 log_arrays: Optional[Set[str]] = None,
                 block_dim3: Optional[Tuple[int, int, int]] = None,
                 grid_dim3: Optional[Tuple[int, int, int]] = None):
        self.ck = ck
        # static dim3 extents for the per-axis intrinsics; None means a
        # 1-D launch whose extents live in the uniforms (tid_x/bid_x are
        # the linear ids, y/z are zero)
        self.block_dim3 = block_dim3
        self.grid_dim3 = grid_dim3
        self.W = ck.warp_size
        self.wid = wid
        self.n_warps = n_warps
        self.uniforms = uniforms
        # Pre-allocate every warp-replicated local so lax control-flow
        # carries have a stable pytree structure.
        if not warp_vars:
            warp_vars = {
                v: jnp.zeros((self.W,), ck.var_types.get(v, DType.f32).jnp)
                for v, c in ck.classes.items()
                if c == "warp" and v not in uniforms}
        self.warp_vars = warp_vars
        self.block_vars = block_vars
        self.shmem = shmem
        self.globals = globals_
        self.simd = simd
        self.track_writes = track_writes
        self.store_masks = store_masks if store_masks is not None else {}
        self.atomic_deltas = atomic_deltas if atomic_deltas is not None else {}
        # shared-memory write masks: tracked only under warp-batched
        # execution, where each warp runs on its own copy of shared
        # memory and the copies merge at block-level PR boundaries
        self.track_shared = shared_masks is not None
        self.shared_masks = shared_masks if shared_masks is not None else {}
        # batched warp plane: block-replicated vars are handed to each
        # warp as its own (W,) row (a warp never touches another warp's
        # row, so the full (n_warps, W) plane would only buy every
        # write a batched scatter); serial mode keeps the plane and
        # indexes it with wid
        self.block_rows = block_rows
        # store log (batched warp plane): stores to arrays this PR never
        # reads skip the copy/mask machinery entirely — each executed
        # StoreGlobal appends its (safe idx, value) lane vectors here and
        # the plane runner replays them onto the carried array with one
        # flat scatter per store instruction, O(n_warps × W) instead of
        # O(n_warps × |array|)
        self.log_arrays = log_arrays if log_arrays is not None else set()
        self.store_log: List[Tuple[str, Any, Any]] = []
        self.lane = jnp.arange(self.W, dtype=jnp.int32)

    @property
    def base_mask(self):
        tid = jnp.asarray(self.wid, jnp.int32) * self.W + self.lane
        return tid < jnp.asarray(self.uniforms["bdim"], jnp.int32)

    # ---------------- state snapshot (for lax control flow) ----------------

    def state(self) -> Dict[str, Any]:
        return {"wv": dict(self.warp_vars), "bv": dict(self.block_vars),
                "sh": dict(self.shmem), "g": dict(self.globals),
                "sm": dict(self.store_masks), "ad": dict(self.atomic_deltas),
                "shm": dict(self.shared_masks)}

    def load(self, st: Dict[str, Any]):
        self.warp_vars = dict(st["wv"])
        self.block_vars = dict(st["bv"])
        self.shmem = dict(st["sh"])
        self.globals = dict(st["g"])
        self.store_masks = dict(st["sm"])
        self.atomic_deltas = dict(st["ad"])
        self.shared_masks = dict(st["shm"])

    # ---------------- variables ----------------

    def _dtype(self, name: str) -> DType:
        return self.ck.var_types.get(name, DType.f32)

    def read_var(self, name: str):
        if name in self.uniforms:
            return jnp.asarray(self.uniforms[name])
        cls = self.ck.classes.get(name, "warp")
        if cls == "warp":
            return self.warp_vars[name]
        if self.block_rows:
            return self.block_vars[name]
        return self.block_vars[name][self.wid]

    def write_var(self, name: str, value, mask=None):
        dt = self._dtype(name).jnp
        value = jnp.broadcast_to(jnp.asarray(value).astype(dt), (self.W,))
        if mask is not None:
            value = jnp.where(mask, value, self.read_var(name))
        cls = self.ck.classes.get(name, "warp")
        if cls == "warp":
            self.warp_vars[name] = value
        elif self.block_rows:
            self.block_vars[name] = value
        else:
            self.block_vars[name] = self.block_vars[name].at[self.wid].set(value)


# ---------------------------------------------------------------------------
# Expression evaluation (vectorized across the warp's lanes)
# ---------------------------------------------------------------------------

_BINOPS = {
    "+": jnp.add, "-": jnp.subtract, "*": jnp.multiply,
    "%": jnp.remainder, "&": None, "|": None, "^": None,
    "<<": jnp.left_shift, ">>": jnp.right_shift,
    "min": jnp.minimum, "max": jnp.maximum,
}

_CMPS = {"<": jnp.less, "<=": jnp.less_equal, ">": jnp.greater,
         ">=": jnp.greater_equal, "==": jnp.equal, "!=": jnp.not_equal}


def eval_expr(e: K.Expr, env: _Env):
    if isinstance(e, K.Const):
        return jnp.asarray(e.value, (e.dtype or DType.f32).jnp)
    if isinstance(e, K.Var):
        return env.read_var(e.name)
    if isinstance(e, K.Special):
        return _eval_special(e, env)
    if isinstance(e, K.BinOp):
        a, b = eval_expr(e.lhs, env), eval_expr(e.rhs, env)
        if e.op == "/":
            return jnp.true_divide(a.astype(jnp.float32), b.astype(jnp.float32)) \
                if not (jnp.issubdtype(a.dtype, jnp.floating)
                        or jnp.issubdtype(b.dtype, jnp.floating)) \
                else jnp.true_divide(a, b)
        if e.op == "//":
            return jnp.floor_divide(a, b)
        if e.op in ("&", "|", "^"):
            if a.dtype == jnp.bool_ or b.dtype == jnp.bool_:
                f = {"&": jnp.logical_and, "|": jnp.logical_or,
                     "^": jnp.logical_xor}[e.op]
                return f(a, b)
            f = {"&": jnp.bitwise_and, "|": jnp.bitwise_or,
                 "^": jnp.bitwise_xor}[e.op]
            return f(a, b)
        return _BINOPS[e.op](a, b)
    if isinstance(e, K.CmpOp):
        return _CMPS[e.op](eval_expr(e.lhs, env), eval_expr(e.rhs, env))
    if isinstance(e, K.BoolOp):
        vals = [eval_expr(a, env).astype(jnp.bool_) for a in e.args]
        out = vals[0]
        for v in vals[1:]:
            out = jnp.logical_and(out, v) if e.op == "and" else jnp.logical_or(out, v)
        return out
    if isinstance(e, K.UnOp):
        v = eval_expr(e.operand, env)
        if e.op == "neg":
            return -v
        if e.op == "not":
            return jnp.logical_not(v.astype(jnp.bool_))
        if e.op == "abs":
            return jnp.abs(v)
        if e.op in ("f32", "i32", "f16", "bf16", "u32"):
            return v.astype(DType(e.op).jnp)
        if e.op == "rsqrt":
            return lax.rsqrt(v.astype(jnp.float32))
        if e.op == "sigmoid":
            return jax.nn.sigmoid(v.astype(jnp.float32))
        fn = {"exp": jnp.exp, "log": jnp.log, "sqrt": jnp.sqrt,
              "tanh": jnp.tanh, "floor": jnp.floor}[e.op]
        return fn(v.astype(jnp.float32) if v.dtype in
                  (jnp.int32, jnp.bool_) else v)
    if isinstance(e, K.Select):
        return jnp.where(eval_expr(e.cond, env).astype(jnp.bool_),
                         eval_expr(e.on_true, env), eval_expr(e.on_false, env))
    if isinstance(e, K.LoadGlobal):
        idx = eval_expr(e.index, env).astype(jnp.int32)
        arr = env.globals[e.array]
        val = arr.at[idx].get(mode="fill", fill_value=0)
        if env.track_writes and e.array in env.atomic_deltas:
            val = val + env.atomic_deltas[e.array].at[idx].get(
                mode="fill", fill_value=0)
        return val
    if isinstance(e, K.LoadShared):
        idx = eval_expr(e.index, env).astype(jnp.int32)
        return env.shmem[e.array].at[idx].get(mode="fill", fill_value=0)
    raise CoxUnsupported(f"cannot evaluate {e!r}")


_AXIS_IX = {"x": 0, "y": 1, "z": 2}


def _decompose(lin, extents, axis: str):
    """x-fastest dim3 decomposition of a linear id against static
    extents, with degenerate-axis shortcuts that keep 1-D launches free
    of mod/div ops and 2-D launches down to one op per axis (lanes past
    the logical extent — the partial last warp — produce out-of-range
    components exactly as the linear path always has; their stores are
    masked off)."""
    dx, dy, dz = extents
    if axis == "x":
        return lin if dy == 1 and dz == 1 else lin % dx
    if axis == "y":
        if dy == 1:
            return jnp.zeros_like(lin)
        return lin // dx if dz == 1 else (lin // dx) % dy
    return jnp.zeros_like(lin) if dz == 1 else lin // (dx * dy)


def _eval_special(e: K.Special, env: _Env):
    """Thread-identity intrinsics.  The schedule is linear (warps over
    the x-fastest linearized block, a lax walk over linear block ids);
    per-axis values are cheap decompositions against the launch's
    static dim3 extents — per-lane (tx, ty, tz) vectors and per-block
    (bx, by, bz) uniforms."""
    if e.kind == "lane":
        return env.lane
    if e.kind == "wid":
        return jnp.broadcast_to(jnp.asarray(env.wid, jnp.int32), (env.W,))
    if e.kind == "wsize":
        return jnp.asarray(env.W, jnp.int32)
    axis = getattr(e, "axis", "x")
    if e.kind == "tid":
        lin = jnp.asarray(env.wid, jnp.int32) * env.W + env.lane
        if env.block_dim3 is None:  # direct make_block_fn caller: 1-D
            return lin if axis == "x" else jnp.zeros_like(lin)
        return _decompose(lin, env.block_dim3, axis)
    if e.kind == "bid":
        bid = jnp.asarray(env.uniforms["bid"], jnp.int32)
        if env.grid_dim3 is None:
            return bid if axis == "x" else jnp.zeros_like(bid)
        return _decompose(bid, env.grid_dim3, axis)
    if e.kind == "bdim":
        if env.block_dim3 is None:
            return jnp.asarray(env.uniforms["bdim"], jnp.int32)
        return jnp.asarray(env.block_dim3[_AXIS_IX[axis]], jnp.int32)
    if e.kind == "gdim":
        if env.grid_dim3 is None:
            return jnp.asarray(env.uniforms["gdim"], jnp.int32)
        return jnp.asarray(env.grid_dim3[_AXIS_IX[axis]], jnp.int32)
    return jnp.asarray(env.uniforms[e.kind], jnp.int32)


# ---------------------------------------------------------------------------
# Instruction execution (with predication masks for barrier-free divergence)
# ---------------------------------------------------------------------------


def _store_mask(env: _Env, mask):
    m = env.base_mask
    return m if mask is None else (m & mask)


def _safe_idx(idx, m, size):
    idx = jnp.broadcast_to(idx.astype(jnp.int32), m.shape)
    return jnp.where(m, idx, jnp.int32(size))  # size == one-past-end → dropped


def exec_instrs(instrs: List, env: _Env, mask, *, jit_mode: bool):
    for ins in instrs:
        exec_instr(ins, env, mask, jit_mode=jit_mode)


def exec_instr(ins, env: _Env, mask, *, jit_mode: bool):
    if isinstance(ins, K.Assign):
        env.write_var(ins.name, eval_expr(ins.value, env), mask)
    elif isinstance(ins, K.StoreGlobal):
        m = _store_mask(env, mask)
        arr = env.globals[ins.array]
        idx = _safe_idx(eval_expr(ins.index, env), m, arr.shape[0])
        val = jnp.broadcast_to(
            jnp.asarray(eval_expr(ins.value, env)).astype(arr.dtype), m.shape)
        if ins.array in env.log_arrays:
            env.store_log.append((ins.array, idx, val))
            return
        env.globals[ins.array] = arr.at[idx].set(val, mode="drop")
        if env.track_writes:
            sm = env.store_masks[ins.array]
            env.store_masks[ins.array] = sm.at[idx].set(True, mode="drop")
    elif isinstance(ins, K.StoreShared):
        m = _store_mask(env, mask)
        arr = env.shmem[ins.array]
        idx = _safe_idx(eval_expr(ins.index, env), m, arr.shape[0])
        val = jnp.broadcast_to(
            jnp.asarray(eval_expr(ins.value, env)).astype(arr.dtype), m.shape)
        env.shmem[ins.array] = arr.at[idx].set(val, mode="drop")
        if env.track_shared:
            shm = env.shared_masks[ins.array]
            env.shared_masks[ins.array] = shm.at[idx].set(True, mode="drop")
    elif isinstance(ins, K.AtomicRMW):
        m = _store_mask(env, mask)
        if env.track_writes:
            tgt = env.atomic_deltas[ins.array]
        else:
            tgt = env.globals[ins.array]
        idx = _safe_idx(eval_expr(ins.index, env), m, tgt.shape[0])
        val = jnp.broadcast_to(
            jnp.asarray(eval_expr(ins.value, env)).astype(tgt.dtype), m.shape)
        if ins.dst:
            if env.track_writes:
                # tgt is the per-block delta buffer (zeroed per block),
                # NOT the value a serial execution would observe, and
                # cross-block uniqueness of captured old values (ticket
                # patterns) cannot hold under delta merging at all.
                # LaunchPlan.check_mergeable rejects such launches
                # before tracing; this guard catches any future
                # make_block_fn caller that skips it.
                raise CoxUnsupported(
                    "atomic old-value capture under write-tracking: "
                    "captured old values are only exact under serial "
                    "execution — use the scan backend")
            old = tgt.at[jnp.where(m, idx, 0)].get(mode="fill", fill_value=0)
            env.write_var(ins.dst, old, mask)
        if ins.op == "add":
            new = tgt.at[idx].add(val, mode="drop")
        elif ins.op == "max":
            new = tgt.at[idx].max(val, mode="drop")
        else:
            new = tgt.at[idx].min(val, mode="drop")
        if env.track_writes:
            env.atomic_deltas[ins.array] = new
        else:
            env.globals[ins.array] = new
    elif isinstance(ins, K.Barrier):
        pass  # structural only — ordering is preserved by lane vectorization
    elif isinstance(ins, WarpBufStore):
        if mask is not None:
            raise CoxUnsupported(
                "warp collective inside divergent (predicated) control flow — "
                "dynamic-mask collectives are outside the supported set "
                "(paper §2.2.3)")
        env.write_var(ins.buf, eval_expr(ins.value, env), None)
    elif isinstance(ins, WarpBufCompute):
        if mask is not None:
            raise CoxUnsupported("warp collective inside divergent control flow")
        buf = env.read_var(ins.buf)
        fn = collectives.dispatch(ins.func, env.simd)
        extra = [eval_expr(a, env) for a in ins.args]
        res = fn(buf, *extra, W=env.W, width=ins.width, mask=env.base_mask)
        env.write_var(ins.dst, res, None)
    elif isinstance(ins, K.If):
        cond = eval_expr(ins.cond, env).astype(jnp.bool_)
        cond = jnp.broadcast_to(cond, (env.W,))
        m_t = cond if mask is None else (mask & cond)
        exec_instrs(ins.then_body, env, m_t, jit_mode=jit_mode)
        if ins.else_body:
            m_f = ~cond if mask is None else (mask & ~cond)
            exec_instrs(ins.else_body, env, m_f, jit_mode=jit_mode)
    elif isinstance(ins, K.While):
        _exec_masked_while(ins, env, mask, jit_mode=jit_mode)
    elif isinstance(ins, K.Return):
        raise CoxUnsupported("return must terminate the kernel")
    else:
        raise CoxUnsupported(f"cannot execute {ins!r}")


def _written_names(instrs) -> Tuple[Set[str], Set[str], Set[str], Set[str]]:
    """(variables, global arrays, shared arrays, atomic targets) a
    statement list may write, descending into If/While — the minimal lax
    carry for a loop, and the minimal per-warp copy/merge set for the
    batched warp plane.  Atomic targets are also members of the global
    set; they are reported separately because they merge by delta sum,
    not writer selection."""
    wv: Set[str] = set()
    arrays: Set[str] = set()
    sh: Set[str] = set()
    atomics: Set[str] = set()
    stack = list(instrs)
    while stack:
        s = stack.pop()
        if isinstance(s, K.Assign):
            wv.add(s.name)
        elif isinstance(s, K.StoreGlobal):
            arrays.add(s.array)
        elif isinstance(s, K.StoreShared):
            sh.add(s.array)
        elif isinstance(s, K.AtomicRMW):
            arrays.add(s.array)
            atomics.add(s.array)
            if s.dst:
                wv.add(s.dst)
        elif isinstance(s, WarpBufStore):
            wv.add(s.buf)
        elif isinstance(s, WarpBufCompute):
            wv.add(s.dst)
        elif isinstance(s, K.If):
            stack.extend(s.then_body)
            stack.extend(s.else_body)
        elif isinstance(s, K.While):
            stack.extend(s.body)
    return wv, arrays, sh, atomics


def _instr_exprs(s):
    """Every expression an instruction evaluates (not descending into
    nested statements)."""
    if isinstance(s, K.Assign):
        return [s.value]
    if isinstance(s, (K.StoreGlobal, K.StoreShared)):
        return [s.index, s.value]
    if isinstance(s, K.AtomicRMW):
        return [s.index, s.value]
    if isinstance(s, WarpBufStore):
        return [s.value]
    if isinstance(s, WarpBufCompute):
        return list(s.args)
    if isinstance(s, K.If):
        return [s.cond]
    if isinstance(s, K.While):
        return [s.cond]
    return []


def _loaded_globals(instrs) -> Set[str]:
    """Global arrays any expression in ``instrs`` may read."""
    out: Set[str] = set()
    stack = list(instrs)
    estack: List[K.Expr] = []
    while stack:
        s = stack.pop()
        estack.extend(_instr_exprs(s))
        if isinstance(s, K.If):
            stack.extend(s.then_body)
            stack.extend(s.else_body)
        elif isinstance(s, K.While):
            stack.extend(s.body)
    while estack:
        e = estack.pop()
        if isinstance(e, K.LoadGlobal):
            out.add(e.array)
        estack.extend(K.expr_children(e))
    return out


def _stored_in_while(instrs, in_while: bool = False) -> Set[str]:
    """Global arrays stored from inside a While body — their stores
    execute inside a lax.while trace, so they cannot use the store log
    (log entries must escape to the post-vmap replay)."""
    out: Set[str] = set()
    for s in instrs:
        if isinstance(s, K.StoreGlobal) and in_while:
            out.add(s.array)
        elif isinstance(s, K.If):
            out |= _stored_in_while(s.then_body, in_while)
            out |= _stored_in_while(s.else_body, in_while)
        elif isinstance(s, K.While):
            out |= _stored_in_while(s.body, True)
    return out


@dataclasses.dataclass(frozen=True)
class _PRPlan:
    """Static per-block-level-PR execution plan for the batched warp
    plane: what to copy/mask/merge, and which stores can go through the
    replay log instead."""
    block_vars: Tuple[str, ...]   # block-replicated vars written
    shared: Tuple[str, ...]       # shared arrays written (mask+merge)
    masked: Tuple[str, ...]       # globals on the copy/mask/merge path
    atomics: Tuple[str, ...]      # atomic targets (delta merge)
    logged: Tuple[str, ...]       # globals on the store-log path


def _pr_plan(ck: CompiledKernel, node: BlockPR) -> _PRPlan:
    """Write sets + store-log eligibility of one block-level PR.

    An array's stores go through the log when the warp graph is linear
    (log entries inside ``lax.switch`` branches cannot escape), every
    store to it sits outside While bodies, the PR never *loads* it (a
    logged store skips the per-warp copy, so a same-lane reload would
    read stale data), and it is not an atomic target in this PR."""
    wv: Set[str] = set()
    g: Set[str] = set()
    sh: Set[str] = set()
    at: Set[str] = set()
    loads: Set[str] = set()
    in_while: Set[str] = set()
    for bname in node.blocks:
        instrs = ck.cfg.blocks[bname].instrs
        w, a, s, t = _written_names(instrs)
        wv |= w
        g |= a
        sh |= s
        at |= t
        loads |= _loaded_globals(instrs)
        in_while |= _stored_in_while(instrs)
    bvw = {v for v in wv if ck.classes.get(v) == "block"}
    logged: Set[str] = set()
    if _try_linear(node.warp) is not None:
        logged = (g - at) - loads - in_while
    return _PRPlan(tuple(sorted(bvw)), tuple(sorted(sh)),
                   tuple(sorted((g - logged))), tuple(sorted(at)),
                   tuple(sorted(logged)))


def _exec_masked_while(ins: K.While, env: _Env, mask, *, jit_mode: bool):
    """Barrier-free loop with potentially lane-divergent trip counts:
    iterate while any lane is active, with per-lane masking (the
    whole-function-vectorization treatment of divergent loops).

    The lax carry holds only the state the body can write — carrying the
    full env (in particular the global-memory dict) would make every
    batched/vmapped execution of the loop select over whole arrays per
    iteration just to freeze finished instances."""
    if jit_mode and ins.static_trip is not None and ins.static_trip <= _UNROLL_LIMIT:
        for _ in range(ins.static_trip):
            cond = jnp.broadcast_to(
                eval_expr(ins.cond, env).astype(jnp.bool_), (env.W,))
            m = cond if mask is None else (mask & cond)
            exec_instrs(ins.body, env, m, jit_mode=jit_mode)
        return

    mask_in = jnp.ones((env.W,), jnp.bool_) if mask is None else mask
    wv, arrays, sh, _ = _written_names(ins.body)

    def snap():
        return {
            "wv": {k: v for k, v in env.warp_vars.items() if k in wv},
            "bv": {k: v for k, v in env.block_vars.items() if k in wv},
            "sh": {k: env.shmem[k] for k in sh if k in env.shmem},
            "g": {k: env.globals[k] for k in arrays if k in env.globals},
            "sm": {k: env.store_masks[k] for k in arrays
                   if k in env.store_masks},
            "ad": {k: env.atomic_deltas[k] for k in arrays
                   if k in env.atomic_deltas},
            "shm": {k: env.shared_masks[k] for k in sh
                    if k in env.shared_masks},
        }

    def load(st):
        env.warp_vars.update(st["wv"])
        env.block_vars.update(st["bv"])
        env.shmem.update(st["sh"])
        env.globals.update(st["g"])
        env.store_masks.update(st["sm"])
        env.atomic_deltas.update(st["ad"])
        env.shared_masks.update(st["shm"])

    def active(st) -> Any:
        load(st)
        cond = jnp.broadcast_to(
            eval_expr(ins.cond, env).astype(jnp.bool_), (env.W,))
        return mask_in & cond

    def cond_f(st):
        return jnp.any(active(st))

    def body_f(st):
        m = active(st)  # load(st) happened inside
        exec_instrs(ins.body, env, m, jit_mode=jit_mode)
        return snap()

    st = lax.while_loop(cond_f, body_f, snap())
    load(st)


# ---------------------------------------------------------------------------
# Warp-level machine (runs one warp through one block-level PR)
# ---------------------------------------------------------------------------


def _peel0(v):
    return v[0].astype(jnp.bool_)


def run_warp_graph(node: BlockPR, env: _Env, *, jit_mode: bool):
    """Execute the block-level PR's warp-level region graph for env.wid.
    Returns the exit index (which block-level successor to take)."""
    g = node.warp
    linear = _try_linear(g)
    if linear is not None:
        for wnode in linear:
            exec_instrs_of_warp_pr(wnode, env, jit_mode=jit_mode)
        return jnp.asarray(linear[-1].succ[1], jnp.int32)

    # general case: PC-dispatch machine
    EXITPC = len(g.nodes)

    def mk_fn(wnode):
        def fn(st):
            env.load(st["env"])
            if isinstance(wnode, WarpPR):
                exec_instrs_of_warp_pr(wnode, env, jit_mode=jit_mode)
                kind, val = wnode.succ
                if kind == "node":
                    pc, ex = jnp.int32(val), st["exit_ix"]
                else:
                    pc, ex = jnp.int32(EXITPC), jnp.int32(val)
            else:  # WarpPeel — loop peeling: lane 0 decides (paper §3.3.1)
                flag = _peel0(env.read_var(wnode.cond))
                def enc(tgt):
                    kind, val = tgt
                    if kind == "node":
                        return jnp.int32(val), st["exit_ix"]
                    return jnp.int32(EXITPC), jnp.int32(val)
                tp, te = enc(wnode.on_true)
                fp, fe = enc(wnode.on_false)
                pc = jnp.where(flag, tp, fp)
                ex = jnp.where(flag, te, fe)
            return {"pc": pc, "exit_ix": ex, "env": env.state()}
        return fn

    fns = [mk_fn(w) for w in g.nodes]

    def cond_f(st):
        return st["pc"] != EXITPC

    def body_f(st):
        return lax.switch(jnp.clip(st["pc"], 0, EXITPC - 1), fns, st)

    st0 = {"pc": jnp.int32(g.entry), "exit_ix": jnp.int32(0), "env": env.state()}
    st = lax.while_loop(cond_f, body_f, st0)
    env.load(st["env"])
    return st["exit_ix"]


def exec_instrs_of_warp_pr(wnode: WarpPR, env: _Env, *, jit_mode: bool):
    for bname in wnode.blocks:
        exec_instrs(env.ck.cfg.blocks[bname].instrs, env, None, jit_mode=jit_mode)


def _try_linear(g) -> Optional[List[WarpPR]]:
    """Fast path: the warp graph is a pure chain of PRs ending at exit 0
    (no peels, no cycles) — the shape every warp-feature-free PR has."""
    out: List[WarpPR] = []
    seen = set()
    cur = g.entry
    while True:
        node = g.nodes[cur]
        if not isinstance(node, WarpPR) or cur in seen:
            return None
        seen.add(cur)
        out.append(node)
        kind, val = node.succ
        if kind == "exit":
            return out
        cur = val


# ---------------------------------------------------------------------------
# Block-level machine
# ---------------------------------------------------------------------------


def make_block_fn(ck: CompiledKernel, *, n_warps: int, mode: str = "jit",
                  simd: bool = True, track_writes: bool = False,
                  warp_exec: str = "serial",
                  block_dim=None, grid_dim=None,
                  persist: Optional[Tuple[Tuple[str, ...],
                                          Tuple[str, ...]]] = None):
    """Build ``f(uniforms, globals[, masks, deltas]) -> (globals, masks,
    deltas)`` executing one CUDA block.  ``uniforms`` must contain bid,
    bdim, gdim and every scalar kernel parameter.

    ``persist=(var_names, shared_names)`` makes the block function one
    *phase* of a cooperative (grid-sync) kernel: it takes an extra
    ``state={"bv": {var: (n_warps, W)}, "sh": {name: flat}}`` argument
    holding this block's carried locals and shared memory from the
    previous phase (zeros for phase 0), and returns a fourth output with
    their final values.  Carried vars are 'block'-class in every phase
    (``compile_kernel`` forces this), so the state plugs straight into
    the block-replicated plane.

    ``block_dim``/``grid_dim`` are the launch's static dim3 extents
    (Dim3 or tuple); they feed only the per-axis intrinsics — the
    machine walk itself stays linear.  ``None`` (direct callers) means
    a 1-D launch: ``tid_x``/``bid_x`` are the linear ids, y/z are 0.

    ``warp_exec='batched'`` replaces the inter-warp loop with a
    ``jax.vmap`` over the warp axis: every block-level PR runs all
    ``n_warps`` warps at once as one ``(n_warps, W)`` lane plane, with
    per-warp copies of shared/global memory merged at each PR boundary
    (see the module docstring).  ``'serial'`` is the paper's Code 3
    inter-warp loop.
    """
    if warp_exec not in ("serial", "batched"):
        raise ValueError(f"unknown warp_exec {warp_exec!r}; "
                         f"expected 'serial' or 'batched'")
    if ck.phases:
        raise ValueError("make_block_fn runs one phase: pass a phase "
                         "CompiledKernel (ck.phase_list()), not the "
                         "multi-phase container")
    jit_mode = mode == "jit"
    W = ck.warp_size
    bdim3 = dim3_tuple(block_dim)
    gdim3 = dim3_tuple(grid_dim)
    all_atomics = [s for s in _all_instrs(ck) if isinstance(s, K.AtomicRMW)]
    has_atomics = bool(all_atomics)
    batch_warps = warp_exec == "batched" and n_warps > 1
    if batch_warps and any(s.dst for s in all_atomics):
        # defense in depth — LaunchPlan.check_warp_batchable rejects
        # these launches before tracing (see that docstring for why)
        raise CoxUnsupported(
            "atomic old-value capture under warp-batched execution: "
            "captured old values are only unique under serial warp "
            "order — use warp_exec='serial'")
    from .backends import merge  # deferred: backends imports execute
    pr_plans = ({n.id: _pr_plan(ck, n) for n in ck.machine.nodes
                 if isinstance(n, BlockPR)} if batch_warps else {})

    def block_fn(uniforms: Dict[str, Any], globals_: Dict[str, Any],
                 store_masks=None, atomic_deltas=None, state=None):
        block_vars = {
            v: jnp.zeros((n_warps, W), ck.var_types.get(v, DType.f32).jnp)
            for v, c in ck.classes.items() if c == "block"}
        shmem = {s.name: jnp.zeros((_prod(s.shape),), s.dtype.jnp)
                 for s in ck.kernel.shared}
        if persist is not None:
            if state is None:
                raise ValueError("persist block fn needs state= (carried "
                                 "per-block locals + shared memory)")
            block_vars.update({v: state["bv"][v] for v in persist[0]})
            shmem.update({s: state["sh"][s] for s in persist[1]})
        if track_writes:
            store_masks = store_masks if store_masks is not None else {
                k: jnp.zeros(v.shape, jnp.bool_) for k, v in globals_.items()}
            atomic_deltas = atomic_deltas if atomic_deltas is not None else ({
                k: jnp.zeros(v.shape, merge.num(v).dtype)
                for k, v in globals_.items()}
                if has_atomics else {})
        else:
            store_masks, atomic_deltas = {}, {}

        def run_warp_plane(node: BlockPR, bv, sh, g, sm, ad):
            """All warps of one block-level PR as a single (n_warps, W)
            lane plane: ``jax.vmap`` over the warp axis of the warp-level
            machine walk.  Sound because warps are independent between
            barriers (COX's hierarchical-collapsing guarantee) and every
            block-level PR boundary *is* a barrier boundary.

            Each warp runs on its own copy of shared/global memory with
            write-mask + atomic-delta tracking; the copies reconcile here
            via the backends' bit-exact single-writer select merge
            (masked integer-sum payload transport), so the
            merged state is bitwise-identical to the serial inter-warp
            loop for race-free kernels (atomic deltas sum order-free).
            Block-replicated vars are written only at each warp's own
            row, so the merged plane is the diagonal of the per-warp
            copies.  All warps reach the same exit under the
            aligned-barrier assumption; warp 0's is taken (the block-peel
            analogue of "warp 0 lane 0 decides")."""
            plan = pr_plans[node.id]
            # under write-tracking (vmap/sharded grid backends) per-warp
            # deltas start from the block's carried deltas so LoadGlobal
            # still observes earlier PRs' atomic effects; under the
            # loop-carried scan outer they start at zero (earlier deltas
            # are already folded into g at each PR boundary)
            ad_in = ad if track_writes else (
                {k: jnp.zeros(g[k].shape, merge.num(g[k]).dtype)
                 for k in plan.atomics})
            log_names: List[str] = []

            def one_warp(wid):
                # dict copies: _Env mutates its dicts in place, and the
                # carried sh/g must stay pristine for the post-vmap
                # merge (aliasing would leak batched tracers into them).
                # Block-replicated vars are handed over as this warp's
                # own (W,) row — see _Env.block_rows.
                env = _Env(
                    ck, wid=wid, n_warps=n_warps, uniforms=uniforms,
                    warp_vars={},
                    block_vars={k: v[wid] for k, v in bv.items()},
                    shmem=dict(sh), globals_=dict(g),
                    simd=simd, track_writes=True, block_rows=True,
                    store_masks={k: jnp.zeros(g[k].shape, jnp.bool_)
                                 for k in plan.masked},
                    atomic_deltas=dict(ad_in),
                    shared_masks={k: jnp.zeros(sh[k].shape, jnp.bool_)
                                  for k in plan.shared},
                    log_arrays=set(plan.logged),
                    block_dim3=bdim3, grid_dim3=gdim3)
                ex = run_warp_graph(node, env, jit_mode=jit_mode)
                # the log structure is static (one trace): capture the
                # entry order once, ship only the lane tensors out
                log_names.clear()
                log_names.extend(n for n, _, _ in env.store_log)
                # return only what this PR can write — unbatched arrays
                # stay broadcast constants with no copy/stack cost
                return ({k: env.block_vars[k] for k in plan.block_vars},
                        {k: env.shmem[k] for k in plan.shared},
                        {k: env.shared_masks[k] for k in plan.shared},
                        {k: env.globals[k] for k in plan.masked},
                        {k: env.store_masks[k] for k in plan.masked},
                        {k: env.atomic_deltas[k] for k in plan.atomics},
                        [(i, v) for _, i, v in env.store_log],
                        ex)

            wids = jnp.arange(n_warps, dtype=jnp.int32)
            bvs, shs, shms, gs, gms, ads, logs, exs = jax.vmap(one_warp)(wids)
            # block-replicated vars: each warp ran on its own (W,) row,
            # so the stacked rows ARE the merged (n_warps, W) plane
            bv2 = {**bv, **bvs}
            shm_in = {k: sh[k] for k in plan.shared}
            sh_new, _, _ = merge.merge_chunk(shm_in, shs, shms, {},
                                             fold_deltas=True)
            sh2 = {**sh, **sh_new}
            g_in = {k: g[k] for k in plan.masked}
            if track_writes:
                new_d = {k: ads[k] - ad_in[k][None] for k in ads}
                g_new, wrote, dsum = merge.merge_chunk(
                    g_in, gs, gms, new_d, fold_deltas=False)
                sm = {**sm, **{k: sm[k] | wrote[k] for k in wrote}}
                if dsum:
                    ad = {**ad, **{k: merge.denum(
                        merge.num(ad[k]) + dsum[k], ad[k].dtype)
                        for k in dsum}}
            else:
                g_new, _, _ = merge.merge_chunk(g_in, gs, gms, ads,
                                                fold_deltas=True)
            g2 = {**g, **g_new}
            # store-log replay: one flat scatter per logged store — the
            # single-writer contract makes cross-warp lanes disjoint
            # (masked-off lanes carry the one-past-end index and drop)
            for name, (idx, val) in zip(log_names, logs):
                g2[name] = g2[name].at[idx.reshape(-1)].set(
                    val.reshape(-1), mode="drop")
                if track_writes:
                    sm = {**sm, name: sm[name].at[idx.reshape(-1)].set(
                        True, mode="drop")}
            return bv2, sh2, g2, sm, ad, exs[0]

        def run_block_pr(node: BlockPR, bv, sh, g, sm, ad):
            """One block-level PR: the inter-warp loop (paper's Code 3
            outer loop), or the batched (n_warps, W) warp plane."""
            if batch_warps:
                bv, sh, g, sm, ad, ex = run_warp_plane(node, bv, sh, g,
                                                       sm, ad)
                return _block_succ(node, ex), bv, sh, g, sm, ad

            def one_warp(wid, carry):
                bv, sh, g, sm, ad, _ = carry
                env = _Env(ck, wid=wid, n_warps=n_warps, uniforms=uniforms,
                           warp_vars={}, block_vars=bv, shmem=sh, globals_=g,
                           simd=simd, track_writes=track_writes,
                           store_masks=sm, atomic_deltas=ad,
                           block_dim3=bdim3, grid_dim3=gdim3)
                ex = run_warp_graph(node, env, jit_mode=jit_mode)
                return (env.block_vars, env.shmem, env.globals,
                        env.store_masks, env.atomic_deltas, ex)

            init = (bv, sh, g, sm, ad, jnp.int32(0))
            if jit_mode:
                carry = init
                for wid in range(n_warps):
                    carry = one_warp(wid, carry)
            else:
                carry = lax.fori_loop(0, n_warps, one_warp, init)
            bv, sh, g, sm, ad, ex = carry
            return _block_succ(node, ex), bv, sh, g, sm, ad

        def _block_succ(node: BlockPR, ex):
            succ = jnp.asarray(
                [EXIT if s == EXIT else s for s in node.succ_ids] or [EXIT],
                jnp.int32)
            return succ[jnp.clip(ex, 0, len(node.succ_ids) - 1)] \
                if node.succ_ids else jnp.int32(EXIT)

        def outputs(g, sm, ad, bv, sh):
            if persist is None:
                return g, sm, ad
            return g, sm, ad, {"bv": {v: bv[v] for v in persist[0]},
                               "sh": {s: sh[s] for s in persist[1]}}

        nodes = ck.machine.nodes
        linear = _try_linear_block(ck.machine)
        if linear is not None:
            bv, sh, g, sm, ad = block_vars, shmem, globals_, store_masks, atomic_deltas
            for node in linear:
                _, bv, sh, g, sm, ad = run_block_pr(node, bv, sh, g, sm, ad)
            return outputs(g, sm, ad, bv, sh)

        # general PC machine at block level
        def mk_fn(node):
            def fn(st):
                bv, sh, g, sm, ad = (st["bv"], st["sh"], st["g"],
                                     st["sm"], st["ad"])
                if isinstance(node, BlockPR):
                    nxt, bv, sh, g, sm, ad = run_block_pr(node, bv, sh, g, sm, ad)
                else:  # BlockPeel — warp 0 lane 0 decides
                    flag = bv[node.cond][0, 0].astype(jnp.bool_)
                    nxt = jnp.where(flag, jnp.int32(node.t_id),
                                    jnp.int32(node.f_id))
                return {"pc": nxt, "bv": bv, "sh": sh, "g": g, "sm": sm,
                        "ad": ad}
            return fn

        fns = [mk_fn(n) for n in nodes]
        st0 = {"pc": jnp.int32(ck.machine.entry), "bv": block_vars,
               "sh": shmem, "g": globals_, "sm": store_masks,
               "ad": atomic_deltas}
        st = lax.while_loop(
            lambda s: s["pc"] != jnp.int32(EXIT),
            lambda s: lax.switch(jnp.clip(s["pc"], 0, len(fns) - 1), fns, s),
            st0)
        return outputs(st["g"], st["sm"], st["ad"], st["bv"], st["sh"])

    return block_fn


def _try_linear_block(machine: Machine) -> Optional[List[BlockPR]]:
    out: List[BlockPR] = []
    seen = set()
    cur = machine.entry
    while cur != EXIT:
        node = machine.nodes[cur]
        if not isinstance(node, BlockPR) or cur in seen:
            return None
        if len(set(node.succ_ids)) > 1:
            return None
        seen.add(cur)
        out.append(node)
        cur = node.succ_ids[0] if node.succ_ids else EXIT
    return out


def walk_instrs(ck: CompiledKernel):
    """Yield every instruction in the kernel, descending into If/While
    (and into every phase of a multi-phase compilation)."""
    return _all_instrs(ck)


def _all_instrs(ck: CompiledKernel):
    for sub in ck.phase_list():
        for blk in sub.cfg.blocks.values():
            stack = list(blk.instrs)
            while stack:
                s = stack.pop()
                yield s
                if isinstance(s, K.If):
                    stack.extend(s.then_body)
                    stack.extend(s.else_body)
                elif isinstance(s, K.While):
                    stack.extend(s.body)
