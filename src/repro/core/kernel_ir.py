"""Structured kernel IR.

The frontend lowers a restricted-Python CUDA-style kernel into this IR.
It plays the role NVVM IR plays in the paper's pipeline (Fig. 3): the
input to the hierarchical-collapsing transformation.  It is structured
(statement trees, not a flat CFG) because the frontend owns the source;
``lower.py`` flattens it into the CFG that the paper's algorithms
(extra-barrier insertion, block splitting, Alg. 1/2) operate on.

Expressions are pure; statements carry all effects.  Thread-varying
semantics: every expression conceptually evaluates once per CUDA thread;
the executor vectorizes a warp's 32 evaluations into one lane-vector op
(the paper's AVX mapping, here the TPU VPU lane axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

from .types import BarrierLevel, DType

# ----------------------------------------------------------------------------
# Expressions
# ----------------------------------------------------------------------------


class Expr:
    dtype: Optional[DType] = None  # filled by type inference


@dataclasses.dataclass
class Const(Expr):
    value: Any
    dtype: Optional[DType] = None

    def __repr__(self):
        return f"{self.value}"


@dataclasses.dataclass
class Var(Expr):
    name: str
    dtype: Optional[DType] = None

    def __repr__(self):
        return self.name


@dataclasses.dataclass
class BinOp(Expr):
    op: str  # + - * / // % & | ^ << >> min max pow
    lhs: Expr
    rhs: Expr
    dtype: Optional[DType] = None

    def __repr__(self):
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclasses.dataclass
class CmpOp(Expr):
    op: str  # < <= > >= == !=
    lhs: Expr
    rhs: Expr
    dtype: Optional[DType] = None  # always b1

    def __repr__(self):
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclasses.dataclass
class BoolOp(Expr):
    op: str  # and or
    args: List[Expr] = dataclasses.field(default_factory=list)
    dtype: Optional[DType] = None

    def __repr__(self):
        return f" {self.op} ".join(map(str, self.args))


@dataclasses.dataclass
class UnOp(Expr):
    op: str  # neg not abs exp log sqrt rsqrt tanh sigmoid floor f32 i32
    operand: Expr
    dtype: Optional[DType] = None

    def __repr__(self):
        return f"{self.op}({self.operand})"


@dataclasses.dataclass
class Select(Expr):
    cond: Expr
    on_true: Expr
    on_false: Expr
    dtype: Optional[DType] = None

    def __repr__(self):
        return f"select({self.cond}, {self.on_true}, {self.on_false})"


@dataclasses.dataclass
class Special(Expr):
    """Thread-identity intrinsics: tid, lane, wid, bid, bdim, gdim, wsize.

    tid/bid/bdim/gdim carry a dim3 ``axis`` ('x' default, so bare calls
    keep their 1-D meaning): the executor decomposes the *linear*
    thread/block id against the launch's static extents, x-fastest
    (``x = lin % dim.x``, ``y = lin // dim.x % dim.y``,
    ``z = lin // (dim.x * dim.y)``).  lane/wid/wsize are axis-less —
    warps are a property of the linearized thread order, as on CUDA.
    """
    kind: str
    dtype: Optional[DType] = None  # i32
    axis: str = "x"

    def __repr__(self):
        suffix = "" if self.axis == "x" else f".{self.axis}"
        return f"%{self.kind}{suffix}"


@dataclasses.dataclass
class LoadGlobal(Expr):
    array: str
    index: Expr
    dtype: Optional[DType] = None

    def __repr__(self):
        return f"{self.array}[{self.index}]"


@dataclasses.dataclass
class LoadShared(Expr):
    array: str
    index: Expr
    dtype: Optional[DType] = None

    def __repr__(self):
        return f"@{self.array}[{self.index}]"


# ----------------------------------------------------------------------------
# Statements
# ----------------------------------------------------------------------------


class Stmt:
    pass


@dataclasses.dataclass
class Assign(Stmt):
    name: str
    value: Expr

    def __repr__(self):
        return f"{self.name} = {self.value}"


@dataclasses.dataclass
class StoreGlobal(Stmt):
    array: str
    index: Expr
    value: Expr

    def __repr__(self):
        return f"{self.array}[{self.index}] = {self.value}"


@dataclasses.dataclass
class AtomicRMW(Stmt):
    """atomicAdd/atomicMax/... — beyond the paper (COX has no atomics)."""
    op: str  # add max min
    array: str
    index: Expr
    value: Expr
    dst: Optional[str] = None  # old value, if captured

    def __repr__(self):
        return f"atomic_{self.op} {self.array}[{self.index}], {self.value}"


@dataclasses.dataclass
class StoreShared(Stmt):
    array: str
    index: Expr
    value: Expr

    def __repr__(self):
        return f"@{self.array}[{self.index}] = {self.value}"


@dataclasses.dataclass
class Barrier(Stmt):
    level: BarrierLevel
    # 'source' distinguishes programmer barriers from the transformer's
    # extra barriers and from RAW/WAR barriers of warp-intrinsic lowering.
    source: str = "explicit"

    def __repr__(self):
        return f"barrier.{self.level.value}<{self.source}>"


@dataclasses.dataclass
class WarpCall(Stmt):
    """A warp-level collective: shfl_down/up/xor/idx, vote_all/any, ballot,
    and tile<N> variants (static cooperative groups).

    Lowered by ``passes.lower_warp_intrinsics`` into
    store→sync(RAW)→compute→sync(WAR) (paper §3.2, Code 5).
    """
    func: str          # shfl_down | shfl_up | shfl_xor | shfl_idx |
                       # vote_all | vote_any | ballot | red_add | red_max | red_min
    dst: Optional[str]
    args: List[Expr]
    width: int = 0     # 0 → full warp; else static tile size (coop groups)

    def __repr__(self):
        w = f"<{self.width}>" if self.width else ""
        return f"{self.dst} = {self.func}{w}({', '.join(map(str, self.args))})"


@dataclasses.dataclass
class If(Stmt):
    cond: Expr
    then_body: List[Stmt]
    else_body: List[Stmt] = dataclasses.field(default_factory=list)

    def __repr__(self):
        return f"if {self.cond}: [{len(self.then_body)}] else [{len(self.else_body)}]"


@dataclasses.dataclass
class While(Stmt):
    """Canonical loop (paper §3.3.2): single latch; for-range loops are
    lowered to this form by the frontend (LLVM loop-simplify analogue)."""
    cond: Expr
    body: List[Stmt]
    # For frontend-known trip counts (range loops with static bounds) the
    # executor's JIT mode may fully unroll:
    static_trip: Optional[int] = None
    induction: Optional[Tuple[str, Expr, Expr]] = None  # (var, init, step)

    def __repr__(self):
        return f"while {self.cond}: [{len(self.body)}]"


@dataclasses.dataclass
class Return(Stmt):
    def __repr__(self):
        return "return"


# ----------------------------------------------------------------------------
# Kernel container
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Kernel:
    name: str
    params: List[Any]                # ArraySpec | ScalarSpec, in order
    shared: List[Any]                # SharedSpec
    body: List[Stmt]
    source: str = ""

    def walk(self):
        """Yield every statement, depth-first."""
        def rec(stmts):
            for s in stmts:
                yield s
                if isinstance(s, If):
                    yield from rec(s.then_body)
                    yield from rec(s.else_body)
                elif isinstance(s, While):
                    yield from rec(s.body)
        yield from rec(self.body)


def subtree_barrier_level(stmts: Sequence[Stmt]) -> Optional[BarrierLevel]:
    """Highest barrier level contained in a statement list (incl. implicit
    barriers from warp collectives), or None.  Drives the lower.py decision
    between *predication* (barrier-free divergence) and *real CFG branches*
    (peelable, per the paper's aligned-barrier assumption)."""
    level: Optional[BarrierLevel] = None

    def up(lvl: BarrierLevel):
        nonlocal level
        if level is None or lvl.rank > level.rank:
            level = lvl

    def rec(body):
        for s in body:
            if isinstance(s, Barrier):
                up(s.level)
            elif isinstance(s, WarpCall):
                up(BarrierLevel.WARP)
            elif isinstance(s, If):
                rec(s.then_body)
                rec(s.else_body)
            elif isinstance(s, While):
                rec(s.body)
    rec(stmts)
    return level


def uses_grid_sync(k: Kernel) -> bool:
    """True when the kernel contains a grid-wide barrier (cooperative
    ``this_grid().sync()``) — the signal that compilation must phase-split
    (``repro.core.phases``) before the collapsing pipeline runs."""
    return any(isinstance(s, Barrier) and s.level == BarrierLevel.GRID
               for s in k.walk())


def uses_warp_features(k: Kernel) -> bool:
    """Feature detector for hybrid mode (paper §5.2.1): flat collapsing is
    used unless warp-level functions / warp barriers are present."""
    for s in k.walk():
        if isinstance(s, WarpCall):
            return True
        if isinstance(s, Barrier) and s.level == BarrierLevel.WARP:
            return True
    return False


def expr_children(e: Expr) -> List[Expr]:
    if isinstance(e, BinOp):
        return [e.lhs, e.rhs]
    if isinstance(e, CmpOp):
        return [e.lhs, e.rhs]
    if isinstance(e, BoolOp):
        return list(e.args)
    if isinstance(e, UnOp):
        return [e.operand]
    if isinstance(e, Select):
        return [e.cond, e.on_true, e.on_false]
    if isinstance(e, (LoadGlobal, LoadShared)):
        return [e.index]
    return []


def expr_vars(e: Expr) -> set:
    out = set()
    stack = [e]
    while stack:
        cur = stack.pop()
        if isinstance(cur, Var):
            out.add(cur.name)
        stack.extend(expr_children(cur))
    return out
