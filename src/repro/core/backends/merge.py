"""Unified write-mask / atomic-delta merge semantics.

Every execution level that runs CUDA code on *copies* of memory — a
vmap chunk of blocks on one device, one device's slice of the grid
under shard_map, or the per-warp copies of shared/global memory under
warp-batched execution (``execute.py``'s ``(n_warps, W)`` lane plane)
— reconciles those copies here, under one contract:

* **plain stores** are single-writer: the CUDA race-freedom contract
  guarantees at most one copy stores to a given element between syncs,
  so the merged value is *the* writer's value, transported bit-exactly
  (:func:`select_writer`: payload bits moved through a masked integer
  sum whose other terms are zero — merged stores are bitwise-identical
  to serial execution);
* **atomics** are order-free reductions: each copy accumulates its own
  delta buffer and deltas are summed across copies (and ``psum``-ed
  across devices) — a *stronger* story than the paper, which has no
  multi-device atomics at all;
* elements nobody touched keep the carried-in value.

Delta buffers live in the "numeric image" of the array dtype
(:func:`num` — bool promotes to int32 so masks/flags can be atomic
targets); :func:`denum` maps merged values back.

Semantics note: within one merge scope (a chunk, or a device between
merges) blocks do not observe each other's atomic updates.  For
order-free reductions that never inspect intermediate state this is
unobservable.  It IS observable to kernels that capture atomic old
values (the atomicAdd ticket pattern — valid and deterministic on CUDA,
where old values are unique across blocks), so those kernels are
rejected by the vmap/sharded builds (``LaunchPlan.check_mergeable``)
and kept on the serial scan backend by the ``auto`` heuristic.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp
from jax import lax


def num(x):
    """Numeric image of an array (bool -> int32) for delta arithmetic."""
    return x.astype(jnp.int32) if x.dtype == jnp.bool_ else x


def denum(x, dt):
    """Inverse of :func:`num` for a target dtype."""
    return (x != 0) if dt == jnp.bool_ else x.astype(dt)


def zeros_masks(globals_: Dict[str, Any]) -> Dict[str, Any]:
    return {k: jnp.zeros(v.shape, jnp.bool_) for k, v in globals_.items()}


def zeros_deltas(globals_: Dict[str, Any]) -> Dict[str, Any]:
    """Accumulator buffers, already in the numeric image."""
    return {k: jnp.zeros(v.shape, num(v).dtype) for k, v in globals_.items()}


def _to_bits(x):
    """Bit image of an array for exact payload transport: floats bitcast
    to same-width unsigned ints, bool widens to int32, ints pass."""
    if x.dtype == jnp.bool_:
        return x.astype(jnp.int32)
    if jnp.issubdtype(x.dtype, jnp.floating):
        nbits = jnp.dtype(x.dtype).itemsize * 8
        return lax.bitcast_convert_type(x, jnp.dtype(f"uint{nbits}"))
    return x


def _from_bits(b, dt):
    """Inverse of :func:`_to_bits`."""
    if dt == jnp.bool_:
        return b != 0
    if jnp.issubdtype(dt, jnp.floating):
        return lax.bitcast_convert_type(b, dt)
    return b


def select_writer(carry, copies, masks, *, axis: int = 0):
    """Single-writer selection along ``axis`` of ``copies``: the merged
    value at each element is *the* writing copy's value; untouched
    elements keep ``carry``.  Returns ``(merged, wrote_any)``.

    The payload is transported **bit-exactly**: values are bitcast to
    integers and moved through a masked sum (all other terms are zero —
    exact because integer addition with zero is the identity and the
    CUDA race-freedom contract guarantees at most one writer per
    element).  The masked sum is pure vector arithmetic, an order of
    magnitude cheaper on CPU than the equivalent argmax +
    ``take_along_axis`` gather; every bit pattern (-0.0, NaN payloads)
    survives unchanged.  A *racy* kernel (two writers between syncs)
    would get a garbage sum instead of an arbitrary winner — both are
    outside the contract.

    ``axis`` is the copy axis — axis 0 for a chunk of blocks or a warp
    plane merged at trace level; an inner axis when the caller merges an
    already-batched stack of copies (e.g. a (chunk, n_warps, N) plane
    merged over warps while the chunk axis stays batched).
    """
    cb = _to_bits(carry)
    xb = _to_bits(copies)
    stored = jnp.where(masks, xb, jnp.zeros_like(xb)).sum(
        axis=axis, dtype=cb.dtype)
    any_w = jnp.any(masks, axis=axis)
    return _from_bits(jnp.where(any_w, stored, cb), carry.dtype), any_w


def merge_chunk(g: Dict[str, Any], chunk_g: Dict[str, Any],
                chunk_m: Dict[str, Any], chunk_d: Dict[str, Any],
                *, fold_deltas: bool, axis: int = 0
                ) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """Merge an ``axis``-batched set of per-copy memories into carry
    ``g``.  The copy axis is a chunk of blocks (grid backends) or the
    warp axis of a batched (n_warps, W) plane (``execute.py``).

    Returns ``(g_new, wrote_any, delta_sum)`` where ``wrote_any`` is the
    per-array union of the copies' write masks and ``delta_sum`` the
    per-array summed deltas (numeric image; empty when the kernel has no
    atomics).  With ``fold_deltas=True`` the summed deltas are applied
    to ``g_new`` directly (single-device semantics); with ``False`` the
    caller owns them (the cross-device ``psum`` path, or the grid
    backends' mask/delta accumulators above a warp-plane merge).
    """
    out: Dict[str, Any] = {}
    wrote: Dict[str, Any] = {}
    dsum: Dict[str, Any] = {}
    for k in g:
        new, any_w = select_writer(g[k], chunk_g[k], chunk_m[k], axis=axis)
        if k in chunk_d:
            d = jnp.sum(num(chunk_d[k]), axis=axis)
            dsum[k] = d
            if fold_deltas:
                new = denum(num(new) + d, g[k].dtype)
        out[k] = new
        wrote[k] = any_w
    return out, wrote, dsum


def cross_device_merge(g0: Dict[str, Any], g: Dict[str, Any],
                       masks: Dict[str, Any], deltas: Dict[str, Any],
                       axis: str) -> Dict[str, Any]:
    """Reconcile per-device global-memory copies inside shard_map:
    single-writer stores land via masked psum (disjoint by contract),
    atomics via psum of the delta buffers (numeric image)."""
    merged = {}
    for k in g0:
        stored = lax.psum(jnp.where(masks[k], num(g[k]), 0), axis)
        cnt = lax.psum(masks[k].astype(jnp.int32), axis)
        val = jnp.where(cnt > 0, stored.astype(num(g[k]).dtype), num(g0[k]))
        if k in deltas:
            val = val + lax.psum(deltas[k], axis)
        merged[k] = denum(val, g0[k].dtype)
    return merged
