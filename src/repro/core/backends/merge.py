"""Unified write-mask / atomic-delta merge semantics.

Every backend that runs CUDA blocks on *copies* of global memory — a
vmap chunk of blocks on one device, or one device's slice of the grid
under shard_map — reconciles those copies here, under one contract:

* **plain stores** are single-writer: the CUDA race-freedom contract
  guarantees at most one block stores to a given element between
  grid-wide syncs, so the merged value is *the* writer's value, selected
  exactly (argmax over the write masks; no arithmetic on the payload —
  merged stores are bitwise-identical to serial execution);
* **atomics** are order-free reductions: each copy accumulates its own
  delta buffer and deltas are summed across copies (and ``psum``-ed
  across devices) — a *stronger* story than the paper, which has no
  multi-device atomics at all;
* elements nobody touched keep the carried-in value.

Delta buffers live in the "numeric image" of the array dtype
(:func:`num` — bool promotes to int32 so masks/flags can be atomic
targets); :func:`denum` maps merged values back.

Semantics note: within one merge scope (a chunk, or a device between
merges) blocks do not observe each other's atomic updates.  For
order-free reductions that never inspect intermediate state this is
unobservable.  It IS observable to kernels that capture atomic old
values (the atomicAdd ticket pattern — valid and deterministic on CUDA,
where old values are unique across blocks), so those kernels are
rejected by the vmap/sharded builds (``LaunchPlan.check_mergeable``)
and kept on the serial scan backend by the ``auto`` heuristic.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp
from jax import lax


def num(x):
    """Numeric image of an array (bool -> int32) for delta arithmetic."""
    return x.astype(jnp.int32) if x.dtype == jnp.bool_ else x


def denum(x, dt):
    """Inverse of :func:`num` for a target dtype."""
    return (x != 0) if dt == jnp.bool_ else x.astype(dt)


def zeros_masks(globals_: Dict[str, Any]) -> Dict[str, Any]:
    return {k: jnp.zeros(v.shape, jnp.bool_) for k, v in globals_.items()}


def zeros_deltas(globals_: Dict[str, Any]) -> Dict[str, Any]:
    """Accumulator buffers, already in the numeric image."""
    return {k: jnp.zeros(v.shape, num(v).dtype) for k, v in globals_.items()}


def merge_chunk(g: Dict[str, Any], chunk_g: Dict[str, Any],
                chunk_m: Dict[str, Any], chunk_d: Dict[str, Any],
                *, fold_deltas: bool
                ) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """Merge a (chunk, N)-batched set of per-block copies into carry ``g``.

    Returns ``(g_new, wrote_any, delta_sum)`` where ``wrote_any`` is the
    per-array union of the chunk's write masks and ``delta_sum`` the
    per-array summed deltas (numeric image; empty when the kernel has no
    atomics).  With ``fold_deltas=True`` the summed deltas are applied
    to ``g_new`` directly (single-device semantics); with ``False`` the
    caller owns them (the cross-device ``psum`` path).
    """
    out: Dict[str, Any] = {}
    wrote: Dict[str, Any] = {}
    dsum: Dict[str, Any] = {}
    for k in g:
        m = chunk_m[k]
        writer = jnp.argmax(m, axis=0)                      # (N,) block slot
        val = jnp.take_along_axis(chunk_g[k], writer[None, :], axis=0)[0]
        any_w = jnp.any(m, axis=0)
        new = jnp.where(any_w, val, g[k])
        if k in chunk_d:
            d = jnp.sum(num(chunk_d[k]), axis=0)
            dsum[k] = d
            if fold_deltas:
                new = denum(num(new) + d, g[k].dtype)
        out[k] = new
        wrote[k] = any_w
    return out, wrote, dsum


def cross_device_merge(g0: Dict[str, Any], g: Dict[str, Any],
                       masks: Dict[str, Any], deltas: Dict[str, Any],
                       axis: str) -> Dict[str, Any]:
    """Reconcile per-device global-memory copies inside shard_map:
    single-writer stores land via masked psum (disjoint by contract),
    atomics via psum of the delta buffers (numeric image)."""
    merged = {}
    for k in g0:
        stored = lax.psum(jnp.where(masks[k], num(g[k]), 0), axis)
        cnt = lax.psum(masks[k].astype(jnp.int32), axis)
        val = jnp.where(cnt > 0, stored.astype(num(g[k]).dtype), num(g0[k]))
        if k in deltas:
            val = val + lax.psum(deltas[k], axis)
        merged[k] = denum(val, g0[k].dtype)
    return merged
