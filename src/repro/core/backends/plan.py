"""LaunchPlan — everything a grid-execution backend needs, precomputed.

A plan captures the launch geometry (grid, block, warps), the execution
flavor (mode, simd), the chunking of block ids into re-dispatchable work
units, and the arg-binding convention (arrays flattened to CUDA-pointer
1-D views, scalars split off as block-uniform parameters).  Backends are
pure functions of a plan; none of them re-derive this state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .. import kernel_ir as K
from ..execute import CompiledKernel, make_block_fn, walk_instrs
from ..types import (COOP_MAX_RESIDENT_BLOCKS, ArraySpec, CoxUnsupported,
                     Dim3, DType, GraphRef, as_dim3, check_launch_geometry)

DEFAULT_CHUNK = 8  # blocks run simultaneously per vmap step


def check_donate_supported(backend: str, kernel_name: str) -> None:
    """Donation aliases each global's single device buffer; the sharded
    backend has none to alias (globals enter shard_map replicated and
    leave through a cross-device psum merge).  One shared check so the
    eager rejection in ``api.KernelFn.make_request`` and the build-time
    rejection in ``backends.sharded`` can never drift apart."""
    if backend == "sharded":
        raise CoxUnsupported(
            f"kernel '{kernel_name}': donate=True is unsupported on the "
            f"sharded backend — replicated cross-device globals have no "
            f"single buffer to reuse; drop donate= or launch without a "
            f"mesh")


def bind_kernel_args(ck: CompiledKernel, args: Sequence[Any]
                     ) -> Tuple[Dict[str, Any], Dict[str, tuple],
                                Dict[str, Any]]:
    """Split positional args into (globals dict, shapes, scalar
    uniforms); arrays are flattened (CUDA pointer semantics).  A module
    function (not only a plan method) because the stream dispatch layer
    binds args at *enqueue* time, before any plan is staged.

    A :class:`~repro.core.types.GraphRef` (a captured launch's output
    placeholder, only meaningful during stream capture) binds
    symbolically: its shape is recorded and the value passes through
    untouched for the graph tracer to resolve — the dtype cast and
    flatten happen *inside* the staged graph program, exactly where the
    eager path does them outside it."""
    if len(args) != len(ck.kernel.params):
        raise TypeError(f"kernel {ck.kernel.name} takes "
                        f"{len(ck.kernel.params)} args, "
                        f"got {len(args)}")
    globals_: Dict[str, Any] = {}
    shapes: Dict[str, tuple] = {}
    scalars: Dict[str, Any] = {}
    for spec, val in zip(ck.kernel.params, args):
        if isinstance(spec, ArraySpec):
            if isinstance(val, GraphRef):
                shapes[spec.name] = tuple(val.shape)
                globals_[spec.name] = val
                continue
            arr = jnp.asarray(val, spec.dtype.jnp)
            shapes[spec.name] = arr.shape
            globals_[spec.name] = arr.reshape(-1)
        else:
            if isinstance(val, GraphRef):
                raise CoxUnsupported(
                    f"kernel {ck.kernel.name}: scalar parameter "
                    f"'{spec.name}' bound to a captured array output "
                    f"({val!r}) — graph data edges carry global-memory "
                    f"arrays, not by-value uniforms")
            scalars[spec.name] = jnp.asarray(val, spec.dtype.jnp)
    return globals_, shapes, scalars


@dataclasses.dataclass(frozen=True)
class LaunchPlan:
    """Immutable description of one ``kernel<<<grid, block>>>`` launch.

    ``grid``/``block`` are the *linear totals* — everything downstream
    (chunk tables, warp counts, merge machinery, heuristics) keys on
    them, so ``grid=4`` and ``grid=(4, 1, 1)`` build identical plans.
    ``grid_dim``/``block_dim`` carry the canonical dim3 geometry for
    the executor's per-axis intrinsics only.
    """
    ck: CompiledKernel
    grid: int            # total blocks (grid_dim.total)
    block: int           # total threads per block (block_dim.total)
    n_warps: int
    mode: str            # 'normal' | 'jit' (resolved, never 'auto')
    simd: bool
    chunk: int           # blocks per vmap slice (1 = fully serial merge)
    has_atomics: bool
    captures_atomic_old: bool  # AtomicRMW with dst — serial-only
    warp_exec: str = "serial"  # 'serial' | 'batched' (resolved, never 'auto')
    grid_dim: Optional[Dim3] = None   # canonical dim3 (set by build)
    block_dim: Optional[Dim3] = None
    n_phases: int = 1          # >1 → cooperative (grid_sync) launch
    schedule: str = "chunked"  # 'chunked' | 'grid_stride'
    n_resident: Optional[int] = None  # grid-stride wave width (else None)

    @classmethod
    def build(cls, ck: CompiledKernel, *, grid, block,
              mode: str = "normal", simd: bool = True,
              chunk: Optional[int] = None,
              warp_exec: str = "serial", schedule: str = "chunked",
              n_resident: Optional[int] = None) -> "LaunchPlan":
        grid3 = as_dim3(grid, "grid")
        block3 = as_dim3(block, "block")
        check_launch_geometry(grid3, block3)
        grid, block = grid3.total, block3.total
        if mode not in ("normal", "jit"):
            raise ValueError(f"mode must be resolved to 'normal' or 'jit' "
                             f"before plan build, got {mode!r} "
                             f"(flat.choose_mode resolves 'auto')")
        if warp_exec not in ("serial", "batched"):
            raise ValueError(f"warp_exec must be resolved to 'serial' or "
                             f"'batched' before plan build, got "
                             f"{warp_exec!r} (flat.choose_warp_exec "
                             f"resolves 'auto')")
        if schedule not in ("chunked", "grid_stride"):
            raise ValueError(f"schedule must be resolved to 'chunked' or "
                             f"'grid_stride' before plan build, got "
                             f"{schedule!r} (runtime.resolve_schedule "
                             f"resolves 'auto')")
        n_warps = -(-block // ck.warp_size)
        n_phases = ck.n_phases
        if schedule == "grid_stride":
            # the wave width doubles as the merge chunk: wave i covers
            # the contiguous block ids [i·R, (i+1)·R), i.e. exactly row
            # i of the chunk table a chunked plan with chunk=R would
            # materialize — which is why the two schedules are bitwise
            # equal by construction
            n_resident = (min(grid, DEFAULT_CHUNK) if n_resident is None
                          else max(1, min(int(n_resident), grid)))
            if n_phases > 1 and n_resident > COOP_MAX_RESIDENT_BLOCKS:
                raise CoxUnsupported(
                    f"cooperative launch of '{ck.kernel.name}': "
                    f"n_resident={n_resident} exceeds the resident "
                    f"capacity ({COOP_MAX_RESIDENT_BLOCKS}) — the "
                    f"grid-stride wave is the resident set, exactly "
                    f"cudaLaunchCooperativeKernel's occupancy rule")
            chunk = n_resident
        elif n_phases > 1:
            # CUDA's cooperative-launch constraint: every block resident
            # per phase.  The chunked schedule may not split the grid —
            # each block's carried state (locals + shared memory) must
            # stay live across the whole phase sequence.  Grids beyond
            # the capacity take the grid-stride schedule above, which
            # pages carried state through a capacity-sized wave instead.
            if grid > COOP_MAX_RESIDENT_BLOCKS:
                raise CoxUnsupported(
                    f"cooperative launch of '{ck.kernel.name}': "
                    f"grid={grid} blocks exceeds the resident capacity "
                    f"({COOP_MAX_RESIDENT_BLOCKS}) — every block must be "
                    f"resident per phase for a grid barrier, exactly "
                    f"cudaLaunchCooperativeKernel's occupancy rule "
                    f"(schedule='grid_stride' pages blocks through a "
                    f"capacity-sized resident wave instead)")
            if chunk is not None and int(chunk) < grid:
                raise CoxUnsupported(
                    f"cooperative launch of '{ck.kernel.name}': "
                    f"chunk={chunk} would split the grid into waves, but "
                    f"a grid barrier needs every block resident per "
                    f"phase — drop chunk= (the plan schedules all "
                    f"{grid} blocks as one wave)")
            chunk = grid
        else:
            n_resident = None  # chunked plans carry no wave width
        if chunk is None:
            chunk = min(grid, DEFAULT_CHUNK)
        chunk = max(1, min(int(chunk), grid))
        atomics = [s for s in walk_instrs(ck) if isinstance(s, K.AtomicRMW)]
        plan = cls(ck, grid, block, n_warps, mode, simd, chunk,
                   has_atomics=bool(atomics),
                   captures_atomic_old=any(s.dst for s in atomics),
                   warp_exec=warp_exec, grid_dim=grid3, block_dim=block3,
                   n_phases=n_phases, schedule=schedule,
                   n_resident=n_resident)
        plan.check_warp_batchable()
        return plan

    def check_warp_batchable(self):
        """Reject launches whose semantics the per-warp copy merge of
        warp-batched execution cannot reproduce — the same ticket-
        pattern argument as :meth:`check_mergeable`, one level down:
        captured atomic old values are unique only under a serial warp
        order, and per-warp delta buffers would hand every warp of a
        block the same ticket."""
        if self.warp_exec == "batched" and self.captures_atomic_old:
            raise CoxUnsupported(
                f"kernel '{self.ck.kernel.name}' captures atomic old "
                f"values (atomic_add_old): old values are only unique "
                f"under a serial warp order, which warp-batched "
                f"execution's per-warp delta merge cannot reproduce — "
                f"use warp_exec='serial' (the 'auto' heuristic picks it)")

    def check_mergeable(self, backend: str):
        """Reject launches whose semantics the write-mask / atomic-delta
        merge cannot reproduce.  Captured atomic old values (the
        atomicAdd ticket pattern) are unique only under serial
        execution — per-copy delta buffers would hand every block the
        same ticket — so such kernels are scan-only."""
        if self.captures_atomic_old:
            raise CoxUnsupported(
                f"kernel '{self.ck.kernel.name}' captures atomic old "
                f"values (atomic_add_old): old values are only unique "
                f"under serial execution, which the {backend!r} "
                f"backend's delta merge cannot reproduce — launch "
                f"without a mesh and use backend='scan' (the "
                f"single-device 'auto' heuristic picks it)")

    # ---------------- phase staging (cooperative grid sync) ----------------

    def persist_spec(self) -> Optional[Tuple[Tuple[str, ...],
                                             Tuple[str, ...]]]:
        """The per-block state a phase executable must thread through:
        ``(carried local names, shared-memory names)`` — or ``None`` for
        single-phase launches (no state, the pre-phase program)."""
        if self.n_phases == 1:
            return None
        return (tuple(self.ck.carried),
                tuple(s.name for s in self.ck.kernel.shared))

    def block_fns(self, *, track_writes: bool):
        """One compiled block function per phase (a single-entry list
        for ordinary kernels), all built with identical launch knobs."""
        persist = self.persist_spec()
        return [make_block_fn(sub, n_warps=self.n_warps, mode=self.mode,
                              simd=self.simd, track_writes=track_writes,
                              warp_exec=self.warp_exec,
                              block_dim=self.block_dim,
                              grid_dim=self.grid_dim, persist=persist)
                for sub in self.ck.phase_list()]

    def init_persist(self, n_blocks: Optional[int] = None):
        """Phase-0 per-block state, stacked over ``n_blocks`` (default:
        the whole grid): zeroed ``(n_blocks, n_warps, W)`` planes for
        carried locals and zeroed flat shared buffers — the same initial
        values a single-phase launch starts from."""
        nb = self.grid if n_blocks is None else int(n_blocks)
        W = self.ck.warp_size
        bv = {v: jnp.zeros((nb, self.n_warps, W),
                           self.ck.var_types.get(v, DType.f32).jnp)
              for v in self.ck.carried}
        sh = {s.name: jnp.zeros((nb, int(np.prod(s.shape))), s.dtype.jnp)
              for s in self.ck.kernel.shared}
        return {"bv": bv, "sh": sh}

    # ---------------- arg binding ----------------

    def bind_args(self, args: Sequence[Any]
                  ) -> Tuple[Dict[str, Any], Dict[str, tuple], Dict[str, Any]]:
        """Split positional args into (globals dict, shapes, scalar
        uniforms); arrays are flattened (CUDA pointer semantics)."""
        return bind_kernel_args(self.ck, args)

    def uniforms(self, bid, scalars: Dict[str, Any]) -> Dict[str, Any]:
        """The block-uniform environment for one block (or a batch of
        blocks when ``bid`` carries a leading chunk axis)."""
        u = {"bid": bid, "bdim": jnp.int32(self.block),
             "gdim": jnp.int32(self.grid)}
        u.update(scalars)
        return u

    # ---------------- grid-stride waves ----------------

    def n_stride_waves(self, total: Optional[int] = None) -> int:
        """How many resident waves a grid-stride launch runs:
        ``ceil(total / n_resident)`` (default: the whole grid; sharded
        passes its per-device block count)."""
        n = self.grid if total is None else int(total)
        return max(1, -(-n // self.n_resident))

    def stride_bids(self, wave, *, base=0, limit: Optional[int] = None):
        """In-graph block ids of one grid-stride wave: the contiguous
        slice ``base + wave·R … base + (wave+1)·R`` of width
        ``R = n_resident``, entries at/past ``limit`` (default: the
        grid) masked to -1 — exactly row ``wave`` of the chunk table
        the chunked schedule would materialize, except computed inside
        the staged program so no O(grid) host array ever exists.
        ``wave``/``base`` may be traced (``fori_loop`` index,
        ``axis_index`` device offset)."""
        R = self.n_resident
        limit = self.grid if limit is None else limit
        start = (jnp.asarray(base, jnp.int32)
                 + jnp.asarray(wave, jnp.int32) * jnp.int32(R))
        bids = start + jnp.arange(R, dtype=jnp.int32)
        return jnp.where(bids < jnp.int32(limit), bids, jnp.int32(-1))

    # ---------------- chunking ----------------

    def chunked_bids(self) -> np.ndarray:
        """The whole grid's block ids as a (n_chunks, chunk) table,
        -1-padded (the sharded backend instead reshapes its slice of
        :func:`device_bid_table`)."""
        n = self.grid
        n_chunks = -(-n // self.chunk)
        bids = np.full((n_chunks * self.chunk,), -1, np.int32)
        bids[:n] = np.arange(n, dtype=np.int32)
        return bids.reshape(n_chunks, self.chunk)

    def device_bid_table(self, ndev: int) -> np.ndarray:
        """Round-robin-contiguous block ids per device, shaped
        (ndev, per_padded) with per_padded a multiple of ``chunk`` and
        -1 marking idle-pad slots."""
        per = -(-self.grid // ndev)
        per_padded = -(-per // self.chunk) * self.chunk
        table = np.full((ndev, per_padded), -1, np.int32)
        flat = np.arange(self.grid, dtype=np.int32)
        for d in range(ndev):
            mine = flat[d * per:(d + 1) * per]
            table[d, :len(mine)] = mine
        return table
