"""`vmap` backend — block-parallel execution.

COX's host runtime (paper §4) forks one pthread per CUDA block because
blocks are independent between grid-wide syncs.  This backend is the
XLA rendition of that observation: ``jax.vmap`` over the compiled block
function runs a *chunk* of blocks simultaneously — each on its own copy
of global memory with write-mask/atomic-delta tracking — and the copies
are reconciled by the shared merge module (single-writer stores selected
exactly, atomic deltas summed).  An outer ``lax.scan`` walks the chunks
so memory stays bounded at ``chunk × |globals|``.

The chunk axis is what exposes inter-block parallelism to the host
scheduler: XLA sees wide batched array ops instead of a length-`grid`
sequential loop of narrow ones.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..execute import make_block_fn
from . import merge
from .plan import LaunchPlan

name = "vmap"


def run_chunked(plan: LaunchPlan, block_fn, bid_chunks, globals_,
                scalars: Dict[str, Any], *, fold_deltas: bool
                ) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """scan-over-chunks × vmap-within-chunk block executor.

    ``bid_chunks`` is a (n_chunks, chunk) int32 table, -1 marking pad
    slots.  Returns ``(globals, masks, deltas)`` where masks/deltas are
    the union/sum over every executed block — the sharded backend feeds
    them to :func:`merge.cross_device_merge`; the single-device caller
    ignores them (``fold_deltas=True`` applies deltas in-line).
    """
    track = not fold_deltas
    masks0 = merge.zeros_masks(globals_) if track else {}
    deltas0 = (merge.zeros_deltas(globals_)
               if track and plan.has_atomics else {})

    def chunk_step(carry, bids):
        g, m_acc, d_acc = carry
        u = plan.uniforms(bids, scalars)            # bid: (chunk,)
        u_axes = {k: (0 if k == "bid" else None) for k in u}
        g2, m2, d2 = jax.vmap(lambda uu, gg: block_fn(uu, gg),
                              in_axes=(u_axes, None))(u, g)
        # pad slots (bid < 0) ran with garbage indices; their writes are
        # discarded by zeroing the masks/deltas before the merge
        valid = (bids >= 0)[:, None]
        m2 = {k: v & valid for k, v in m2.items()}
        d2 = {k: jnp.where(valid, v, 0) for k, v in d2.items()}
        g, wrote, dsum = merge.merge_chunk(g, g2, m2, d2,
                                           fold_deltas=fold_deltas)
        if track:
            m_acc = {k: m_acc[k] | wrote[k] for k in m_acc}
            d_acc = {k: d_acc[k] + dsum[k] for k in d_acc}
        return (g, m_acc, d_acc), None

    (g, m, d), _ = lax.scan(chunk_step, (globals_, masks0, deltas0),
                            jnp.asarray(bid_chunks))
    return g, m, d


def run_phase_wave(plan: LaunchPlan, fn, bids, globals_, scalars, state,
                   *, fold_deltas: bool):
    """One cooperative phase as a single all-resident ``jax.vmap`` wave
    over ``bids`` (the plan pins ``chunk == grid``, so there is exactly
    one wave — CUDA's cooperative-launch residency rule).  Per-block
    carried state rides the batch axis; -1 pad slots (sharded backend's
    idle lanes) get their masks/deltas zeroed exactly like
    :func:`run_chunked`'s pad handling.  Returns
    ``(globals, wrote_masks, delta_sums, state)`` with masks/deltas
    merged over the wave (``fold_deltas=True`` applies them in-line)."""
    u = plan.uniforms(bids, scalars)
    u_axes = {k: (0 if k == "bid" else None) for k in u}
    g2, m2, d2, st2 = jax.vmap(
        lambda uu, gg, ss: fn(uu, gg, state=ss),
        in_axes=(u_axes, None, 0))(u, globals_, state)
    valid = (bids >= 0)[:, None]
    m2 = {k: v & valid for k, v in m2.items()}
    d2 = {k: jnp.where(valid, v, 0) for k, v in d2.items()}
    g, wrote, dsum = merge.merge_chunk(globals_, g2, m2, d2,
                                       fold_deltas=fold_deltas)
    return g, wrote, dsum, st2


def build_fn(plan: LaunchPlan, mesh=None, axis: str = "data"):
    """Return the *raw* traceable ``run(globals_, scalars) -> globals_``
    launcher — the un-jitted form the graph tracer (``repro.core.
    graphs``) inlines into one fused program.  :func:`build` wraps it in
    ``jax.jit`` for standalone dispatch."""
    plan.check_mergeable(name)
    if plan.n_phases > 1:
        return _build_phased_fn(plan)
    block_fn = make_block_fn(plan.ck, n_warps=plan.n_warps, mode=plan.mode,
                             simd=plan.simd, track_writes=True,
                             warp_exec=plan.warp_exec,
                             block_dim=plan.block_dim, grid_dim=plan.grid_dim)
    bid_chunks = plan.chunked_bids()

    def run(globals_, scalars):
        g, _, _ = run_chunked(plan, block_fn, bid_chunks, globals_, scalars,
                              fold_deltas=True)
        return g

    return run


def build(plan: LaunchPlan, mesh=None, axis: str = "data",
          donate: bool = False):
    """Return a jitted ``exe(globals_, scalars) -> globals_`` launcher.
    ``donate=True`` donates the globals dict (argnum 0) — every input
    buffer aliases its same-shape output, so the chunked merge carry
    starts in place instead of on a copy."""
    return jax.jit(build_fn(plan, mesh=mesh, axis=axis),
                   donate_argnums=(0,) if donate else ())


def _build_phased_fn(plan: LaunchPlan):
    """Cooperative launch: one all-resident vmap wave per phase, globals
    merged (single-writer select + summed atomic deltas) at every phase
    boundary so phase *p+1* observes all of phase *p*'s writes."""
    fns = plan.block_fns(track_writes=True)
    bids = jnp.arange(plan.grid, dtype=jnp.int32)

    def run(globals_, scalars):
        g = globals_
        state = plan.init_persist()
        for fn in fns:
            g, _, _, state = run_phase_wave(plan, fn, bids, g, scalars,
                                            state, fold_deltas=True)
        return g

    return run
