"""`vmap` backend — block-parallel execution.

COX's host runtime (paper §4) forks one pthread per CUDA block because
blocks are independent between grid-wide syncs.  This backend is the
XLA rendition of that observation: ``jax.vmap`` over the compiled block
function runs a *chunk* of blocks simultaneously — each on its own copy
of global memory with write-mask/atomic-delta tracking — and the copies
are reconciled by the shared merge module (single-writer stores selected
exactly, atomic deltas summed).  An outer ``lax.scan`` walks the chunks
so memory stays bounded at ``chunk × |globals|``.

The chunk axis is what exposes inter-block parallelism to the host
scheduler: XLA sees wide batched array ops instead of a length-`grid`
sequential loop of narrow ones.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..execute import make_block_fn
from . import merge
from .plan import LaunchPlan

name = "vmap"


def _merge_wave(plan: LaunchPlan, block_fn, bids, g,
                scalars: Dict[str, Any], *, fold_deltas: bool):
    """One vmap wave over ``bids`` (-1 marking pad slots) + the
    write-mask/atomic-delta merge — the body both the chunk-table walk
    and the grid-stride loop run, so the two schedules are the same
    computation over the same wave contents."""
    u = plan.uniforms(bids, scalars)                # bid: (chunk,)
    u_axes = {k: (0 if k == "bid" else None) for k in u}
    g2, m2, d2 = jax.vmap(lambda uu, gg: block_fn(uu, gg),
                          in_axes=(u_axes, None))(u, g)
    # pad slots (bid < 0) ran with garbage indices; their writes are
    # discarded by zeroing the masks/deltas before the merge
    valid = (bids >= 0)[:, None]
    m2 = {k: v & valid for k, v in m2.items()}
    d2 = {k: jnp.where(valid, v, 0) for k, v in d2.items()}
    return merge.merge_chunk(g, g2, m2, d2, fold_deltas=fold_deltas)


def run_chunked(plan: LaunchPlan, block_fn, bid_chunks, globals_,
                scalars: Dict[str, Any], *, fold_deltas: bool
                ) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """scan-over-chunks × vmap-within-chunk block executor.

    ``bid_chunks`` is a (n_chunks, chunk) int32 table, -1 marking pad
    slots.  Returns ``(globals, masks, deltas)`` where masks/deltas are
    the union/sum over every executed block — the sharded backend feeds
    them to :func:`merge.cross_device_merge`; the single-device caller
    ignores them (``fold_deltas=True`` applies deltas in-line).
    """
    track = not fold_deltas
    masks0 = merge.zeros_masks(globals_) if track else {}
    deltas0 = (merge.zeros_deltas(globals_)
               if track and plan.has_atomics else {})

    def chunk_step(carry, bids):
        g, m_acc, d_acc = carry
        g, wrote, dsum = _merge_wave(plan, block_fn, bids, g, scalars,
                                     fold_deltas=fold_deltas)
        if track:
            m_acc = {k: m_acc[k] | wrote[k] for k in m_acc}
            d_acc = {k: d_acc[k] + dsum[k] for k in d_acc}
        return (g, m_acc, d_acc), None

    (g, m, d), _ = lax.scan(chunk_step, (globals_, masks0, deltas0),
                            jnp.asarray(bid_chunks))
    return g, m, d


def run_strided(plan: LaunchPlan, block_fn, globals_,
                scalars: Dict[str, Any], *, fold_deltas: bool,
                base=0, total: Optional[int] = None
                ) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """Grid-stride block executor: a counted ``lax.fori_loop`` over
    resident waves, each wave a vmap over ``n_resident`` block slots
    whose ids are computed in-graph (``plan.stride_bids``) — no
    ``(n_chunks, chunk)`` table is ever materialized, so the working
    set is ``n_resident × |globals|`` regardless of grid size.

    Wave *i* covers the contiguous ids ``base + [i·R, (i+1)·R)`` —
    exactly row *i* of the chunk table a chunked plan with ``chunk=R``
    would walk, so the two schedules produce bitwise-equal results.
    ``base``/``total`` scope the loop to one device's slice of the grid
    (``base`` may be a traced ``axis_index`` offset); the defaults
    cover the whole grid.  Returns ``(globals, masks, deltas)`` exactly
    like :func:`run_chunked`."""
    track = not fold_deltas
    masks0 = merge.zeros_masks(globals_) if track else {}
    deltas0 = (merge.zeros_deltas(globals_)
               if track and plan.has_atomics else {})
    total = plan.grid if total is None else int(total)
    n_waves = plan.n_stride_waves(total)
    limit = jnp.minimum(jnp.asarray(base, jnp.int32) + jnp.int32(total),
                        jnp.int32(plan.grid))

    def wave_step(i, carry):
        g, m_acc, d_acc = carry
        bids = plan.stride_bids(i, base=base, limit=limit)
        g, wrote, dsum = _merge_wave(plan, block_fn, bids, g, scalars,
                                     fold_deltas=fold_deltas)
        if track:
            m_acc = {k: m_acc[k] | wrote[k] for k in m_acc}
            d_acc = {k: d_acc[k] + dsum[k] for k in d_acc}
        return (g, m_acc, d_acc)

    g, m, d = lax.fori_loop(0, n_waves, wave_step,
                            (globals_, masks0, deltas0))
    return g, m, d


def run_phase_wave(plan: LaunchPlan, fn, bids, globals_, scalars, state,
                   *, fold_deltas: bool):
    """One cooperative phase as a single all-resident ``jax.vmap`` wave
    over ``bids`` (the plan pins ``chunk == grid``, so there is exactly
    one wave — CUDA's cooperative-launch residency rule).  Per-block
    carried state rides the batch axis; -1 pad slots (sharded backend's
    idle lanes) get their masks/deltas zeroed exactly like
    :func:`run_chunked`'s pad handling.  Returns
    ``(globals, wrote_masks, delta_sums, state)`` with masks/deltas
    merged over the wave (``fold_deltas=True`` applies them in-line)."""
    u = plan.uniforms(bids, scalars)
    u_axes = {k: (0 if k == "bid" else None) for k in u}
    g2, m2, d2, st2 = jax.vmap(
        lambda uu, gg, ss: fn(uu, gg, state=ss),
        in_axes=(u_axes, None, 0))(u, globals_, state)
    valid = (bids >= 0)[:, None]
    m2 = {k: v & valid for k, v in m2.items()}
    d2 = {k: jnp.where(valid, v, 0) for k, v in d2.items()}
    g, wrote, dsum = merge.merge_chunk(globals_, g2, m2, d2,
                                       fold_deltas=fold_deltas)
    return g, wrote, dsum, st2


def build_fn(plan: LaunchPlan, mesh=None, axis: str = "data"):
    """Return the *raw* traceable ``run(globals_, scalars) -> globals_``
    launcher — the un-jitted form the graph tracer (``repro.core.
    graphs``) inlines into one fused program.  :func:`build` wraps it in
    ``jax.jit`` for standalone dispatch."""
    plan.check_mergeable(name)
    if plan.n_phases > 1:
        return _build_phased_fn(plan)
    block_fn = make_block_fn(plan.ck, n_warps=plan.n_warps, mode=plan.mode,
                             simd=plan.simd, track_writes=True,
                             warp_exec=plan.warp_exec,
                             block_dim=plan.block_dim, grid_dim=plan.grid_dim)
    if plan.schedule == "grid_stride":
        def run(globals_, scalars):
            g, _, _ = run_strided(plan, block_fn, globals_, scalars,
                                  fold_deltas=True)
            return g

        return run
    bid_chunks = plan.chunked_bids()

    def run(globals_, scalars):
        g, _, _ = run_chunked(plan, block_fn, bid_chunks, globals_, scalars,
                              fold_deltas=True)
        return g

    return run


def build(plan: LaunchPlan, mesh=None, axis: str = "data",
          donate: bool = False):
    """Return a jitted ``exe(globals_, scalars) -> globals_`` launcher.
    ``donate=True`` donates the globals dict (argnum 0) — every input
    buffer aliases its same-shape output, so the chunked merge carry
    starts in place instead of on a copy."""
    return jax.jit(build_fn(plan, mesh=mesh, axis=axis),
                   donate_argnums=(0,) if donate else ())


def _build_phased_fn(plan: LaunchPlan):
    """Cooperative launch: one all-resident vmap wave per phase, globals
    merged (single-writer select + summed atomic deltas) at every phase
    boundary so phase *p+1* observes all of phase *p*'s writes."""
    if plan.schedule == "grid_stride":
        return _build_phased_strided_fn(plan)
    fns = plan.block_fns(track_writes=True)
    bids = jnp.arange(plan.grid, dtype=jnp.int32)

    def run(globals_, scalars):
        g = globals_
        state = plan.init_persist()
        for fn in fns:
            g, _, _, state = run_phase_wave(plan, fn, bids, g, scalars,
                                            state, fold_deltas=True)
        return g

    return run


def _build_phased_strided_fn(plan: LaunchPlan):
    """Cooperative grid-stride: each phase runs as a ``fori_loop`` over
    resident waves of ``n_resident`` blocks, with every block's
    persistent state paged through ``dynamic_slice`` windows of the
    stacked O(grid) planes.  All waves of phase *p* complete before
    phase *p+1* starts (the loop is inside the per-phase step), so the
    grid barrier's guarantee holds beyond the all-resident capacity —
    the lowering CUDA itself uses for occupancy-sized cooperative
    launches.  Single-writer stores and summed deltas make the result
    equal to the one-wave schedule regardless of wave grouping."""
    fns = plan.block_fns(track_writes=True)
    R = plan.n_resident
    n_waves = plan.n_stride_waves()
    tmap = jax.tree_util.tree_map

    def run(globals_, scalars):
        g = globals_
        # padded to whole waves: pad slots run with bid=-1 and have
        # their masks/deltas zeroed by run_phase_wave, so the garbage
        # state they write back is never observed
        state = plan.init_persist(n_blocks=n_waves * R)
        for fn in fns:
            def wave(i, carry, fn=fn):
                g, st = carry
                bids = plan.stride_bids(i)
                st_i = tmap(lambda a: lax.dynamic_slice_in_dim(
                    a, i * R, R, 0), st)
                g2, _, _, st2 = run_phase_wave(plan, fn, bids, g, scalars,
                                               st_i, fold_deltas=True)
                st = tmap(lambda a, v: lax.dynamic_update_slice_in_dim(
                    a, v, i * R, 0), st, st2)
                return g2, st

            g, state = lax.fori_loop(0, n_waves, wave, (g, state))
        return g

    return run
