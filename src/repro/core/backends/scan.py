"""`scan` backend — the loop-carried baseline.

One ``lax.scan`` over block indices, carrying global memory: block *i*
observes every write of blocks *< i* (a legal schedule; CUDA guarantees
nothing about cross-block ordering between grid-wide syncs).  Minimal
memory (one copy of global memory), zero merge cost, but the grid is
fully serialized from XLA's point of view.

Cooperative (grid-sync) launches run one scan per phase: the scan's
carry holds global memory (phase *p+1* blocks observe every phase-*p*
write — the grid barrier's guarantee) while each block's persistent
state (carried locals + shared memory) rides the scan's per-step
xs/ys — sliced in by block id, stacked back out.

``schedule='grid_stride'`` swaps the scanned ``arange(grid)`` for a
counted ``lax.fori_loop`` whose index *is* the block id — the same
serial block order, so results are bitwise-identical, but with no
O(grid) index array in the program (scan's wave width is 1 by
construction, so the stride wave degenerates to the loop counter).
Phased grid-stride pages each block's persistent state through
``dynamic_slice``/``dynamic_update_slice`` instead of scan xs/ys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..execute import make_block_fn
from .plan import LaunchPlan

name = "scan"


def build_fn(plan: LaunchPlan, mesh=None, axis: str = "data"):
    """Return the *raw* traceable ``run(globals_, scalars) -> globals_``
    launcher — the un-jitted form the graph tracer (``repro.core.
    graphs``) inlines into one fused program.  :func:`build` wraps it in
    ``jax.jit`` for standalone dispatch."""
    if plan.n_phases > 1:
        return _build_phased_fn(plan)
    block_fn = make_block_fn(plan.ck, n_warps=plan.n_warps, mode=plan.mode,
                             simd=plan.simd, warp_exec=plan.warp_exec,
                             block_dim=plan.block_dim, grid_dim=plan.grid_dim)
    if plan.schedule == "grid_stride":
        def run(globals_, scalars):
            def body(i, g):
                bid = jnp.asarray(i, jnp.int32)
                g2, _, _ = block_fn(plan.uniforms(bid, scalars), g)
                return g2

            return lax.fori_loop(0, plan.grid, body, globals_)

        return run

    def run(globals_, scalars):
        def step(g, bid):
            g2, _, _ = block_fn(plan.uniforms(bid, scalars), g)
            return g2, None

        g, _ = lax.scan(step, globals_,
                        jnp.arange(plan.grid, dtype=jnp.int32))
        return g

    return run


def build(plan: LaunchPlan, mesh=None, axis: str = "data",
          donate: bool = False):
    """Return a jitted ``exe(globals_, scalars) -> globals_`` launcher.
    ``donate=True`` donates the globals dict (argnum 0): every input
    buffer has a same-shape output to alias, so XLA reuses it in place
    instead of copying — the caller must treat the inputs as consumed."""
    return jax.jit(build_fn(plan, mesh=mesh, axis=axis),
                   donate_argnums=(0,) if donate else ())


def _build_phased_fn(plan: LaunchPlan):
    if plan.schedule == "grid_stride":
        return _build_phased_strided_fn(plan)
    fns = plan.block_fns(track_writes=False)
    bids = jnp.arange(plan.grid, dtype=jnp.int32)

    def run(globals_, scalars):
        g = globals_
        state = plan.init_persist()
        for fn in fns:
            def step(carry, x, fn=fn):
                bid, st = x
                g2, _, _, st2 = fn(plan.uniforms(bid, scalars), carry,
                                   state=st)
                return g2, st2

            g, state = lax.scan(step, g, (bids, state))
        return g

    return run


def _build_phased_strided_fn(plan: LaunchPlan):
    """Cooperative grid-stride: a counted ``fori_loop`` per phase whose
    index is the block id, paging each block's persistent state in and
    out of the stacked O(grid) planes with ``dynamic_slice`` — every
    block of phase *p* completes before phase *p+1* starts, so the grid
    barrier's guarantee holds at any grid size (the resident capacity
    becomes a lowering decision, not a launch limit).  Same serial
    block order as the scanned schedule ⇒ bitwise-identical results."""
    fns = plan.block_fns(track_writes=False)
    tmap = jax.tree_util.tree_map

    def run(globals_, scalars):
        g = globals_
        state = plan.init_persist()
        for fn in fns:
            def body(i, carry, fn=fn):
                g, st = carry
                bid = jnp.asarray(i, jnp.int32)
                st_i = tmap(lambda a: lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False), st)
                g2, _, _, st2 = fn(plan.uniforms(bid, scalars), g,
                                   state=st_i)
                st = tmap(lambda a, v: lax.dynamic_update_index_in_dim(
                    a, v, i, 0), st, st2)
                return g2, st

            g, state = lax.fori_loop(0, plan.grid, body, (g, state))
        return g

    return run
