"""`sharded` backend — shard_map over devices × vmap within device.

Blocks are dealt round-robin-contiguously over a mesh axis; each device
runs its slice of the grid with the same chunked block-parallel executor
as the single-device `vmap` backend (so the multi-device path owns no
execution or merge logic of its own), then the per-device copies of
global memory are reconciled with the shared write-mask / psum-delta
merge.  Straggler note for the 1000-node posture: blocks are pure
functions of (bid, inputs), so any chunk can be re-executed anywhere —
the -1-padded per-device bid table is the re-dispatchable unit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..execute import make_block_fn
from . import merge
from .block_vmap import run_chunked, run_phase_wave
from .plan import LaunchPlan, check_donate_supported

name = "sharded"


def build_fn(plan: LaunchPlan, mesh=None, axis: str = "data"):
    """Return the *raw* traceable ``run(globals_, scalars) -> globals_``
    launcher — the un-jitted form the graph tracer (``repro.core.
    graphs``) inlines into one fused program.  :func:`build` wraps it in
    ``jax.jit`` for standalone dispatch."""
    if mesh is None:
        raise ValueError("the sharded backend needs a mesh")
    plan.check_mergeable(name)
    if plan.n_phases > 1:
        return _build_phased_fn(plan, mesh, axis)
    ndev = mesh.shape[axis]
    block_fn = make_block_fn(plan.ck, n_warps=plan.n_warps, mode=plan.mode,
                             simd=plan.simd, track_writes=True,
                             warp_exec=plan.warp_exec,
                             block_dim=plan.block_dim, grid_dim=plan.grid_dim)
    bid_table = jnp.asarray(plan.device_bid_table(ndev))

    def device_fn(dev_bids, g0, scalars):
        # local view of the sharded (ndev, per) table is (1, per):
        # reshape to this device's (n_chunks, chunk) work units
        bid_chunks = dev_bids.reshape(-1, plan.chunk)
        g, masks, deltas = run_chunked(plan, block_fn, bid_chunks, g0,
                                       scalars, fold_deltas=False)
        return merge.cross_device_merge(g0, g, masks, deltas, axis)

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(axis), P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(bid_table, globals_, scalars)

    return run


def build(plan: LaunchPlan, mesh=None, axis: str = "data",
          donate: bool = False):
    """Return a jitted ``exe(globals_, scalars) -> globals_`` launcher."""
    if donate:
        check_donate_supported(name, plan.ck.kernel.name)
    return jax.jit(build_fn(plan, mesh=mesh, axis=axis))


def _build_phased_fn(plan: LaunchPlan, mesh, axis: str):
    """Cooperative launch over a mesh: each device keeps its slice of
    the grid resident across the whole phase sequence (per-block carried
    state never leaves its device — blocks are pinned, the bid table is
    identical every phase), and global memory is reconciled with the
    masked-psum / delta-psum merge at **every phase boundary**, so a
    phase-*p+1* block on one device observes phase-*p* writes made on
    any other device — the grid barrier's guarantee."""
    ndev = mesh.shape[axis]
    fns = plan.block_fns(track_writes=True)
    per = -(-plan.grid // ndev)
    table = np.full((ndev, per), -1, np.int32)
    flat = np.arange(plan.grid, dtype=np.int32)
    for d in range(ndev):
        mine = flat[d * per:(d + 1) * per]
        table[d, :len(mine)] = mine
    bid_table = jnp.asarray(table)

    def device_fn(dev_bids, g0, scalars):
        dev_bids = dev_bids.reshape(-1)        # this device's resident wave
        g = g0
        state = plan.init_persist(n_blocks=dev_bids.shape[0])
        for fn in fns:
            g2, wrote, dsum, state = run_phase_wave(
                plan, fn, dev_bids, g, scalars, state, fold_deltas=False)
            g = merge.cross_device_merge(g, g2, wrote, dsum, axis)
        return g

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(axis), P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(bid_table, globals_, scalars)

    return run
