"""`sharded` backend — shard_map over devices × vmap within device.

Blocks are dealt round-robin-contiguously over a mesh axis; each device
runs its slice of the grid with the same chunked block-parallel executor
as the single-device `vmap` backend (so the multi-device path owns no
execution or merge logic of its own), then the per-device copies of
global memory are reconciled with the shared write-mask / psum-delta
merge.  Straggler note for the 1000-node posture: blocks are pure
functions of (bid, inputs), so any chunk can be re-executed anywhere —
the -1-padded per-device bid table is the re-dispatchable unit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..execute import make_block_fn
from . import merge
from .block_vmap import run_chunked, run_phase_wave, run_strided
from .plan import LaunchPlan, check_donate_supported

name = "sharded"


def build_fn(plan: LaunchPlan, mesh=None, axis: str = "data"):
    """Return the *raw* traceable ``run(globals_, scalars) -> globals_``
    launcher — the un-jitted form the graph tracer (``repro.core.
    graphs``) inlines into one fused program.  :func:`build` wraps it in
    ``jax.jit`` for standalone dispatch."""
    if mesh is None:
        raise ValueError("the sharded backend needs a mesh")
    plan.check_mergeable(name)
    if plan.n_phases > 1:
        return _build_phased_fn(plan, mesh, axis)
    ndev = mesh.shape[axis]
    block_fn = make_block_fn(plan.ck, n_warps=plan.n_warps, mode=plan.mode,
                             simd=plan.simd, track_writes=True,
                             warp_exec=plan.warp_exec,
                             block_dim=plan.block_dim, grid_dim=plan.grid_dim)
    if plan.schedule == "grid_stride":
        return _build_strided_fn(plan, mesh, axis, block_fn)
    bid_table = jnp.asarray(plan.device_bid_table(ndev))

    def device_fn(dev_bids, g0, scalars):
        # local view of the sharded (ndev, per) table is (1, per):
        # reshape to this device's (n_chunks, chunk) work units
        bid_chunks = dev_bids.reshape(-1, plan.chunk)
        g, masks, deltas = run_chunked(plan, block_fn, bid_chunks, g0,
                                       scalars, fold_deltas=False)
        return merge.cross_device_merge(g0, g, masks, deltas, axis)

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(axis), P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(bid_table, globals_, scalars)

    return run


def _build_strided_fn(plan: LaunchPlan, mesh, axis: str, block_fn):
    """Grid-stride over a mesh: the resident slots stripe across
    devices — device *d* owns the contiguous ids ``[d·per, (d+1)·per)``
    (the same round-robin-contiguous deal as ``device_bid_table``, so
    results match the chunked schedule bitwise) and loops its slice in
    waves of ``n_resident`` with ids computed from ``lax.axis_index``
    inside the staged program.  No ``(ndev, per)`` table is built or
    shipped; the per-device working set is ``n_resident × |globals|``
    regardless of grid size."""
    ndev = mesh.shape[axis]
    per = -(-plan.grid // ndev)

    def device_fn(g0, scalars):
        base = lax.axis_index(axis) * per
        g, masks, deltas = run_strided(plan, block_fn, g0, scalars,
                                       fold_deltas=False, base=base,
                                       total=per)
        return merge.cross_device_merge(g0, g, masks, deltas, axis)

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(globals_, scalars)

    return run


def build(plan: LaunchPlan, mesh=None, axis: str = "data",
          donate: bool = False):
    """Return a jitted ``exe(globals_, scalars) -> globals_`` launcher."""
    if donate:
        check_donate_supported(name, plan.ck.kernel.name)
    return jax.jit(build_fn(plan, mesh=mesh, axis=axis))


def _build_phased_fn(plan: LaunchPlan, mesh, axis: str):
    """Cooperative launch over a mesh: each device keeps its slice of
    the grid resident across the whole phase sequence (per-block carried
    state never leaves its device — blocks are pinned, the bid table is
    identical every phase), and global memory is reconciled with the
    masked-psum / delta-psum merge at **every phase boundary**, so a
    phase-*p+1* block on one device observes phase-*p* writes made on
    any other device — the grid barrier's guarantee."""
    if plan.schedule == "grid_stride":
        return _build_phased_strided_fn(plan, mesh, axis)
    ndev = mesh.shape[axis]
    fns = plan.block_fns(track_writes=True)
    per = -(-plan.grid // ndev)
    table = np.full((ndev, per), -1, np.int32)
    flat = np.arange(plan.grid, dtype=np.int32)
    for d in range(ndev):
        mine = flat[d * per:(d + 1) * per]
        table[d, :len(mine)] = mine
    bid_table = jnp.asarray(table)

    def device_fn(dev_bids, g0, scalars):
        dev_bids = dev_bids.reshape(-1)        # this device's resident wave
        g = g0
        state = plan.init_persist(n_blocks=dev_bids.shape[0])
        for fn in fns:
            g2, wrote, dsum, state = run_phase_wave(
                plan, fn, dev_bids, g, scalars, state, fold_deltas=False)
            g = merge.cross_device_merge(g, g2, wrote, dsum, axis)
        return g

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(axis), P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(bid_table, globals_, scalars)

    return run


def _build_phased_strided_fn(plan: LaunchPlan, mesh, axis: str):
    """Cooperative grid-stride over a mesh: each device pages its
    contiguous slice of the grid through waves of ``n_resident`` blocks
    per phase (ids from ``lax.axis_index``, no bid table), accumulating
    write masks and atomic deltas across its waves, then global memory
    reconciles with the masked-psum / delta-psum merge at **every phase
    boundary** — all waves on all devices complete phase *p* before any
    block starts *p+1*, the grid barrier's guarantee, now without the
    all-resident capacity limit.  Per-block persistent state stays
    device-local in stacked planes windowed by ``dynamic_slice``."""
    ndev = mesh.shape[axis]
    fns = plan.block_fns(track_writes=True)
    R = plan.n_resident
    per = -(-plan.grid // ndev)
    n_waves = max(1, -(-per // R))
    tmap = jax.tree_util.tree_map

    def device_fn(g0, scalars):
        base = lax.axis_index(axis) * per
        limit = jnp.minimum(jnp.asarray(base, jnp.int32) + jnp.int32(per),
                            jnp.int32(plan.grid))
        g = g0
        state = plan.init_persist(n_blocks=n_waves * R)
        for fn in fns:
            masks0 = merge.zeros_masks(g)
            deltas0 = merge.zeros_deltas(g) if plan.has_atomics else {}

            def wave(i, carry, fn=fn):
                g, st, m_acc, d_acc = carry
                bids = plan.stride_bids(i, base=base, limit=limit)
                st_i = tmap(lambda a: lax.dynamic_slice_in_dim(
                    a, i * R, R, 0), st)
                g2, wrote, dsum, st2 = run_phase_wave(
                    plan, fn, bids, g, scalars, st_i, fold_deltas=False)
                st = tmap(lambda a, v: lax.dynamic_update_slice_in_dim(
                    a, v, i * R, 0), st, st2)
                m_acc = {k: m_acc[k] | wrote[k] for k in m_acc}
                d_acc = {k: d_acc[k] + dsum[k] for k in d_acc}
                return g2, st, m_acc, d_acc

            g2, state, masks, deltas = lax.fori_loop(
                0, n_waves, wave, (g, state, masks0, deltas0))
            g = merge.cross_device_merge(g, g2, masks, deltas, axis)
        return g

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(globals_, scalars)

    return run
