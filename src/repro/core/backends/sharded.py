"""`sharded` backend — shard_map over devices × vmap within device.

Blocks are dealt round-robin-contiguously over a mesh axis; each device
runs its slice of the grid with the same chunked block-parallel executor
as the single-device `vmap` backend (so the multi-device path owns no
execution or merge logic of its own), then the per-device copies of
global memory are reconciled with the shared write-mask / psum-delta
merge.  Straggler note for the 1000-node posture: blocks are pure
functions of (bid, inputs), so any chunk can be re-executed anywhere —
the -1-padded per-device bid table is the re-dispatchable unit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..execute import make_block_fn
from . import merge
from .block_vmap import run_chunked
from .plan import LaunchPlan

name = "sharded"


def build(plan: LaunchPlan, mesh=None, axis: str = "data"):
    """Return a jitted ``exe(globals_, scalars) -> globals_`` launcher."""
    if mesh is None:
        raise ValueError("the sharded backend needs a mesh")
    plan.check_mergeable(name)
    ndev = mesh.shape[axis]
    block_fn = make_block_fn(plan.ck, n_warps=plan.n_warps, mode=plan.mode,
                             simd=plan.simd, track_writes=True,
                             warp_exec=plan.warp_exec,
                             block_dim=plan.block_dim, grid_dim=plan.grid_dim)
    bid_table = jnp.asarray(plan.device_bid_table(ndev))

    def device_fn(dev_bids, g0, scalars):
        # local view of the sharded (ndev, per) table is (1, per):
        # reshape to this device's (n_chunks, chunk) work units
        bid_chunks = dev_bids.reshape(-1, plan.chunk)
        g, masks, deltas = run_chunked(plan, block_fn, bid_chunks, g0,
                                       scalars, fold_deltas=False)
        return merge.cross_device_merge(g0, g, masks, deltas, axis)

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(axis), P(), P()), out_specs=P(),
                   check_vma=False)

    def run(globals_, scalars):
        return fn(bid_table, globals_, scalars)

    return jax.jit(run)
