"""Pluggable grid-execution backends for the COX launcher.

A backend turns a :class:`~repro.core.backends.plan.LaunchPlan` into a
jitted ``exe(globals_, scalars) -> globals_`` callable via ``build``,
and exposes the same launcher un-jitted via ``build_fn`` so the graph
tracer (``repro.core.graphs``) can inline whole launches into one fused
XLA program:

* ``scan``    — loop-carried baseline: one ``lax.scan`` over block ids
                (minimal memory, fully serialized grid);
* ``vmap``    — block-parallel: ``jax.vmap`` runs chunks of blocks
                simultaneously, reconciled by the shared write-mask /
                atomic-delta merge (``merge.py``);
* ``sharded`` — shard_map over a mesh axis × the same vmap executor
                within each device, psum merge across devices.

``repro.core.flat.choose_backend`` is the autotune heuristic (kernel
features + grid size + mesh → backend name); ``get_backend`` resolves a
name to its module.
"""
from __future__ import annotations

from . import block_vmap, scan, sharded
from .plan import LaunchPlan  # noqa: F401

BACKENDS = {
    scan.name: scan,
    block_vmap.name: block_vmap,
    sharded.name: sharded,
}


def available_backends():
    return tuple(BACKENDS)


def get_backend(name: str):
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown launch backend {name!r}; "
                         f"available: {sorted(BACKENDS)}") from None
